"""Kernel micro-benchmarks: the sampling hot spot and TLR matvec.

On this CPU container the measurable path is the jnp reference (what XLA
executes); the Pallas kernels are validated in interpret mode and targeted
at TPU -- their VMEM behavior is assessed in the §Roofline analysis instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.core import TLROperator, covariance_problem

from .common import emit, scaled, timeit


def bench_lr_sample_chain():
    """Sampling-chain GEMM throughput (Eq. 2), the paper's dominant op."""
    rng = np.random.default_rng(0)
    for (T, k, b, r, s) in [(8, 8, 128, 32, 16), (15, 15, 128, 32, 16)]:
        Ui = jnp.asarray(rng.standard_normal((T, k, b, r)))
        Vi = jnp.asarray(rng.standard_normal((T, k, b, r)))
        W2 = jnp.asarray(rng.standard_normal((k, b, s)))
        f = jax.jit(ref.lr_sample_ref)
        dt, _ = timeit(f, Ui, Vi, W2, repeats=5)
        flops = T * k * 2 * (2 * b * r * s)
        emit(f"kernel/lr_sample_T{T}k{k}", dt * 1e6,
             f"gflops={flops/dt/1e9:.2f}")


def bench_tlr_matvec():
    n, b = scaled(2048), 128
    _, K = covariance_problem(n, 3, b)
    op = TLROperator.compress(jnp.asarray(K), b, b, 1e-6)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n))
    dt, _ = timeit(lambda: op.matvec(x), repeats=5)
    dense = jnp.asarray(K)
    dtd, _ = timeit(lambda: dense @ x, repeats=5)
    emit("kernel/tlr_matvec", dt * 1e6,
         f"dense_us={dtd*1e6:.0f};speedup={dtd/dt:.2f}")


ALL = [bench_lr_sample_chain, bench_tlr_matvec]
