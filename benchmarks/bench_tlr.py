"""TLR benchmarks, one function per paper table/figure (section 6).

Runnable standalone with suite selection:

    PYTHONPATH=src python -m benchmarks.bench_tlr --suite solve

``--suite solve`` times the solve phase: the old host-loop TRSV against the
jitted bucketed TRSM that replaced it (PR 2), and the TilePlan-dispatched
ranked read paths against the flat r_max-wide ones (PR 6, also standalone
as ``--suite plans``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CholOptions, TLROperator, choose_batching, covariance_problem,
    fractional_diffusion_problem, pcg, tile_plan, tlr_axpy, tlr_gemm,
    tlr_matvec, tlr_newton_schulz, tlr_round, tlr_to_dense, tlr_trsv,
    tlr_trsv_reference,
)

from .common import emit, factorization_flop_model, scaled, timeit, write_json


def _build(n, d, b, build_eps=1e-9, r_max=None):
    _, K = covariance_problem(n, d, b)
    op = TLROperator.compress(jnp.asarray(K), b, r_max or b, build_eps)
    return K, op


def _factor_err(K, fact):
    Ld = np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                         fact.L.nb, fact.L.b)))
    from repro.core import tile_perm_to_element_perm
    ep = tile_perm_to_element_perm(fact.perm, fact.L.b)
    if fact.d is not None:
        R = Ld @ np.diag(np.asarray(fact.d).reshape(-1)) @ Ld.T
    else:
        R = Ld @ Ld.T
    return np.linalg.norm(K[np.ix_(ep, ep)] - R, 2)


def bench_tile_size():
    """Table 1: tile size vs memory and factorization time (3D covariance)."""
    n = scaled(2048)
    for b in (64, 128, 256):
        K, op = _build(n, 3, b)
        dt, fact = timeit(
            lambda: op.cholesky(CholOptions(eps=1e-6, bs=8)), repeats=1)
        mem = op.memory_stats()
        emit(f"table1/tile{b}", dt * 1e6,
             f"mem_logical_MB={mem['total_bytes_logical']/2**20:.1f};"
             f"avg_rank={mem['avg_rank']:.1f};"
             f"err={_factor_err(K, fact):.2e}")


def bench_memory_growth():
    """Figure 5: memory vs N for 2D/3D at several eps; fit growth exponent."""
    for d in (2, 3):
        sizes = [scaled(512), scaled(1024), scaled(2048)]
        for eps in (1e-2, 1e-6):
            mems = []
            for n in sizes:
                b = 128 if n >= 1024 else 64
                _, K = covariance_problem(n, d, b)
                op = TLROperator.compress(jnp.asarray(K), b, b, eps)
                mems.append(op.memory_stats()["total_bytes_logical"])
            expo = np.polyfit(np.log(sizes), np.log(mems), 1)[0]
            emit(f"fig5/{d}d_eps{eps:g}", 0.0,
                 f"bytes={mems};growth_exponent={expo:.2f}")


def bench_rank_distributions():
    """Figure 6: rank distribution, regular grid vs random ball (3D)."""
    n, b = scaled(2048), 128
    for geom in ("grid", "ball"):
        _, K = covariance_problem(n, 3, b, geometry=geom)
        op = TLROperator.compress(jnp.asarray(K), b, b, 1e-6)
        ranks = np.sort(np.asarray(op.ranks))[::-1]
        emit(f"fig6/{geom}", 0.0,
             f"max={ranks[0]};median={int(np.median(ranks))};"
             f"over_half_tile={(ranks > b // 2).sum()}")


def bench_compress():
    """PR 2 construction path: batched-SVD compression vs the per-tile host
    SVD loop it replaced, plus the batched-ARA compressor."""
    n, b = scaled(2048), 128
    _, K = covariance_problem(n, 3, b)
    Kj = jnp.asarray(K)

    def old_loop():
        # the pre-PR-2 construction: one host SVD per tile
        nb = n // b
        for i in range(1, nb):
            for j in range(i):
                np.linalg.svd(K[i * b:(i + 1) * b, j * b:(j + 1) * b],
                              full_matrices=False)

    t_old, _ = timeit(old_loop, repeats=1)
    t_new, op = timeit(
        lambda: TLROperator.compress(Kj, b, b, 1e-6), repeats=1)
    t_ara, op_a = timeit(
        lambda: TLROperator.compress(Kj, b, b, 1e-6, method="ara"), repeats=1)
    emit("compress/batched_svd", t_new * 1e6,
         f"host_loop_us={t_old*1e6:.0f};speedup={t_old/t_new:.2f};"
         f"avg_rank={op.memory_stats()['avg_rank']:.1f}")
    emit("compress/batched_ara", t_ara * 1e6,
         f"avg_rank={op_a.memory_stats()['avg_rank']:.1f}")


def bench_factor_time():
    """Figure 7: TLR factor time vs N and eps, against dense Cholesky."""
    for d in (2, 3):
        for n in (scaled(1024), scaled(2048)):
            b = 128
            K, op = _build(n, d, b)
            t_dense, _ = timeit(lambda: np.linalg.cholesky(K), repeats=1)
            for eps in (1e-2, 1e-6):
                dt, fact = timeit(
                    lambda: op.cholesky(CholOptions(eps=eps, bs=8)),
                    repeats=1)
                emit(f"fig7/{d}d_n{n}_eps{eps:g}", dt * 1e6,
                     f"dense_us={t_dense*1e6:.0f};speedup={t_dense/dt:.2f};"
                     f"err={_factor_err(K, fact):.2e}")


def bench_profile():
    """Figure 8a: GEMM share of factorization work (FLOP-weighted)."""
    n, b = scaled(2048), 128
    K, op = _build(n, 3, b)
    fact = op.cholesky(CholOptions(eps=1e-6, bs=16))
    ranks = np.asarray(fact.L.ranks)
    model = factorization_flop_model(
        op.nb, b, int(ranks.max() or b), 16, fact.stats)
    phases = {k: f"{100*v/model['total']:.1f}%"
              for k, v in model["phases"].items()}
    emit("fig8a/profile", 0.0,
         f"gemm_fraction={model['gemm_fraction']:.3f};{phases}")
    assert model["gemm_fraction"] > 0.7


def bench_pcg():
    """Figures 9/10: fractional-diffusion PCG iterations vs eps."""
    n, b = scaled(2048), 128
    _, Kfd = fractional_diffusion_problem(n, b)
    op = TLROperator.compress(jnp.asarray(Kfd), b, b, 1e-10)
    rhs = jnp.asarray(np.random.default_rng(0).standard_normal(op.n))
    for eps in (1e-1, 1e-2, 1e-4, 1e-6):
        Keps = Kfd + eps * np.eye(op.n)
        op_eps = TLROperator.compress(jnp.asarray(Keps), b, b,
                                      min(eps * 1e-2, 1e-8))
        t_fact, fact = timeit(
            lambda: op_eps.cholesky(CholOptions(eps=eps, bs=16)),
            repeats=1)
        t_solve0 = time.perf_counter()
        x, iters, hist = pcg(op, rhs, precond=fact, tol=1e-6, maxiter=300)
        t_solve = time.perf_counter() - t_solve0
        # check_every batches the host-sync convergence checks (ISSUE 6);
        # the iterate history is bitwise identical, only sync count drops.
        t_b0 = time.perf_counter()
        _, it_b, _ = pcg(op, rhs, precond=fact, tol=1e-6, maxiter=300,
                         check_every=8)
        t_batched = time.perf_counter() - t_b0
        emit(f"fig9/eps{eps:g}", t_fact * 1e6,
             f"cg_iters={iters};residual={hist[-1]:.2e};"
             f"solve_us={t_solve*1e6:.0f};"
             f"batched_sync_us={t_batched*1e6:.0f};batched_iters={it_b}")
        assert it_b == iters


def bench_trsm_old_vs_new():
    """PR 2 solve phase: old host-loop TRSV vs the jitted bucketed TRSM,
    single and batched right-hand sides."""
    n, b = scaled(2048), 128
    K, op = _build(n, 3, b)
    fact = op.cholesky(CholOptions(eps=1e-6, bs=16))
    rng = np.random.default_rng(0)
    for m, rhs in (("1", jnp.asarray(rng.standard_normal(n))),
                   ("16", jnp.asarray(rng.standard_normal((n, 16))))):
        for trans in (False, True):
            t_old, x_old = timeit(
                lambda: tlr_trsv_reference(fact.L, rhs, trans=trans),
                repeats=3)
            t_new, x_new = timeit(
                lambda: tlr_trsv(fact.L, rhs, trans=trans), repeats=3)
            err = float(jnp.max(jnp.abs(x_old - x_new)))
            emit(f"trsm/rhs{m}_trans{int(trans)}", t_new * 1e6,
                 f"old_us={t_old*1e6:.0f};speedup={t_old/t_new:.2f};"
                 f"max_abs_diff={err:.2e}")
    t_solve, _ = timeit(lambda: fact.solve(jnp.asarray(
        rng.standard_normal(n))), repeats=3)
    emit("trsm/full_solve", t_solve * 1e6, "both_triangles+perm")


def bench_solve_plans():
    """ISSUE 6 tentpole: TilePlan-dispatched ranked read paths vs the flat
    r_max-wide paths on a skewed-rank factor (most tiles rank 1-4, a few at
    r_max, some empty), plus the auto policy against both manual modes.

    The factor is synthetic so the skew survives ``BENCH_SCALE``: covariance
    compression at small n produces near-uniform ranks, which is exactly the
    regime the ranked paths are *not* for. The store cap ``r_max`` sits well
    above every detected rank -- the ARA regime the plan layer exists for:
    the flat paths pay the cap, the ranked paths pay the histogram.
    """
    from repro.core.tlr import TLRMatrix, num_tiles

    b = 128
    r_max = 128
    nb = max(16, scaled(4096) // b)
    rng = np.random.default_rng(0)
    nt = num_tiles(nb)
    ranks = rng.integers(1, 5, size=nt).astype(np.int32)
    ranks[rng.permutation(nt)[: max(1, nt // 16)]] = 32
    ranks[rng.permutation(nt)[: max(1, nt // 16)]] = 0
    D = np.tril(rng.standard_normal((nb, b, b)) * 0.1)
    D[:, np.arange(b), np.arange(b)] = 2.0 + rng.random((nb, b))
    U = np.zeros((nt, b, r_max))
    V = np.zeros((nt, b, r_max))
    for t, r in enumerate(ranks):
        U[t, :, : int(r)] = rng.standard_normal((b, int(r))) * 0.1
        V[t, :, : int(r)] = rng.standard_normal((b, int(r))) * 0.1
    L = TLRMatrix(D=jnp.asarray(D), U=jnp.asarray(U), V=jnp.asarray(V),
                  ranks=jnp.asarray(ranks))
    plan = tile_plan(L.ranks, L.r_max)
    n = nb * b
    y1 = jnp.asarray(rng.standard_normal(n))
    y16 = jnp.asarray(rng.standard_normal((n, 16)))

    def _compare(fn, tag):
        times, outs = {}, {}
        for mode in ("flat", "ranked", "auto"):
            times[mode], outs[mode] = timeit(fn, mode, repeats=9, warmup=2)
        err = float(jnp.max(jnp.abs(outs["flat"] - outs["ranked"])))
        best = min(times["flat"], times["ranked"])
        emit(tag, times["ranked"] * 1e6,
             f"flat_us={times['flat']*1e6:.0f};"
             f"speedup={times['flat']/times['ranked']:.2f};"
             f"auto_us={times['auto']*1e6:.0f};"
             f"auto_vs_best_manual={best/times['auto']:.2f};"
             f"max_abs_diff={err:.2e}")

    for m, rhs in (("1", y1), ("16", y16)):
        for trans in (False, True):
            _compare(lambda mode: tlr_trsv(L, rhs, trans=trans,
                                           batching=mode),
                     f"plans/trsm_rhs{m}_trans{int(trans)}")

    Dsym = jnp.asarray(D + np.swapaxes(D, 1, 2))
    A = TLRMatrix(D=Dsym, U=L.U, V=L.V, ranks=L.ranks)
    for m, rhs in (("1", y1), ("16", y16)):
        _compare(lambda mode: tlr_matvec(A, rhs, batching=mode),
                 f"plans/matvec_rhs{m}")

    emit("plans/plan_info", 0.0,
         f"nb={nb};b={b};r_max={r_max};"
         f"widths={sorted(set(int(w) for w in plan.widths if w))};"
         f"rank_skew={plan.rank_skew:.2f};"
         f"padded_flop_ratio={plan.padded_flop_ratio():.2f};"
         f"decision={choose_batching(plan)}")


def bench_rank_vs_svd():
    """Figure 11b: ARA-detected ranks vs optimal SVD ranks at eps=1e-6."""
    n, b = scaled(1024), 128
    K, op = _build(n, 3, b)
    fact = op.cholesky(CholOptions(eps=1e-6, bs=8))
    Ld = np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                         fact.L.nb, fact.L.b)))
    nb = op.nb
    ara_total = int(np.asarray(fact.L.ranks).sum())
    svd_total = 0
    for i in range(1, nb):
        for j in range(i):
            blk = Ld[i * b:(i + 1) * b, j * b:(j + 1) * b]
            s = np.linalg.svd(blk, compute_uv=False)
            svd_total += int((s > 1e-6).sum())
    ratio = ara_total / max(svd_total, 1)
    emit("fig11b/ara_vs_svd", 0.0,
         f"ara_ranks={ara_total};svd_ranks={svd_total};ratio={ratio:.3f}")


def bench_pivoting():
    """Figures 12/13 + section 6.3: pivoting effect on ranks/time; LDLT cost."""
    n, b = scaled(1024), 128
    K, op = _build(n, 3, b)
    t0, f0 = timeit(lambda: op.cholesky(CholOptions(eps=1e-6, bs=8)),
                    repeats=1)
    base_rank = float(np.asarray(f0.L.ranks).mean())
    for pivot in ("frobenius", "power"):
        dt, fact = timeit(
            lambda: op.cholesky(CholOptions(eps=1e-6, bs=8, pivot=pivot)),
            repeats=1)
        emit(f"fig12/pivot_{pivot}", dt * 1e6,
             f"avg_rank={np.asarray(fact.L.ranks).mean():.1f};"
             f"base_rank={base_rank:.1f};base_us={t0*1e6:.0f};"
             f"err={_factor_err(K, fact):.2e}")
    dt, fl = timeit(lambda: op.ldlt(CholOptions(eps=1e-6, bs=8)),
                    repeats=1)
    emit("sec6.3/ldlt", dt * 1e6,
         f"chol_us={t0*1e6:.0f};avg_rank={np.asarray(fl.L.ranks).mean():.1f};"
         f"err={_factor_err(K, fl):.2e}")


def bench_left_vs_right():
    """ISSUE 4 tentpole: left-looking (ARA sampling chain) vs right-looking
    (eager trailing updates through the column-scoped SYRK) factorization,
    Cholesky and LDL^T."""
    n, b = scaled(1024), 128
    K, op = _build(n, 3, b)
    for ldl in (False, True):
        make = op.ldlt if ldl else op.cholesky
        name = "ldlt" if ldl else "chol"
        base_us = None
        for algo in ("left", "right"):
            dt, fact = timeit(
                lambda: make(CholOptions(eps=1e-6, bs=8, algo=algo)),
                repeats=1)
            extra = (f"err={_factor_err(K, fact):.2e};"
                     f"avg_rank={np.asarray(fact.L.ranks).mean():.1f};"
                     f"column_traces={fact.stats['column_traces']}")
            if algo == "left":
                base_us = dt * 1e6
            else:
                extra += (f";left_us={base_us:.0f};"
                          f"speedup={base_us/(dt*1e6):.2f};"
                          f"flushes={fact.stats['flushes']}")
            emit(f"rightlook/{name}_{algo}", dt * 1e6, extra)


def bench_lookahead():
    """ISSUE 9: sequential vs lookahead schedule on the right-looking
    driver. Lookahead overlaps column k's wide trailing update with column
    k+1's panel, hiding the adaptive-rank host sync; the rows record the
    end-to-end factor time, the mean per-column wall time, and the host-sync
    gap summed from the ``chol.sync`` telemetry spans."""
    from repro import obs

    n, b = scaled(1024), 128
    K, op = _build(n, 3, b)
    base_us = None
    for lookahead in (False, True):
        opts = CholOptions(eps=1e-6, bs=8, algo="right", lookahead=lookahead)
        op.cholesky(opts)                      # warm the jit caches
        tele = obs.current()
        n0 = len(tele.spans) if tele else 0
        t0 = time.perf_counter()
        fact = op.cholesky(opts)
        dt = time.perf_counter() - t0
        sync_s = sum(
            sp.dur for sp in (tele.spans[n0:] if tele else [])
            if sp.name == "chol.sync")
        col_us = [ev["seconds"] * 1e6 for ev in fact.stats["column_events"]]
        extra = (f"lookahead={int(lookahead)};"
                 f"schedule={fact.stats['schedule']['name']};"
                 f"sync_us={sync_s*1e6:.0f};sync_frac={sync_s/dt:.3f};"
                 f"col_us_mean={np.mean(col_us):.0f};"
                 f"col_us_max={np.max(col_us):.0f};"
                 f"err={_factor_err(K, fact):.2e}")
        if lookahead:
            extra += (f";seq_us={base_us:.0f};"
                      f"speedup={base_us/(dt*1e6):.2f}")
        else:
            base_us = dt * 1e6
        emit(f"lookahead/{'on' if lookahead else 'seq'}", dt * 1e6, extra)


def bench_batching_modes():
    """Section 4.2: dynamic batched ARA vs fused whole-column batching."""
    n, b = scaled(1024), 128
    K, op = _build(n, 3, b)
    for mode, bucket in (("fused", 0), ("dynamic", 0), ("dynamic", 4)):
        dt, fact = timeit(
            lambda: op.cholesky(CholOptions(eps=1e-6, bs=8, mode=mode,
                                            bucket=bucket)), repeats=1)
        emit(f"sec4.2/{mode}_bucket{bucket}", dt * 1e6,
             f"err={_factor_err(K, fact):.2e}")


def bench_column_buckets():
    """DESIGN section 2: compile vs steady-state time per column bucket.

    The shape-stable pipeline amortizes ~log2(nb) compiled column-step
    variants over nb columns; ``column_events`` records, per column, its
    (T, J) bucket pair, wall time, and whether the call traced (compiled) a
    fresh executable. Total wall time (compile + run) must beat the seed's
    one-executable-per-column driver on the same problem.
    """
    n, b = scaled(2048), 128
    K, op = _build(n, 3, b)
    for mode in ("dynamic", "fused"):
        t0 = time.perf_counter()
        fact = op.cholesky(CholOptions(eps=1e-6, bs=8, mode=mode))
        total = time.perf_counter() - t0
        ev = fact.stats["column_events"]
        buckets = {}
        for e in ev:
            d = buckets.setdefault((e["Tb"], e["Jb"]),
                                   {"compile_s": 0.0, "steady_s": 0.0,
                                    "cols": 0, "steady_cols": 0})
            d["cols"] += 1
            if e["traced"]:
                d["compile_s"] += e["seconds"]
            else:
                d["steady_s"] += e["seconds"]
                d["steady_cols"] += 1
        for (Tb, Jb), d in sorted(buckets.items()):
            # steady-state mean; compile-inclusive when the bucket's only
            # columns all traced (e.g. the Tb=1 bucket has one column)
            per_col = (d["steady_s"] / d["steady_cols"] if d["steady_cols"]
                       else (d["compile_s"] / d["cols"]))
            emit(f"pipeline/{mode}_bucket_T{Tb}_J{Jb}", per_col * 1e6,
                 f"cols={d['cols']};compile_s={d['compile_s']:.2f};"
                 f"steady_s={d['steady_s']:.2f}")
        emit(f"pipeline/{mode}_total", total * 1e6,
             f"column_traces={fact.stats['column_traces']};"
             f"columns={len(ev)};ladder={fact.stats['bucket_ladder']};"
             f"err={_factor_err(K, fact):.2e}")


def bench_share_omega():
    """DESIGN section 2 beyond-paper optimization: shared-Omega sampling."""
    n, b = scaled(1024), 128
    K, op = _build(n, 3, b)
    for share in (False, True):
        dt, fact = timeit(
            lambda: op.cholesky(CholOptions(eps=1e-6, bs=8,
                                            share_omega=share)),
            repeats=1)
        emit(f"design2/share_omega_{share}", dt * 1e6,
             f"err={_factor_err(K, fact):.2e};"
             f"avg_rank={np.asarray(fact.L.ranks).mean():.1f}")


def bench_flop_rate():
    """Figure 8b analogue: factorization FLOP rate vs this host's measured
    batched-GEMM roofline (the paper plots GPU TLR FLOP/s between its two
    batched-GEMM bounds)."""
    # host matmul roofline: a big f64 matmul
    m = 1024
    X = jnp.asarray(np.random.default_rng(0).standard_normal((m, m)))
    f = jax.jit(lambda a: a @ a)
    dt_mm, _ = timeit(f, X, repeats=3)
    peak = 2 * m**3 / dt_mm
    n, b = scaled(2048), 128
    K, op = _build(n, 3, b)
    dt, fact = timeit(
        lambda: op.cholesky(CholOptions(eps=1e-6, bs=16)), repeats=1)
    ranks = np.asarray(fact.L.ranks)
    model = factorization_flop_model(op.nb, b, int(ranks.max() or b), 16,
                                     fact.stats)
    rate = model["total"] / dt
    emit("fig8b/flop_rate", dt * 1e6,
         f"gflops={rate/1e9:.2f};host_gemm_gflops={peak/1e9:.2f};"
         f"fraction={rate/peak:.3f}")


def bench_algebra_round_axpy():
    """PR 3 tile algebra: batched rounding and low-rank add vs the dense
    equivalents (one QR+SVD pass over all nt tiles, no host loop)."""
    n, b = scaled(1024), 64
    _, K = covariance_problem(n, 3, b)
    Kj = jnp.asarray(K)
    op = TLROperator.compress(Kj, b, b, 1e-9)
    S = tlr_axpy(1.0, op.A, op.A)  # accumulated sum, r_max = 2b
    t_round, R = timeit(lambda: tlr_round(S, 1e-6), repeats=3)
    t_dense, _ = timeit(lambda: jnp.linalg.svd(Kj + Kj), repeats=1)
    emit("algebra/round", t_round * 1e6,
         f"dense_svd_us={t_dense*1e6:.0f};speedup={t_dense/t_round:.2f};"
         f"avg_rank={float(np.asarray(R.ranks).mean()):.1f}")
    t_axpy, _ = timeit(lambda: tlr_axpy(2.0, op.A, op.A, eps=1e-6),
                       repeats=3)
    emit("algebra/axpy_rounded", t_axpy * 1e6,
         f"round_us={t_round*1e6:.0f}")


def bench_algebra_gemm():
    """TLR x TLR product vs the dense GEMM it replaces."""
    n, b = scaled(1024), 64
    _, K = covariance_problem(n, 3, b)
    Kj = jnp.asarray(K)
    op = TLROperator.compress(Kj, b, b, 1e-9)
    t_tlr, C = timeit(lambda: tlr_gemm(op.A, op.A, 1e-6), repeats=3)
    t_dense, want = timeit(lambda: Kj @ Kj, repeats=3)
    err = float(jnp.linalg.norm(C.to_dense() - want) /
                jnp.linalg.norm(want))
    emit("algebra/gemm", t_tlr * 1e6,
         f"dense_us={t_dense*1e6:.0f};speedup={t_dense/t_tlr:.2f};"
         f"rel_err={err:.2e};avg_rank="
         f"{float(np.asarray(C.ranks).mean()):.1f}")


def bench_batching():
    """ISSUE 5 tentpole: rank-bucketed dynamic batching vs flat r_max-wide
    batching on a heterogeneous-rank problem (random-ball covariance, ranks
    spread well below r_max), with the cost_analysis-derived padded-vs-
    useful FLOP ratio of the rounding pass reported alongside wall times.
    """
    from functools import partial

    from repro.core import (
        CholOptions as CO, plan_rank_buckets, tlr_round)
    from repro.core.algebra import _round_factors
    from repro.kernels.ops import flop_estimate

    n, b = scaled(2048), 128
    _, K = covariance_problem(n, 3, b, geometry="ball")
    op = TLROperator.compress(jnp.asarray(K), b, b, 1e-4)
    ranks = np.asarray(op.ranks)
    r_max = op.r_max
    nt = int(ranks.shape[0])
    dtype = op.dtype

    # padded-vs-useful FLOPs of the rounding pass at these exact shapes:
    # the flat core runs all nt tiles at r_max; the ranked path runs each
    # rank bucket at its ladder width (count-padded). XLA's own
    # cost_analysis does the counting, so fusion effects are included.
    eps = jnp.asarray(1e-6, dtype)
    core = partial(_round_factors, r_out=min(r_max, b), rel=False, impl="ref")
    z = jnp.zeros((nt, b, r_max), dtype)
    flops_flat = flop_estimate(core, z, z, eps)
    flops_ranked = 0.0
    plan = plan_rank_buckets(ranks, r_max)
    for bk in plan.buckets:
        zb = jnp.zeros((bk.padded, b, bk.width), dtype)
        corew = partial(_round_factors, r_out=min(min(r_max, b), bk.width),
                        rel=False, impl="ref")
        flops_ranked += flop_estimate(corew, zb, zb, eps)
    ratio = flops_flat / max(flops_ranked, 1.0)

    t_flat, Rf = timeit(lambda: tlr_round(op.A, 1e-6), repeats=3)
    t_rank, Rr = timeit(lambda: tlr_round(op.A, 1e-6, batching="ranked"),
                        repeats=3)
    emit("batching/round", t_rank * 1e6,
         f"flat_us={t_flat*1e6:.0f};speedup={t_flat/t_rank:.2f};"
         f"padded_flop_ratio={ratio:.2f};flops_flat={flops_flat:.3e};"
         f"flops_ranked={flops_ranked:.3e};"
         f"avg_rank={ranks.mean():.1f};r_max={r_max};"
         f"rank_buckets={[bk.width for bk in plan.buckets]};"
         f"zero_tiles={plan.zero_count}")

    for algo in ("right", "left"):
        times = {}
        for batching in ("flat", "ranked", "auto"):
            dt, fact = timeit(
                lambda: op.cholesky(CO(eps=1e-6, bs=8, algo=algo,
                                       batching=batching)), repeats=1)
            times[batching] = dt
            cols = fact.stats["column_events"]
            per_col = (np.mean([e["seconds"] for e in cols if not e["traced"]])
                       if any(not e["traced"] for e in cols) else
                       np.mean([e["seconds"] for e in cols]))
            extra = (f"err={_factor_err(K, fact):.2e};"
                     f"per_col_us={per_col*1e6:.0f};"
                     f"avg_rank={np.asarray(fact.L.ranks).mean():.1f}")
            if batching == "ranked":
                extra += (f";flat_us={times['flat']*1e6:.0f};"
                          f"speedup={times['flat']/dt:.2f}")
                if algo == "right":
                    extra += (f";append_widths="
                              f"{sorted(set(fact.stats['append_widths']))}")
            elif batching == "auto":
                # ISSUE 6: CholOptions(batching="auto") must record its
                # decision in stats and track the best manual setting.
                pol = fact.stats["policy"]
                assert pol["requested"] == "auto"
                assert pol["batching"] in ("flat", "ranked")
                best = min(times["flat"], times["ranked"])
                extra += (f";decision={pol['batching']};"
                          f"rank_skew={pol['rank_skew']:.2f};"
                          f"right_flush={pol['right_flush']};"
                          f"best_manual_us={best*1e6:.0f};"
                          f"auto_vs_best_manual={best/dt:.2f}")
            emit(f"batching/{algo}_{batching}", dt * 1e6, extra)


def bench_newton_schulz():
    """Newton-Schulz TLR inverse as a PCG preconditioner: build time and
    iteration-count reduction on the fractional-diffusion system."""
    n, b = scaled(1024), 64
    _, Kfd = fractional_diffusion_problem(n, b)
    op = TLROperator.compress(jnp.asarray(Kfd), b, b, 1e-10)
    rhs = jnp.asarray(np.random.default_rng(0).standard_normal(n))
    _, it_plain, _ = pcg(op, rhs, tol=1e-6, maxiter=300)
    for iters in (4, 8):
        t_build, (Xop, info) = timeit(
            lambda: tlr_newton_schulz(op, iters=iters, eps=1e-8,
                                      scale="norm"), repeats=1)
        t_solve = time.perf_counter()
        _, it_pre, hist = pcg(op, rhs, precond=Xop, tol=1e-6, maxiter=300)
        t_solve = time.perf_counter() - t_solve
        emit(f"algebra/newton_schulz_{iters}", t_build * 1e6,
             f"cg_iters={it_pre};plain_iters={it_plain};"
             f"residual={hist[-1]:.2e};solve_us={t_solve*1e6:.0f};"
             f"avg_rank={info.avg_rank:.1f}")


def bench_serve():
    """ISSUE 7 tentpole: the continuous-batching inference server.

    A resident TLR factorization (deliberately loose, so ``pcg_solve``
    requests genuinely iterate and occupy slots across ticks) serves a
    mixed queue of solve/logdet/sample/pcg requests through fixed-shape
    ``(n, slots)`` RHS blocks. Reports per-kind p50/p99 latency, slot
    occupancy (asserted >= 0.8 -- the Algorithm 5 high-occupancy claim on
    the serving side), and end-to-end throughput. Warmup happens before
    any submit, so latencies are steady-state (zero recompiles; pinned in
    tests/test_serve.py).
    """
    from repro.serve import KINDS, ServeRequest

    n, b = scaled(2048), 64
    K, op = _build(n, 3, b)
    loose = TLROperator.compress(jnp.asarray(K), b, b, 1e-2)
    fact = loose.cholesky(CholOptions(eps=1e-2, bs=8))
    slots, check_every = 8, 4
    srv = fact.serve(operator=op, slots=slots, check_every=check_every)
    rng = np.random.default_rng(0)
    reqs = []
    for k in range(48):
        kind = KINDS[k % len(KINDS)]
        rhs = (rng.standard_normal(n)
               if kind in ("solve", "pcg_solve") else None)
        reqs.append(ServeRequest(kind, rhs=rhs, tol=1e-6, maxiter=100,
                                 seed=k))
    t0 = time.perf_counter()
    for r in reqs:
        srv.submit(r)
    results = srv.run()
    wall = time.perf_counter() - t0
    st = srv.stats
    occ = st.occupancy()
    assert len(results) == len(reqs)
    assert occ >= 0.8, f"occupancy {occ:.3f} < 0.8 on the bench schedule"
    for kind in KINDS:
        p = st.latency_percentiles(kind)
        if p["count"]:
            emit(f"serve/{kind}", p["p50_s"] * 1e6,
                 f"p50_us={p['p50_s']*1e6:.0f};p99_us={p['p99_s']*1e6:.0f};"
                 f"mean_us={p['mean_s']*1e6:.0f};count={p['count']}")
        else:
            # Zero completions of this kind: percentiles are null (the
            # ServerStats contract), recorded as such instead of a crash.
            emit(f"serve/{kind}", 0.0,
                 "p50_us=null;p99_us=null;mean_us=null;count=0")
    pall = st.latency_percentiles()
    if pall["count"]:
        emit("serve/latency_all", pall["p50_s"] * 1e6,
             f"p50_us={pall['p50_s']*1e6:.0f};"
             f"p99_us={pall['p99_s']*1e6:.0f};count={pall['count']}")
    else:
        emit("serve/latency_all", 0.0, "p50_us=null;p99_us=null;count=0")
    emit("serve/occupancy", 0.0,
         f"occupancy={occ:.3f};slots={slots};ticks={st.ticks};"
         f"admitted={st.admitted};completed={st.completed}")
    emit("serve/throughput", wall * 1e6,
         f"requests_per_s={len(reqs)/wall:.1f};wall_s={wall:.3f};"
         f"check_every={check_every}")
    pcg_res = [results[r.rid] for r in reqs if r.kind == "pcg_solve"]
    iters = [r.iterations for r in pcg_res]
    if iters:
        emit("serve/pcg_requests", 0.0,
             f"mean_iters={np.mean(iters):.1f};max_iters={max(iters)};"
             f"converged={sum(r.converged for r in pcg_res)}/{len(pcg_res)}")
    else:
        emit("serve/pcg_requests", 0.0,
             "mean_iters=null;max_iters=null;converged=0/0")


def bench_faults():
    """ISSUE 10: the robustness subsystem under the deterministic fault
    harness (``repro.faults``).

    Four groups of records:

    * ``faults/overhead_*`` -- wall-time cost of ``CholOptions(check=True)``
      on a *clean* factorization, per driver (the ISSUE 10 acceptance gate:
      <= 3% over the unchecked path; CI asserts on ``overhead_pct``);
    * ``faults/recover_*`` -- injected breakdowns that must *recover*:
      an indefinite diagonal tile (jitter ladder, both drivers) and a
      genuine rank spike under a hard cap (eps-loosen/densify ladder);
      each record asserts finite factors and counts the recorded
      ``HealthEvent``s;
    * ``faults/breakdown_detect`` -- an unrecoverable NaN diagonal must
      raise :class:`FactorizationBreakdown` (never return NaN factors);
    * ``faults/serve_*`` -- the serve-side guards: submit-time rejection
      of a non-finite RHS, poisoned-column isolation inside a co-batched
      solve block, and deadline eviction of a stalled request.
    """
    from repro import faults
    from repro.core import (
        FactorizationBreakdown, from_dense, tlr_cholesky,
    )
    from repro.serve import RequestRejected, ServeRequest

    n, b = scaled(2048), 64
    nb = n // b
    _, K = covariance_problem(n, 3, b)
    A = from_dense(jnp.asarray(K), b, b, 1e-9)

    # -- detection overhead on the clean path (both drivers) -----------------
    # Interleaved min-of-N wall times: the min is the standard noise-robust
    # estimator, and alternating the two variants cancels machine drift --
    # a median-of-3 A/B at quick-lane scale swings +-10% run to run, far
    # above the 3% gate this record feeds.
    for algo in ("left", "right"):
        off = CholOptions(eps=1e-6, bs=8, algo=algo)
        on = CholOptions(eps=1e-6, bs=8, algo=algo, check=True)
        fact = tlr_cholesky(A, on)          # warm both executables
        tlr_cholesky(A, off)
        t_off, t_on = [], []
        for _ in range(5):
            t0 = time.perf_counter()
            tlr_cholesky(A, off)
            t_off.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            tlr_cholesky(A, on)
            t_on.append(time.perf_counter() - t0)
        h = fact.stats["health"]
        ks = fact.stats["schedule"]["kind_seconds"]
        check_s = ks.get("check", 0.0)
        emit(f"faults/overhead_{algo}", min(t_on) * 1e6,
             f"clean_us={min(t_off)*1e6:.0f};"
             f"overhead_pct={(min(t_on)/min(t_off) - 1)*100:.2f};"
             f"check_stage_s={check_s:.4f};"
             f"columns_checked={h['columns_checked']};"
             f"events={len(h['events'])}")

    # -- recovery: indefinite diagonal tile -> jitter ladder -----------------
    Abad = faults.make_diag_indefinite(A, nb // 2, magnitude=4.0)
    for algo in ("left", "right"):
        t, fact = timeit(
            lambda: tlr_cholesky(Abad, CholOptions(eps=1e-6, bs=8, algo=algo,
                                                   check=True)),
            repeats=1)
        h = fact.stats["health"]
        spd = [e for e in h["events"] if e["kind"] == "spd_breakdown"]
        finite = all(bool(np.isfinite(np.asarray(x)).all())
                     for x in (fact.L.D, fact.L.U, fact.L.V))
        assert finite and spd, \
            f"indefinite-diag recovery failed ({algo}): " \
            f"finite={finite}, spd events={len(spd)}"
        remedies = ",".join(sorted({e["remedy"] for e in spd}))
        emit(f"faults/recover_indefinite_{algo}", t * 1e6,
             f"recovered=1;spd_events={len(spd)};remedies={remedies};"
             f"total_events={len(h['events'])}")

    # -- recovery: rank spike under a hard cap -> eps-loosen/densify ---------
    # 1-D covariance (rank-1 off-diagonal tiles) so the spiked tile is the
    # only thing near the cap; fixed size -- the recipe is calibrated.
    _, K1 = covariance_problem(256, 1, 32)
    A1 = from_dense(jnp.asarray(K1), 32, 32, 1e-10)
    # scale calibrated so BOTH ladders engage: the left driver needs two
    # eps-loosening re-passes, the right driver's SVD-optimal rounding
    # accepts within the policy floor (a smaller spike never overflows the
    # right driver at all -- its truncation is already optimal).
    A1s = faults.spike_rank(A1, 4, 1, seed=3, scale=3e-4)
    for algo in ("left", "right"):
        t, fact = timeit(
            lambda: tlr_cholesky(A1s, CholOptions(eps=1e-6, bs=8,
                                                  r_max_out=16, algo=algo,
                                                  check=True)),
            repeats=1)
        h = fact.stats["health"]
        overflow = [e for e in h["events"] if e["kind"] == "rank_overflow"]
        finite = all(bool(np.isfinite(np.asarray(x)).all())
                     for x in (fact.L.D, fact.L.U, fact.L.V))
        assert finite and overflow, \
            f"rank-spike recovery failed ({algo}): finite={finite}, " \
            f"overflow events={len(overflow)}"
        remedies = ",".join(sorted({e["remedy"] for e in overflow}))
        emit(f"faults/recover_rankspike_{algo}", t * 1e6,
             f"recovered=1;overflow_events={len(overflow)};"
             f"remedies={remedies}")

    # -- unrecoverable fault -> structured breakdown, never NaN factors ------
    for algo in ("left", "right"):
        detected = 0
        t0 = time.perf_counter()
        with faults.inject(faults.Fault(site="chol.diag", kind="nan",
                                        column=nb // 2)):
            try:
                tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, algo=algo,
                                            check=True))
            except FactorizationBreakdown as e:
                detected = 1
                col = e.report.column
        t = time.perf_counter() - t0
        assert detected, f"NaN diag not detected ({algo})"
        emit(f"faults/breakdown_detect_{algo}", t * 1e6,
             f"detected=1;column={col};injected_at={nb // 2}")

    # -- serve-side degradation guards ---------------------------------------
    ns, bsrv = scaled(1024), 64
    Ks, ops = _build(ns, 3, bsrv)
    fact = ops.cholesky(CholOptions(eps=1e-6, bs=8))
    srv = fact.serve(operator=ops, slots=4, check_every=4)
    rng = np.random.default_rng(0)
    bad = rng.standard_normal(ns)
    bad[7] = np.nan
    t0 = time.perf_counter()
    try:
        srv.submit(ServeRequest("solve", rhs=bad))
        rejected = 0
    except RequestRejected:
        rejected = 1
    t_reject = time.perf_counter() - t0
    assert rejected, "non-finite RHS was admitted"
    r1 = ServeRequest("solve", rhs=rng.standard_normal(ns))
    r2 = ServeRequest("solve", rhs=rng.standard_normal(ns))
    i1, i2 = srv.submit(r1), srv.submit(r2)
    with faults.inject(faults.Fault(site="serve.solve", rid=i1)):
        results = srv.run()
    ok_iso = (not results[i1].ok
              and results[i1].error == "nonfinite_result"
              and results[i2].ok
              and bool(np.isfinite(results[i2].value).all()))
    assert ok_iso, "poisoned column leaked into the co-batched block"
    r3 = ServeRequest("solve", rhs=rng.standard_normal(ns),
                      deadline_ticks=2)
    r4 = ServeRequest("solve", rhs=rng.standard_normal(ns))
    i3, i4 = srv.submit(r3), srv.submit(r4)
    with faults.inject(faults.Fault(site="serve.admit", rid=i3, delay=6)):
        results = srv.run(max_ticks=10)
    assert results[i3].error == "timeout" and results[i4].ok, \
        "deadline eviction failed or took down a healthy request"
    hs = srv.stats.summary()["health"]
    emit("faults/serve_guards", t_reject * 1e6,
         f"rejected={hs['rejected']};isolated={hs['errors']};"
         f"timeouts={hs['timeouts']};co_batched_ok=1")


ALL = [
    bench_tile_size, bench_memory_growth, bench_rank_distributions,
    bench_compress, bench_factor_time, bench_profile, bench_pcg,
    bench_trsm_old_vs_new, bench_solve_plans, bench_rank_vs_svd,
    bench_pivoting, bench_left_vs_right, bench_lookahead,
    bench_batching_modes, bench_column_buckets, bench_share_omega,
    bench_flop_rate,
    bench_algebra_round_axpy, bench_algebra_gemm, bench_newton_schulz,
    bench_batching, bench_serve, bench_faults,
]

SUITES = {
    "all": ALL,
    "build": [bench_compress, bench_memory_growth, bench_rank_distributions],
    "factor": [bench_tile_size, bench_factor_time, bench_profile,
               bench_pivoting, bench_left_vs_right, bench_lookahead,
               bench_batching_modes, bench_column_buckets,
               bench_share_omega, bench_flop_rate, bench_batching],
    "solve": [bench_trsm_old_vs_new, bench_solve_plans, bench_pcg],
    "algebra": [bench_algebra_round_axpy, bench_algebra_gemm,
                bench_newton_schulz],
    "batching": [bench_batching],
    "plans": [bench_solve_plans],
    "serve": [bench_serve],
    "faults": [bench_faults],
}


def main() -> None:
    import argparse

    from repro import obs

    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all", choices=sorted(SUITES))
    ap.add_argument("--json", default=None,
                    help="machine-readable output path "
                         "(default: BENCH_<suite>.json in the cwd)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also export the run's telemetry as Chrome-trace/"
                         "Perfetto JSON (load at ui.perfetto.dev)")
    args = ap.parse_args()
    # Every bench records under telemetry so the JSON carries the per-phase
    # FLOP/s snapshot (and compare.py can diff it); --trace additionally
    # keeps the full span timeline as a Perfetto file.
    obs.enable()
    for fn in SUITES[args.suite]:
        fn()
    obs.record_retraces()
    snapshot = obs.metrics_snapshot()
    if args.trace:
        obs.export_chrome_trace(args.trace)
        print(f"telemetry trace -> {args.trace}")
    obs.disable()
    write_json(args.json or f"BENCH_{args.suite}.json",
               meta={"suite": args.suite, "telemetry": snapshot})


if __name__ == "__main__":
    main()
