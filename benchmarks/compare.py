"""Regression diff of two ``BENCH_<suite>.json`` files (ISSUE 8 tooling).

``python -m benchmarks.compare baseline.json current.json`` compares the
machine-readable bench records (``benchmarks/common.py::write_json``) and
exits nonzero on a *hard* regression:

* a record present in the baseline but missing from the current run
  (coverage regression -- a bench silently stopped emitting);
* a ``padded_flop_ratio=...`` derived field rising by more than
  ``--ratio-tol`` (relative) -- the rank-bucketed dispatch layer started
  padding more work;
* an ``occupancy=...`` derived field dropping by more than ``--occ-tol``
  (absolute) -- the serve loop started idling slots;
* a *topology* mismatch (PR 9): every bench file is stamped with
  ``{device_count, backend, mesh, lookahead}`` by
  ``benchmarks/common.py::bench_topology``, and two files recorded on
  different topologies are never diffed silently --
  ``--allow-topology-mismatch`` downgrades the failure to a warning.

Wall-time changes (``us_per_call`` beyond ``--time-tol`` relative) only
*warn* by default: CI runners are too noisy for hard timing gates at
quick-lane scale (``--fail-on-time`` upgrades them for controlled
hardware). The thresholds are deliberately tolerant; the point is to
catch structural regressions (lost records, worse padding, idle slots),
not 5% timer jitter.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


class BenchFileError(Exception):
    """A bench JSON file that cannot be compared: missing, unparseable, or
    schema-drifted. The message names the file, the offending record/key,
    and (for baselines) the exact command that regenerates it."""


def _regen_hint(path: str) -> str:
    """The command that (re)produces ``path``, recovered from the
    ``BENCH_<suite>.json`` naming convention."""
    name = os.path.basename(path)
    if name.startswith("BENCH_") and name.endswith(".json"):
        suite = name[len("BENCH_"):-len(".json")]
        return (f"regenerate with: python -m benchmarks.bench_tlr "
                f"--suite {suite} --json {path}")
    return ("regenerate with: python -m benchmarks.bench_tlr "
            f"--suite <suite> --json {path}")


def parse_derived(derived: str) -> dict:
    """``k1=v1;k2=v2;...`` -> dict with floats where they parse."""
    out = {}
    for field in str(derived).split(";"):
        if "=" not in field:
            continue
        k, v = field.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def load_payload(path: str, role: str = "bench file") -> dict:
    """Read one bench JSON; every failure mode raises
    :class:`BenchFileError` with an actionable message (which file, what is
    wrong with it, how to regenerate it) instead of a bare traceback."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except FileNotFoundError:
        raise BenchFileError(
            f"{role} {path!r} does not exist; {_regen_hint(path)}") from None
    except json.JSONDecodeError as e:
        raise BenchFileError(
            f"{role} {path!r} is not valid JSON (line {e.lineno}, column "
            f"{e.colno}: {e.msg}); the file is truncated or corrupt -- "
            f"{_regen_hint(path)}") from None
    if not isinstance(payload, dict):
        raise BenchFileError(
            f"{role} {path!r} holds a JSON {type(payload).__name__}, not "
            f"the expected object with a 'records' list; {_regen_hint(path)}")
    validate_schema(payload, path, role)
    return payload


_RECORD_KEYS = ("name", "us_per_call", "derived")


def validate_schema(payload: dict, path: str, role: str = "bench file"):
    """Pin the record schema compare() depends on, so drift surfaces as
    'which file, which record, which key' instead of a KeyError deep in
    the diff loop."""
    records = payload.get("records")
    if records is None:
        raise BenchFileError(
            f"{role} {path!r} has no 'records' key (top-level keys: "
            f"{sorted(payload)}); this is not a benchmarks/common.py "
            f"bench file -- {_regen_hint(path)}")
    if not isinstance(records, list):
        raise BenchFileError(
            f"{role} {path!r}: 'records' is a "
            f"{type(records).__name__}, expected a list; {_regen_hint(path)}")
    for idx, rec in enumerate(records):
        if not isinstance(rec, dict):
            raise BenchFileError(
                f"{role} {path!r}: records[{idx}] is a "
                f"{type(rec).__name__}, expected an object; "
                f"{_regen_hint(path)}")
        missing = [k for k in _RECORD_KEYS if k not in rec]
        if missing:
            label = rec.get("name", f"records[{idx}]")
            raise BenchFileError(
                f"{role} {path!r}: record {label!r} is missing key(s) "
                f"{missing} (schema drift -- compare needs "
                f"{list(_RECORD_KEYS)}); {_regen_hint(path)}")


def load_records(path: str) -> dict:
    payload = load_payload(path)
    return {r["name"]: r for r in payload.get("records", [])}


def compare_topology(base_payload: dict, cur_payload: dict, *,
                     allow_mismatch: bool):
    """Never diff across topologies silently: a 1-device wall time against
    an 8-device one (or meshed vs un-meshed, lookahead on vs off) is not a
    regression signal. Returns ``(failures, warnings)``."""
    bt = base_payload.get("topology")
    ct = cur_payload.get("topology")
    if bt is None or ct is None:
        which = [n for n, t in (("baseline", bt), ("current", ct))
                 if t is None]
        return [], [f"no topology recorded in {' and '.join(which)} "
                    f"(pre-topology bench file); comparing anyway"]
    if bt == ct:
        return [], []
    diffs = [f"{k}: {bt.get(k)!r} -> {ct.get(k)!r}"
             for k in sorted(set(bt) | set(ct)) if bt.get(k) != ct.get(k)]
    msg = ("topology mismatch between baseline and current run ("
           + "; ".join(diffs) + ")")
    if allow_mismatch:
        return [], [msg + " -- compared anyway (--allow-topology-mismatch)"]
    return [msg + "; pass --allow-topology-mismatch to compare anyway"], []


def compare(base: dict, cur: dict, *, time_tol: float, ratio_tol: float,
            occ_tol: float, fail_on_time: bool):
    """Returns ``(failures, warnings)`` as lists of message strings."""
    failures, warnings = [], []
    for name in sorted(base):
        if name not in cur:
            failures.append(f"missing record: {name!r} (present in baseline)")
            continue
        b, c = base[name], cur[name]
        bd, cd = parse_derived(b["derived"]), parse_derived(c["derived"])

        bt, ct = float(b["us_per_call"]), float(c["us_per_call"])
        if bt > 0 and ct > bt * (1.0 + time_tol):
            msg = (f"{name}: us_per_call {bt:.1f} -> {ct:.1f} "
                   f"({ct / bt:.2f}x, tol {1.0 + time_tol:.2f}x)")
            (failures if fail_on_time else warnings).append(msg)

        for key in bd:
            if not key.endswith("padded_flop_ratio"):
                continue
            bv, cv = bd[key], cd.get(key)
            if not isinstance(bv, float) or not isinstance(cv, float):
                continue
            if bv > 0 and cv > bv * (1.0 + ratio_tol):
                failures.append(
                    f"{name}: {key} {bv:.3f} -> {cv:.3f} "
                    f"(+{(cv / bv - 1) * 100:.1f}%, tol {ratio_tol:.0%})")

        bv, cv = bd.get("occupancy"), cd.get("occupancy")
        if isinstance(bv, float) and isinstance(cv, float) \
                and cv < bv - occ_tol:
            failures.append(f"{name}: occupancy {bv:.3f} -> {cv:.3f} "
                            f"(-{bv - cv:.3f}, tol {occ_tol:.3f})")
    for name in sorted(set(cur) - set(base)):
        warnings.append(f"new record (not in baseline): {name!r}")
    return failures, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_<suite>.json files; exit 1 on "
                    "regression")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("current", help="freshly produced JSON")
    ap.add_argument("--time-tol", type=float, default=1.0,
                    help="relative wall-time growth tolerated before a "
                         "warning (1.0 = 2x; default %(default)s)")
    ap.add_argument("--ratio-tol", type=float, default=0.10,
                    help="relative padded_flop_ratio growth tolerated "
                         "before a failure (default %(default)s)")
    ap.add_argument("--occ-tol", type=float, default=0.05,
                    help="absolute occupancy drop tolerated before a "
                         "failure (default %(default)s)")
    ap.add_argument("--fail-on-time", action="store_true",
                    help="treat wall-time warnings as failures (controlled "
                         "hardware only)")
    ap.add_argument("--allow-topology-mismatch", action="store_true",
                    help="compare even when the two files were recorded on "
                         "different device topologies (downgrades the "
                         "hard failure to a warning)")
    args = ap.parse_args(argv)

    try:
        base_payload = load_payload(args.baseline, role="baseline")
        cur_payload = load_payload(args.current, role="current run")
    except BenchFileError as e:
        print(f"ERROR {e}")
        return 2
    base = {r["name"]: r for r in base_payload.get("records", [])}
    cur = {r["name"]: r for r in cur_payload.get("records", [])}
    failures, warnings = compare_topology(
        base_payload, cur_payload,
        allow_mismatch=args.allow_topology_mismatch)
    f2, w2 = compare(
        base, cur, time_tol=args.time_tol, ratio_tol=args.ratio_tol,
        occ_tol=args.occ_tol, fail_on_time=args.fail_on_time)
    failures += f2
    warnings += w2

    for w in warnings:
        print(f"WARN  {w}")
    for f in failures:
        print(f"FAIL  {f}")
    n = len(base)
    print(f"compared {n} baseline records against {args.current}: "
          f"{len(failures)} failure(s), {len(warnings)} warning(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
