"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. ``BENCH_SCALE`` scales the
problem sizes (default 1.0; the paper's N=2^17 sizes are infeasible on one
CPU core, the asymptotic claims are validated at N up to ~4k).

Usage: PYTHONPATH=src python -m benchmarks.run [--only substring]
(Phase-level suites: PYTHONPATH=src python -m benchmarks.bench_tlr
 --suite {all,build,factor,solve}.)
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from . import bench_tlr, bench_kernels

    benches = list(bench_tlr.ALL) + list(bench_kernels.ALL)
    failures = 0
    t0 = time.time()
    for fn in benches:
        name = fn.__name__
        if args.only and args.only not in name:
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,FAILED:{type(e).__name__}:{e}")
            traceback.print_exc()
    print(f"# total {time.time()-t0:.1f}s, failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
