"""Shared benchmark utilities: timing, problem construction, FLOP model."""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

# TLR validation benches run in f64 like the paper.
jax.config.update("jax_enable_x64", True)

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))


def scaled(n: int) -> int:
    return max(256, int(n * SCALE))


def timeit(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall time in seconds; blocks on jax outputs."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(out)) if jax.tree.leaves(
            [x for x in jax.tree.leaves(out)
             if isinstance(x, jax.Array)]) else None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        leaves = [x for x in jax.tree.leaves(out) if isinstance(x, jax.Array)]
        if leaves:
            jax.block_until_ready(leaves)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


RECORDS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness CSV contract: name,us_per_call,derived. Every record is
    also retained for the machine-readable JSON dump (``write_json``)."""
    print(f"{name},{us_per_call:.1f},{derived}")
    RECORDS.append({"name": name, "us_per_call": float(us_per_call),
                    "derived": str(derived)})


def reset_records() -> None:
    RECORDS.clear()


def bench_topology() -> dict:
    """The execution topology stamped into every ``BENCH_<suite>.json``:
    device count, backend, the active tile mesh (if any), and the default
    lookahead setting. ``benchmarks/compare.py`` refuses to diff two bench
    files recorded on different topologies unless told to -- a 1-device
    number against an 8-device number is not a regression signal."""
    from repro.core import CholOptions, tile_mesh

    mesh = tile_mesh()
    return {
        "device_count": jax.device_count(),
        "backend": jax.default_backend(),
        "mesh": None if mesh is None else {
            "shape": list(mesh.devices.shape),
            "axes": list(mesh.axis_names),
        },
        "lookahead": bool(CholOptions().lookahead),
    }


def write_json(path: str, meta: dict | None = None) -> None:
    """Dump all emitted records as JSON (the CI artifact contract:
    ``BENCH_<suite>.json`` with wall times plus any derived metrics such as
    the cost_analysis padded-vs-useful FLOP ratio, stamped with the
    execution topology)."""
    import json

    payload = {"bench_scale": SCALE, "topology": bench_topology(),
               "records": list(RECORDS)}
    if meta:
        payload.update(meta)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path} ({len(RECORDS)} records)")


# -- analytic FLOP model for the factorization phases -------------------------


def factorization_flop_model(nb: int, b: int, r: int, bs: int,
                             stats: dict, share_omega: bool = True) -> dict:
    """Per-phase padded-arithmetic FLOPs from the recorded column stats.

    Phases (paper Fig. 8a): sampling GEMMs, projection GEMMs, orthog (QR),
    trsm, dense diagonal updates + Cholesky, reductions/misc.
    """
    f = {"sample": 0.0, "project": 0.0, "orthog": 0.0, "trsm": 0.0,
         "dense_diag": 0.0, "chol": 0.0}
    iters = stats["column_iters"]
    for k in range(1, nb):
        T = nb - k                       # tiles below the diagonal
        it = iters[k - 1] if k - 1 < len(iters) else 1
        # sampling: shared W2 hoist: per iter 2 GEMMs over j=(k) tiles for
        # the column + per (tile, j) 2 GEMMs; A-tile sample 2 GEMMs
        per_iter = 2 * (2 * b * r * bs) * k if share_omega else 0
        per_iter += T * k * 2 * (2 * b * r * bs) * (1 if share_omega else 2)
        per_iter += T * 2 * (2 * b * r * bs)
        f["sample"] += it * per_iter
        # orthog: GS projections vs Q (b x r) + QR of (b, bs)
        f["orthog"] += it * T * (2 * 2 * b * r * bs + 2 * b * bs * bs)
        # projection B = expr^T Q: same chain with s=r
        f["project"] += T * k * 4 * (2 * b * r * r) / (2 if share_omega else 1)
        f["project"] += T * 2 * (2 * b * r * r)
        # trsm: triangular solve of (b x b) against r rhs
        f["trsm"] += T * b * b * r
        # dense diagonal update: k low-rank products to (b, b)
        f["dense_diag"] += k * (2 * b * r * r + 2 * b * b * r)
        f["chol"] += b ** 3 / 3
    f["chol"] += b ** 3 / 3  # first diagonal
    total = sum(f.values())
    gemm = f["sample"] + f["project"] + f["dense_diag"] + f["trsm"]
    return {"phases": f, "total": total, "gemm_fraction": gemm / total}
