"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck  # noqa: E402

from repro.core import (
    ARAParams, CholOptions, ara_compress_dense, exp_covariance, from_dense,
    kd_tree_ordering, tlr_cholesky, tlr_matvec,
    tlr_to_dense, tlr_tri_matvec, tlr_trsv, tril_pairs, num_tiles,
)
from repro.data import DataConfig, SyntheticTokens

SET = dict(deadline=None, max_examples=8,
           suppress_health_check=[HealthCheck.too_slow,
                                  HealthCheck.data_too_large])


@settings(**SET)
@given(seed=st.integers(0, 10_000), nb=st.sampled_from([3, 4, 6]),
       b=st.sampled_from([16, 32]))
def test_from_dense_roundtrip_bound(seed, nb, b):
    """to_dense(from_dense(A)) stays within the truncation threshold."""
    rng = np.random.default_rng(seed)
    n = nb * b
    M = rng.standard_normal((n, n)) / np.sqrt(n)
    A = M @ M.T + np.eye(n)
    eps = 1e-8
    T = from_dense(jnp.asarray(A), b, b, eps)
    err = np.linalg.norm(np.asarray(T.to_dense()) - A, 2)
    assert err < 10 * eps * n


@settings(**SET)
@given(seed=st.integers(0, 10_000), nb=st.sampled_from([3, 5]),
       b=st.sampled_from([16, 32]), nrhs=st.sampled_from([1, 3]))
def test_matvec_matches_dense(seed, nb, b, nrhs):
    rng = np.random.default_rng(seed)
    n = nb * b
    M = rng.standard_normal((n, n)) / np.sqrt(n)
    A = M @ M.T + np.eye(n)
    T = from_dense(jnp.asarray(A), b, b, 1e-12)
    x = rng.standard_normal((n, nrhs)) if nrhs > 1 else rng.standard_normal(n)
    got = np.asarray(tlr_matvec(T, jnp.asarray(x)))
    want = np.asarray(T.to_dense()) @ x
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@settings(**SET)
@given(seed=st.integers(0, 10_000),
       ell=st.floats(0.05, 0.5),
       d=st.sampled_from([2, 3]))
def test_spd_kernel_matrices_factor_within_eps(seed, ell, d):
    """Any exponential-kernel covariance factors to <= c*eps error."""
    rng = np.random.default_rng(seed)
    n, b = 128, 32
    pts = rng.random((n, d))
    pts = pts[kd_tree_ordering(pts, b)]
    K = exp_covariance(pts, ell)
    A = from_dense(jnp.asarray(K), b, b, 1e-10)
    eps = 1e-6
    fact = tlr_cholesky(A, CholOptions(eps=eps, bs=8))
    Ld = np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                         A.nb, b)))
    err = np.linalg.norm(K - Ld @ Ld.T, 2)
    assert err < 1e3 * eps, err


@settings(**SET)
@given(seed=st.integers(0, 10_000))
def test_trsv_inverts_tri_matvec(seed):
    rng = np.random.default_rng(seed)
    n, b = 128, 32
    pts = rng.random((n, 3))
    K = exp_covariance(pts[kd_tree_ordering(pts, b)], 0.3)
    A = from_dense(jnp.asarray(K), b, b, 1e-10)
    fact = tlr_cholesky(A, CholOptions(eps=1e-8, bs=8))
    x = jnp.asarray(rng.standard_normal(n))
    for trans in (False, True):
        y = tlr_tri_matvec(fact.L, x, trans=trans)
        x2 = tlr_trsv(fact.L, y, trans=trans)
        np.testing.assert_allclose(np.asarray(x2), np.asarray(x),
                                   rtol=1e-7, atol=1e-7)


@settings(**SET)
@given(seed=st.integers(0, 10_000), true_rank=st.integers(1, 24),
       bs=st.sampled_from([4, 8]))
def test_ara_error_bound_and_rank(seed, true_rank, bs):
    """ARA reaches eps accuracy without wildly overshooting the true rank."""
    rng = np.random.default_rng(seed)
    b = 64
    u = rng.standard_normal((b, true_rank))
    v = rng.standard_normal((b, true_rank))
    Am = jnp.asarray((u @ v.T) / true_rank)[None]
    p = ARAParams(bs=bs, r_max=64, eps=1e-8)
    Q, B, ranks, _ = ara_compress_dense(Am, jax.random.PRNGKey(seed), p)
    approx = np.asarray(Q[0]) @ np.asarray(B[0]).T
    assert np.linalg.norm(np.asarray(Am[0]) - approx, 2) < 1e-5
    assert int(ranks[0]) <= min(true_rank + 2 * bs, 64)


@settings(**SET)
@given(n=st.integers(10, 500), tile=st.sampled_from([16, 64]),
       seed=st.integers(0, 1000), d=st.sampled_from([2, 3]))
def test_kd_ordering_is_permutation(n, tile, seed, d):
    pts = np.random.default_rng(seed).random((n, d))
    perm = kd_tree_ordering(pts, tile)
    assert sorted(perm.tolist()) == list(range(n))


@settings(**SET)
@given(nb=st.integers(2, 10))
def test_tril_pairs_bijective(nb):
    pairs = tril_pairs(nb)
    assert len(pairs) == num_tiles(nb)
    assert len({(int(i), int(j)) for i, j in pairs}) == len(pairs)


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), step=st.integers(0, 10_000),
       hosts=st.sampled_from([1, 2, 4]))
def test_data_pipeline_invariants(seed, step, hosts):
    cfg = DataConfig(vocab_size=512, batch=8, seq_len=32, seed=seed)
    ds = SyntheticTokens(cfg)
    shards = [ds.batch_at(step, host_index=h, host_count=hosts)
              for h in range(hosts)]
    for s in shards:
        assert s["tokens"].shape == (8 // hosts, 32)
        assert s["tokens"].min() >= 0
        assert s["tokens"].max() < 512
        np.testing.assert_array_equal(s["tokens"][:, 1:], s["labels"][:, :-1])
    again = ds.batch_at(step, host_index=0, host_count=hosts)
    np.testing.assert_array_equal(shards[0]["tokens"], again["tokens"])


@settings(**SET)
@given(seed=st.integers(0, 10_000))
def test_factor_solve_residual(seed):
    """||A x - y|| / ||y|| small for the factored solve, any SPD kernel."""
    rng = np.random.default_rng(seed)
    n, b = 96, 32
    pts = rng.random((n, 2))
    K = exp_covariance(pts[kd_tree_ordering(pts, b)], 0.2, nugget=1e-6)
    A = from_dense(jnp.asarray(K), b, b, 1e-12)
    fact = tlr_cholesky(A, CholOptions(eps=1e-9, bs=8))
    y = jnp.asarray(rng.standard_normal(n))
    x = fact.solve(y)
    resid = np.linalg.norm(K @ np.asarray(x) - np.asarray(y))
    assert resid / np.linalg.norm(np.asarray(y)) < 1e-5
