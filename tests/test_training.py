"""Trainer / checkpoint / optimizer / server integration tests."""

import dataclasses
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokens
from repro.models import init_model
from repro.optim import (AdamWConfig, CompressConfig, TLRNewtonConfig,
                         adamw_init, adamw_update, compress_grads,
                         compress_init, tlr_newton_init, tlr_newton_update)
from repro.train import DecodeServer, Request, TrainConfig, Trainer


# -- data pipeline -----------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, batch=4, seq_len=32, seed=7)
    ds = SyntheticTokens(cfg)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(
        ds.batch_at(0)["tokens"][:, 1:], ds.batch_at(0)["labels"][:, :-1])


def test_data_host_sharding():
    cfg = DataConfig(vocab_size=1000, batch=8, seq_len=16, seed=1)
    ds = SyntheticTokens(cfg)
    h0 = ds.batch_at(3, host_index=0, host_count=2)
    h1 = ds.batch_at(3, host_index=1, host_count=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


# -- checkpointing -----------------------------------------------------------


def test_checkpoint_roundtrip_and_keep(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": [jnp.ones((2,)), jnp.asarray(3)],
            "c": {"d": jnp.zeros((5,), jnp.bfloat16)}}
    for step in (1, 2, 3, 4):
        save_checkpoint(tmp_path, step, tree, keep=2, meta={"s": step})
    ckpts = sorted(p.name for p in tmp_path.glob("step_*"))
    assert ckpts == ["step_00000003", "step_00000004"]
    latest = latest_checkpoint(tmp_path)
    step, restored, meta = restore_checkpoint(latest, tree)
    assert step == 4 and meta["s"] == 4
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_checkpoint_atomicity(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    save_checkpoint(tmp_path, 1, tree)
    # a stale tmp dir from a crashed writer must not break anything
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "junk.npy").write_bytes(b"garbage")
    assert latest_checkpoint(tmp_path).name == "step_00000001"
    save_checkpoint(tmp_path, 2, tree)
    assert latest_checkpoint(tmp_path).name == "step_00000002"


def test_checkpoint_elastic_dtype_cast(tmp_path):
    """Restore casts dtypes to the receiving tree (e.g. new mixed-precision
    policy after an elastic restart)."""
    save_checkpoint(tmp_path, 1, {"w": jnp.ones((4,), jnp.float32)})
    _, restored, _ = restore_checkpoint(
        latest_checkpoint(tmp_path), {"w": jnp.zeros((4,), jnp.bfloat16)})
    assert restored["w"].dtype == jnp.bfloat16


# -- trainer: end-to-end, resume, preemption ----------------------------------


def _tiny_trainer(tmp_path, steps, metrics="m.jsonl"):
    cfg = get_config("qwen1_5_0_5b", smoke=True)
    tcfg = TrainConfig(steps=steps, batch=4, seq_len=64,
                       ckpt_dir=str(tmp_path / "ck"), save_every=10,
                       log_every=5, metrics_path=str(tmp_path / metrics))
    return Trainer(cfg, tcfg)


def test_trainer_loss_decreases(tmp_path):
    out = _tiny_trainer(tmp_path, steps=30).run()
    assert out["status"] == "done"
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses


@pytest.mark.slow
def test_trainer_resume(tmp_path):
    _tiny_trainer(tmp_path, steps=10).run()
    t2 = _tiny_trainer(tmp_path, steps=20)
    out = t2.run()
    assert out["status"] == "done"
    # resumed run only executed the remaining steps
    assert len(out["losses"]) == 10
    metrics = [json.loads(l) for l in
               (tmp_path / "m.jsonl").read_text().splitlines()]
    assert any(m["event"] == "resumed" and m["step"] == 10 for m in metrics)


def test_trainer_preemption_checkpoint(tmp_path):
    t = _tiny_trainer(tmp_path, steps=50)
    orig_check = t._straggler_check

    def preempt_at_7(step, dt):
        orig_check(step, dt)
        if step == 7:
            t._preempted = True   # what the SIGTERM handler sets

    t._straggler_check = preempt_at_7
    out = t.run()
    assert out["status"] == "preempted"
    assert out["step"] == 8
    assert latest_checkpoint(tmp_path / "ck").name == "step_00000008"


# -- gradient compression -------------------------------------------------------


def test_compress_error_feedback_converges():
    """Rank-2 compressed GD with error feedback still solves least squares."""
    rng = np.random.default_rng(0)
    W_true = rng.standard_normal((64, 64))
    X = rng.standard_normal((256, 64))
    Y = X @ W_true
    W = jnp.zeros((64, 64))
    ccfg = CompressConfig(rank=2, min_size=16)
    cstate = compress_init({"w": W}, ccfg)
    key = jax.random.PRNGKey(0)
    lr = 0.02
    losses = []
    for it in range(400):
        G = {"w": jnp.asarray(2 * X.T @ (np.asarray(X @ W) - Y) / 256)}
        G, cstate, stats = compress_grads(G, cstate, ccfg,
                                          jax.random.fold_in(key, it))
        W = W - lr * G["w"]
        losses.append(float(np.mean((np.asarray(X @ W) - Y) ** 2)))
    assert stats["ratio"] > 5
    assert losses[-1] < 0.05 * losses[0], losses[::60]


def test_compress_small_leaves_passthrough():
    ccfg = CompressConfig(rank=4, min_size=10_000)
    g = {"small": jnp.ones((8, 8)), "vec": jnp.ones((32,))}
    st = compress_init(g, ccfg)
    out, _, stats = compress_grads(g, st, ccfg, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out["small"]),
                                  np.asarray(g["small"]))
    assert stats["ratio"] == 1.0


# -- TLR-Newton -----------------------------------------------------------------


@pytest.mark.slow
def test_tlr_newton_least_squares():
    """TLR-KFAC solves an ill-conditioned LS problem far faster than AdamW.

    Loss = ||X W - Y||^2 / B with ill-conditioned input covariance; K-FAC's
    activation factor A = X^T X / B is the exact Gauss-Newton curvature, so
    the TLR-factored preconditioner should beat Adam decisively.
    """
    rng = np.random.default_rng(1)
    n = 128
    U, _ = np.linalg.qr(rng.standard_normal((n, n)))
    cov = (U * np.geomspace(1, 1e-2, n)) @ U.T     # ill-conditioned inputs
    X = rng.standard_normal((512, n)) @ cov
    W_true = rng.standard_normal((n, n))
    Y = X @ W_true

    def loss_and_grad(W):
        # model: y = W x  (weight m x n applied to inputs x) => G = 2 R^T X/B
        R = X @ np.asarray(W).T - Y
        return float(np.mean(R * R)), jnp.asarray(2 * R.T @ X / 512)

    ncfg = TLRNewtonConfig(min_dim=64, tile=32, refresh_every=5, beta=0.0,
                           grafting=AdamWConfig(lr=3e-2, weight_decay=0.0))
    params = {"w": jnp.zeros((n, n))}
    nstate = tlr_newton_init(params, ncfg)
    astate = adamw_init(params, ncfg.grafting)
    aw = {"w": jnp.zeros((n, n))}
    newton_losses, adam_losses = [], []
    for it in range(30):
        l_n, g_n = loss_and_grad(params["w"])
        newton_losses.append(l_n)
        params, nstate = tlr_newton_update(
            {"w": g_n}, nstate, params, ncfg,
            curvature={"w": (X, None)})   # activation-side factor only
        l_a, g_a = loss_and_grad(aw["w"])
        adam_losses.append(l_a)
        aw, astate = adamw_update({"w": g_a}, astate, aw, ncfg.grafting)
    assert newton_losses[-1] < adam_losses[-1], (
        newton_losses[-5:], adam_losses[-5:])
    assert newton_losses[-1] < 0.2 * newton_losses[0], newton_losses[::6]


# -- decode server ----------------------------------------------------------------


def test_decode_server_continuous_batching():
    cfg = get_config("qwen1_5_0_5b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    srv = DecodeServer(cfg, params, slots=2, max_len=64)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4, rid=i)
            for i in range(5)]
    done = srv.run(reqs)
    assert len(done) == 5
    assert sorted(c.rid for c in done) == [0, 1, 2, 3, 4]
    for c in done:
        assert len(c.tokens) == 4
        assert all(0 <= t < cfg.vocab_size for t in c.tokens)


def test_decode_server_greedy_deterministic():
    cfg = get_config("qwen1_5_0_5b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    out1 = DecodeServer(cfg, params, slots=1, max_len=32).run(
        [Request(prompt=[5, 6], max_new_tokens=6, rid=0)])
    out2 = DecodeServer(cfg, params, slots=1, max_len=32).run(
        [Request(prompt=[5, 6], max_new_tokens=6, rid=0)])
    assert out1[0].tokens == out2[0].tokens
