"""Rank-bucketed dynamic batching (core/batching.py, DESIGN.md section 8).

Pins the tentpole contracts of the ``batching="ranked"`` dispatch layer:

* bucketed-vs-flat parity: ``tlr_round``, ``tlr_gemm``, ``tlr_syrk`` and
  both Cholesky drivers produce the same result (same truncation
  semantics; exact up to floating-point reduction order),
* the compile-count contract: ``batching_trace_count()`` stays at
  O(log2(r_max) * log2(nt)) bucket-core variants -- never one per rank
  distribution or per tile -- and a repeat call at the same shapes
  compiles nothing,
* rank-0 buckets skip the kernels entirely (no QR/SVD, no phantom rank-1
  regrowth -- the PR 4 rank-floor semantics extended to the bucketed
  path),
* the tile-mesh sharding hook is numerics-neutral with a single-device
  mesh and the no-mesh fallback is the identity.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, TLROperator, batching_trace_count, bucket_width,
    exp_covariance, kd_tree_ordering, plan_rank_buckets, rank_ladder,
    set_tile_mesh, tile_mesh, tlr_axpy, tlr_gemm, tlr_round, tlr_round_tiles,
    tlr_syrk, tlr_to_dense,
)


def _cov_operator(seed, nb, b, eps=1e-5, ell=0.1):
    """Covariance operator with a *heterogeneous* rank distribution (short
    correlation length + loose threshold spreads ranks well below b)."""
    rng = np.random.default_rng(seed)
    n = nb * b
    pts = rng.random((n, 3))
    K = exp_covariance(pts[kd_tree_ordering(pts, b)], ell)
    return np.asarray(K), TLROperator.compress(jnp.asarray(K), b, b, eps)


def _block_diag_op(nb=4, b=32, seed=0):
    rng = np.random.default_rng(seed)
    n = nb * b
    K = np.zeros((n, n))
    for s in range(0, n, b):
        M = rng.standard_normal((b, b))
        K[s:s + b, s:s + b] = M @ M.T + b * np.eye(b)
    return K, TLROperator.compress(jnp.asarray(K), b, b, 1e-10)


def _factor_error(K, fact):
    Ld = np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                         fact.L.nb, fact.L.b)))
    if fact.d is not None:
        R = Ld @ np.diag(np.asarray(fact.d).reshape(-1)) @ Ld.T
    else:
        R = Ld @ Ld.T
    return np.linalg.norm(K - R, 2)


# -- planning units ------------------------------------------------------------


def test_rank_ladder_and_bucket_width():
    assert rank_ladder(8) == [1, 2, 4, 8]
    assert rank_ladder(12) == [1, 2, 4, 8, 12]
    assert bucket_width([3, 9], 64) == 16
    assert bucket_width([64], 64) == 64
    assert bucket_width([0, 0], 64) == 1    # floor: no 0-width batches
    assert bucket_width(np.zeros((0,)), 64) == 1
    assert bucket_width([5], 0) == 0


def test_plan_rank_buckets_groups_and_zero_bucket():
    ranks = np.asarray([0, 1, 2, 3, 4, 5, 8, 9, 0])
    plan = plan_rank_buckets(ranks, 16)
    widths = {bk.width: sorted(bk.idx.tolist()) for bk in plan.buckets}
    assert widths == {1: [1], 2: [2], 4: [3, 4], 8: [5, 6], 16: [7]}
    assert sorted(plan.zero_idx.tolist()) == [0, 8]
    assert plan.zero_count == 2
    # every tile lands in exactly one group
    covered = sorted(sum((bk.idx.tolist() for bk in plan.buckets),
                         plan.zero_idx.tolist()))
    assert covered == list(range(len(ranks)))
    # count padding rides the count ladder
    for bk in plan.buckets:
        assert bk.padded >= bk.count


def test_resolve_batching_validated():
    _, op = _block_diag_op()
    with pytest.raises(ValueError, match="batching"):
        op.cholesky(CholOptions(batching="bucketed"))
    with pytest.raises(ValueError, match="batching"):
        tlr_round(op.A, 1e-8, batching="bogus")


# -- bucketed-vs-flat parity ---------------------------------------------------


def test_round_ranked_matches_flat():
    _, op = _cov_operator(0, 6, 32)
    ranks = np.asarray(op.ranks)
    assert ranks.min() < ranks.max()  # heterogeneous, else the test is void
    Rf = tlr_round(op.A, 1e-6)
    Rr = tlr_round(op.A, 1e-6, batching="ranked")
    np.testing.assert_array_equal(np.asarray(Rf.ranks), np.asarray(Rr.ranks))
    np.testing.assert_allclose(np.asarray(Rr.to_dense()),
                               np.asarray(Rf.to_dense()), rtol=1e-12,
                               atol=1e-12)


def test_round_ranked_wide_concat_densify_bucket():
    """Accumulated concatenations (axpy width convention) whose per-tile
    width exceeds b must route through the densify bucket and still agree
    with the flat pass."""
    _, op = _cov_operator(1, 4, 32)
    S = tlr_axpy(1.0, op.A, tlr_axpy(1.0, op.A, op.A))  # widths up to 3b
    assert S.r_max > op.b
    Rf = tlr_round(S, 1e-8)
    Rr = tlr_round(S, 1e-8, batching="ranked")
    np.testing.assert_allclose(np.asarray(Rr.to_dense()),
                               np.asarray(Rf.to_dense()), rtol=1e-9,
                               atol=1e-9)


def test_round_tiles_ranked_needs_ranks():
    _, op = _cov_operator(2, 3, 16)
    with pytest.raises(ValueError, match="ranks"):
        tlr_round_tiles(op.A.U, op.A.V, 1e-8, batching="ranked")
    Uf, Vf, rf, ef = tlr_round_tiles(op.A.U, op.A.V, 1e-8)
    Ur, Vr, rr, er = tlr_round_tiles(op.A.U, op.A.V, 1e-8, ranks=op.A.ranks,
                                     batching="ranked")
    np.testing.assert_array_equal(np.asarray(rf), np.asarray(rr))
    np.testing.assert_allclose(np.asarray(ef), np.asarray(er), rtol=1e-12,
                               atol=1e-14)


def test_gemm_and_syrk_ranked_match_flat():
    _, opA = _cov_operator(3, 5, 32)
    _, opB = _cov_operator(4, 5, 32)
    Cf = tlr_gemm(opA.A, opB.A, 1e-8)
    Cr = tlr_gemm(opA.A, opB.A, 1e-8, batching="ranked")
    np.testing.assert_allclose(np.asarray(Cr.to_dense()),
                               np.asarray(Cf.to_dense()), rtol=1e-11,
                               atol=1e-11)
    fact = opB.cholesky(CholOptions(eps=1e-8, algo="right"))
    Sf = tlr_syrk(opA.A, fact.L, 1e-10)
    Sr = tlr_syrk(opA.A, fact.L, 1e-10, batching="ranked")
    np.testing.assert_allclose(np.asarray(Sr.to_dense()),
                               np.asarray(Sf.to_dense()), rtol=1e-10,
                               atol=1e-10)


@pytest.mark.parametrize("ldl", [False, True])
def test_right_driver_ranked_matches_flat(ldl):
    K, op = _cov_operator(5, 8, 32)
    make = op.ldlt if ldl else op.cholesky
    ff = make(CholOptions(eps=1e-6, algo="right"))
    fr = make(CholOptions(eps=1e-6, algo="right", batching="ranked"))
    assert fr.stats["batching"] == "ranked"
    ef, er = _factor_error(K, ff), _factor_error(K, fr)
    assert ef < 1e-4 and er < 1e-4
    assert er < 100 * max(ef, 1e-8)
    # both factorizations solve to the same answer
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(op.n)
    y = jnp.asarray(K @ x_true)
    xf, xr = np.asarray(ff.solve(y)), np.asarray(fr.solve(y))
    nrm = np.linalg.norm(x_true)
    assert np.linalg.norm(xf - x_true) / nrm < 1e-3
    assert np.linalg.norm(xr - x_true) / nrm < 1e-3
    # ranked appends run at the bucketed panel rank, never above r_max
    assert all(1 <= w <= op.r_max for w in fr.stats["append_widths"])


@pytest.mark.parametrize("mode", ["dynamic", "fused"])
def test_left_driver_ranked_matches_flat(mode):
    K, op = _cov_operator(6, 8, 32)
    ff = op.cholesky(CholOptions(eps=1e-6, bs=8, mode=mode))
    fr = op.cholesky(CholOptions(eps=1e-6, bs=8, mode=mode,
                                 batching="ranked"))
    ef, er = _factor_error(K, ff), _factor_error(K, fr)
    assert ef < 1e-4 and er < 1e-4
    # same sampling keys, exact (zero-column) slicing: the ranked run sees
    # the same operator samples, so the factors agree to rounding noise
    Lf = np.tril(np.asarray(tlr_to_dense(ff.L.D, ff.L.U, ff.L.V, 8, 32)))
    Lr = np.tril(np.asarray(tlr_to_dense(fr.L.D, fr.L.U, fr.L.V, 8, 32)))
    np.testing.assert_allclose(Lr, Lf, rtol=1e-7, atol=1e-7)
    # the ranked projection widths ride the rank ladder
    for ev in fr.stats["column_events"]:
        assert ev["wQ"] in rank_ladder(op.r_max)


# -- compile-count contract ----------------------------------------------------


def test_batching_trace_count_pinned():
    """Bucket cores compile O(log) variants per shape family, reuse across
    rank distributions sharing the ladder, and never retrace at steady
    state."""
    _, op = _cov_operator(7, 6, 16)
    tlr_round(op.A, 1e-6, batching="ranked")  # warm the family
    t0 = batching_trace_count()
    tlr_round(op.A, 1e-6, batching="ranked")
    tlr_round(op.A, 1e-4, batching="ranked")  # new eps: still no retrace
    assert batching_trace_count() == t0
    # a bigger grid of the same tile shape adds at most a ladder of count
    # variants (never one executable per tile)
    _, big = _cov_operator(8, 12, 16)
    t0 = batching_trace_count()
    tlr_round(big.A, 1e-6, batching="ranked")
    nt = big.A.U.shape[0]
    bound = (int(math.log2(big.r_max)) + 1) + (int(math.log2(nt)) + 1)
    assert batching_trace_count() - t0 <= bound
    t0 = batching_trace_count()
    tlr_round(big.A, 1e-6, batching="ranked")
    assert batching_trace_count() == t0


def test_right_ranked_compile_count_steady_state():
    """A repeat ranked factorization at the same shapes compiles no new
    bucket cores (process-wide cache), and the per-run TRSM variants stay
    ladder-bounded like every other column step."""
    _, op = _cov_operator(9, 8, 16)
    opts = CholOptions(eps=1e-6, algo="right", batching="ranked")
    op.cholesky(opts)
    t0 = batching_trace_count()
    fact = op.cholesky(opts)
    assert batching_trace_count() == t0
    assert fact.stats["column_traces"] <= int(math.log2(op.nb)) + 1


# -- rank-0 bucket skips the kernels (PR 4 rank-floor, bucketed) ---------------


def test_zero_rank_bucket_skips_kernels_and_keeps_floor():
    K, op = _block_diag_op()
    assert int(np.asarray(op.ranks).max()) == 0
    t0 = batching_trace_count()
    R = tlr_round(op.A, 1e-10, batching="ranked")
    # all tiles sit in the zero bucket: no bucket core compiles, no QR/SVD
    assert batching_trace_count() == t0
    assert int(np.asarray(R.ranks).max()) == 0
    np.testing.assert_allclose(np.asarray(R.to_dense()), K, rtol=0,
                               atol=1e-12)


def test_right_ranked_block_diagonal_no_phantom_ranks():
    """The ranked right-looking driver on a block-diagonal matrix: every
    panel is rank 0, so the trailing update is skipped outright and no
    off-diagonal rank is ever resurrected."""
    K, op = _block_diag_op()
    fact = op.cholesky(CholOptions(eps=1e-8, algo="right",
                                   batching="ranked"))
    assert int(np.asarray(fact.L.ranks).max()) == 0
    assert float(jnp.abs(fact.L.U).max()) == 0.0
    # rank-0 panels skip the trailing update entirely: nothing appended,
    # nothing accumulated, so no flush can ever trigger
    assert fact.stats["append_widths"] == [0] * (op.nb - 1)
    assert fact.stats["flushes"] == 0
    assert _factor_error(K, fact) < 1e-10 * np.linalg.norm(K, 2)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(op.n))
    y = np.asarray(fact.solve(jnp.asarray(K @ np.asarray(x))))
    assert np.linalg.norm(y - np.asarray(x)) / np.linalg.norm(x) < 1e-8


# -- tile-mesh sharding hook ---------------------------------------------------


def test_tile_mesh_single_device_smoke():
    """Sharding the accumulation batch axis over a 1-device mesh is
    numerics-neutral for tlr_gemm and the ranked right driver; the hook
    restores cleanly and the no-mesh path is the identity."""
    from jax.sharding import Mesh

    K, op = _cov_operator(10, 4, 32)
    want = np.asarray(tlr_gemm(op.A, op.A, 1e-8).to_dense())
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    prev = set_tile_mesh(mesh)
    try:
        assert tile_mesh() is mesh
        got = np.asarray(tlr_gemm(op.A, op.A, 1e-8).to_dense())
        fact = op.cholesky(CholOptions(eps=1e-6, algo="right",
                                       batching="ranked"))
    finally:
        set_tile_mesh(prev)
    assert tile_mesh() is prev
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    assert _factor_error(K, fact) < 1e-4
