"""Right-looking TLR Cholesky / LDL^T (DESIGN.md section 7).

The right-looking driver trades the left-looking sampling chain for eager
trailing Schur updates on materialized tiles: per column, one batched
rounding pass + TRSM on the panel, then the column-scoped ``tlr_syrk_column``
pushes the rank-r_k outer product onto the trailing matrix. These tests pin:

* dense-reference parity for Cholesky and LDL^T up to nb = 16,
* left-vs-right agreement (same matrix, same eps, same solve),
* the compile-count contract: trailing-update variants stay O(log nb)
  (``algebra_trace_count``) and the panel step rides the bucket ladder,
* inter-tile pivoting is rejected with a clear error.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, TLROperator, algebra_trace_count, covariance_problem,
    tlr_to_dense,
)


def _cov_op(n, b, d=3, eps=1e-9, shift=0.0):
    _, K = covariance_problem(n, d, b)
    K = np.asarray(K) + shift * np.eye(n)
    return K, TLROperator.compress(jnp.asarray(K), b, b, eps)


def _factor_error(K, fact):
    """||A - L (D) L^T||_2 via dense reconstruction (right: perm = id)."""
    Ld = np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                         fact.L.nb, fact.L.b)))
    if fact.d is not None:
        R = Ld @ np.diag(np.asarray(fact.d).reshape(-1)) @ Ld.T
    else:
        R = Ld @ Ld.T
    return np.linalg.norm(K - R, 2)


# -- dense-reference parity ----------------------------------------------------


@pytest.mark.parametrize("nb", [2, 4, 8, 16])
def test_right_cholesky_matches_dense(nb):
    b = 32
    K, op = _cov_op(nb * b, b)
    fact = op.cholesky(CholOptions(eps=1e-6, algo="right"))
    assert fact.stats["algo"] == "right"
    err = _factor_error(K, fact)
    assert err < 1e-4, f"nb={nb}: ||A - LL^T|| = {err}"
    assert fact.stats["modified_chol"] == 0


@pytest.mark.parametrize("flush", [1, 2, 4])
def test_right_flush_period_is_numerics_neutral(flush):
    """The accumulate-then-round cadence only changes scheduling, not the
    eps-scaled accuracy."""
    K, op = _cov_op(256, 32)
    fact = op.cholesky(CholOptions(eps=1e-6, algo="right", right_flush=flush))
    assert _factor_error(K, fact) < 1e-4
    # wider accumulation windows => fewer rounding passes
    assert fact.stats["acc_width"] >= 32 + flush * 32


def test_right_ldlt_matches_dense_spd():
    K, op = _cov_op(256, 32)
    fact = op.ldlt(CholOptions(eps=1e-6, algo="right"))
    assert _factor_error(K, fact) < 1e-4
    assert (np.asarray(fact.d) > 0).all()


@pytest.mark.slow
def test_right_ldlt_indefinite_and_solve():
    """LDL^T factors a mildly indefinite matrix; the handle solves with it."""
    n, b = 256, 32
    K, _ = _cov_op(n, b)
    K = K - 0.5 * np.eye(n)  # indefinite but invertible
    op = TLROperator.compress(jnp.asarray(K), b, b, 1e-9)
    fact = op.ldlt(CholOptions(eps=1e-7, algo="right"))
    assert _factor_error(K, fact) < 1e-4
    assert (np.asarray(fact.d) < 0).any()
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(n)
    x = np.asarray(fact.solve(jnp.asarray(K @ x_true)))
    assert np.linalg.norm(x - x_true) / np.linalg.norm(x_true) < 1e-2


# -- left-vs-right agreement ---------------------------------------------------


@pytest.mark.slow
def test_left_right_agree():
    """Same matrix, same eps: both drivers hit the same eps-scaled accuracy
    band and their factorizations solve to the same answer."""
    K, op = _cov_op(512, 64)
    fl = op.cholesky(CholOptions(eps=1e-6, bs=8, algo="left"))
    fr = op.cholesky(CholOptions(eps=1e-6, algo="right"))
    el, er = _factor_error(K, fl), _factor_error(K, fr)
    assert el < 1e-4 and er < 1e-4
    # both within the same order of magnitude of each other
    assert er < 100 * max(el, 1e-7)
    rng = np.random.default_rng(1)
    x_true = rng.standard_normal(op.n)
    y = jnp.asarray(K @ x_true)
    xl, xr = np.asarray(fl.solve(y)), np.asarray(fr.solve(y))
    nrm = np.linalg.norm(x_true)
    assert np.linalg.norm(xl - x_true) / nrm < 1e-3
    assert np.linalg.norm(xr - x_true) / nrm < 1e-3
    # logdet through either factorization agrees with the dense oracle
    _, ld_ref = np.linalg.slogdet(K)
    assert abs(float(fl.logdet()) - ld_ref) / abs(ld_ref) < 1e-3
    assert abs(float(fr.logdet()) - ld_ref) / abs(ld_ref) < 1e-3


# -- compile-count contract (tentpole acceptance) -------------------------------


def test_right_compile_count_bounded():
    """nb=16: panel-step variants ride the bucket ladder and the algebra
    cores (column-scoped SYRK, panel/flush rounding) stay O(log nb)."""
    nb, b = 16, 16
    _, op = _cov_op(nb * b, b)
    c0 = algebra_trace_count()
    fact = op.cholesky(CholOptions(eps=1e-6, algo="right"))
    delta = algebra_trace_count() - c0
    bound = int(math.log2(nb)) + 1
    assert fact.stats["column_traces"] <= bound, fact.stats["column_events"]
    # panel compress + syrk cores + flush: a few ladder families, never O(nb)
    assert delta <= 3 * bound + 3, delta
    # steady state: each bucket compiles once, later columns reuse it
    seen = set()
    for ev in fact.stats["column_events"]:
        assert ev["traced"] == (ev["Tb"] not in seen)
        seen.add(ev["Tb"])
    # per-column rounding-error diagnostics ride along (stats-schema parity
    # with the left driver's ARA estimates)
    for ev in fact.stats["column_events"]:
        assert ev["err"].shape == (ev["T"],)
        assert np.isfinite(ev["err"]).all()


def test_right_stats_schema_matches_left():
    _, op = _cov_op(128, 32)
    fl = op.cholesky(CholOptions(eps=1e-6, bs=8, algo="left"))
    fr = op.cholesky(CholOptions(eps=1e-6, algo="right"))
    assert set(fl.stats) <= set(fr.stats)
    for key in ("column_iters", "column_ranks", "column_events",
                "column_traces", "modified_chol", "safety_valve", "algo"):
        assert key in fl.stats and key in fr.stats


# -- option validation ---------------------------------------------------------


def test_right_pivot_rejected():
    _, op = _cov_op(128, 32)
    with pytest.raises(ValueError, match="pivot"):
        op.cholesky(CholOptions(algo="right", pivot="frobenius"))


def test_unknown_algo_rejected():
    _, op = _cov_op(128, 32)
    with pytest.raises(ValueError, match="algo"):
        op.cholesky(CholOptions(algo="up"))
    with pytest.raises(ValueError, match="algo"):
        op.ldlt(CholOptions(algo="up"))


def test_right_is_a_normal_factorization_handle():
    """The handle workflow (solve / tri_solve / sample / pytree) is
    driver-agnostic."""
    K, op = _cov_op(128, 32)
    fact = op.cholesky(CholOptions(eps=1e-8, algo="right"))
    x = jnp.asarray(np.random.default_rng(2).standard_normal(op.n))
    y = fact.tri_matvec(x)
    np.testing.assert_allclose(np.asarray(fact.tri_solve(y)), np.asarray(x),
                               rtol=1e-8, atol=1e-8)
    s = fact.sample(jax.random.PRNGKey(0), num=2)
    assert s.shape == (op.n, 2) and np.isfinite(np.asarray(s)).all()
    leaves = jax.tree_util.tree_leaves(fact)
    assert all(isinstance(l, jax.Array) for l in leaves)
