"""Telemetry layer (``repro.obs``): span nesting, Chrome-trace schema,
metrics parity with the drivers' ``stats``, and the disabled-mode pin
(ISSUE 8 satellite: no registry drift, bounded overhead when off)."""

import json
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import (CholOptions, TLROperator, trace_counts,
                        trace_counts_diff)
from repro.core.batching import tile_plan


@pytest.fixture(autouse=True)
def _telemetry_off():
    """Every test starts and ends with telemetry disabled -- a leaked
    enabled state would contaminate the rest of the suite's timings."""
    obs.disable()
    yield
    obs.disable()


def _problem(n=256, b=32, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, 2))
    d = np.linalg.norm(X[:, None] - X[None], axis=-1)
    K = np.exp(-d / 0.5) + 1e-2 * np.eye(n)
    return TLROperator.compress(jnp.asarray(K), b, b, 1e-8)


# -- span mechanics ------------------------------------------------------------


def test_span_nesting_and_ordering():
    tel = obs.enable()
    with obs.span("outer", cat="factor", k=0) as outer:
        with obs.span("inner_a", cat="factor"):
            pass
        with obs.span("inner_b", cat="factor") as ib:
            ib.set(flops=10.0)
    obs.disable()
    by_name = {s.name: s for s in tel.spans}
    assert set(by_name) == {"outer", "inner_a", "inner_b"}
    out, ia, ib = by_name["outer"], by_name["inner_a"], by_name["inner_b"]
    # parent/depth linkage
    assert out.parent == -1 and out.depth == 0
    assert ia.parent == out.id and ib.parent == out.id
    assert ia.depth == ib.depth == 1
    # temporal containment and sibling ordering
    assert out.ts <= ia.ts and ia.ts + ia.dur <= ib.ts + ib.dur
    assert ib.ts + ib.dur <= out.ts + out.dur + 1e-9
    assert ib.args["flops"] == 10.0
    assert out.args == {"k": 0}


def test_disabled_span_is_shared_noop():
    assert not obs.enabled()
    h1 = obs.span("a", cat="x", big=list(range(3)))
    h2 = obs.span("b")
    assert h1 is h2 is obs.NOOP_SPAN
    with h1 as h:
        assert h.set(x=1) is h
    assert obs.current() is None


def test_subtree_selection():
    tel = obs.enable()
    with obs.span("r1") as r1:
        with obs.span("c1"):
            with obs.span("g1"):
                pass
    with obs.span("r2"):
        pass
    obs.disable()
    names = {s.name for s in tel.subtree(r1)}
    assert names == {"r1", "c1", "g1"}
    assert {s.name for s in tel.subtree(None)} == {"r1", "c1", "g1", "r2"}


# -- Chrome-trace / Perfetto schema --------------------------------------------


def _assert_chrome_trace_schema(obj):
    """The subset of the Trace Event Format Perfetto actually validates:
    the object form, ph/pid/tid/name on every event, ts+dur on complete
    events, and JSON-serializability of the whole object."""
    assert isinstance(obj, dict) and isinstance(obj["traceEvents"], list)
    json.dumps(obj)  # must be pure-JSON types throughout
    for ev in obj["traceEvents"]:
        assert ev["ph"] in ("X", "C", "M")
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert isinstance(ev["name"], str)
        if ev["ph"] == "X":
            assert ev["ts"] >= 0 and ev["dur"] >= 0
        if ev["ph"] == "M":
            assert ev["name"] in ("process_name", "thread_name")
            assert isinstance(ev["args"]["name"], str)


def test_chrome_trace_export_covers_all_layers(tmp_path):
    """One recording spanning factorize + solve + serve exports a valid
    trace containing spans from all three layers (the acceptance
    criterion): per-column phase spans with per-bucket children on the
    factor track, and per-tick spans on the serve track."""
    op = _problem()
    obs.enable()
    fact = op.cholesky(CholOptions(eps=1e-8, algo="right",
                                   batching="ranked"))
    fact.solve(jnp.ones((op.n,)))
    srv = fact.serve(slots=4)
    from repro.serve import ServeRequest

    srv.submit(ServeRequest("solve", rhs=np.ones(op.n)))
    srv.submit(ServeRequest("logdet"))
    srv.run()
    path = tmp_path / "trace.json"
    obj = obs.export_chrome_trace(str(path))
    obs.disable()

    _assert_chrome_trace_schema(obj)
    on_disk = json.loads(path.read_text())
    assert on_disk["traceEvents"]  # file round-trips

    evs = obj["traceEvents"]
    cats = {e.get("cat") for e in evs if e["ph"] == "X"}
    assert {"factor", "solve", "serve"} <= cats
    names = {e["name"] for e in evs if e["ph"] == "X"}
    assert {"chol.factorize", "chol.diag", "chol.panel",
            "trsm.sweep", "serve.tick"} <= names

    # per-column phase spans carry per-bucket children (ranked panel)
    assert "round.bucket" in names
    # serve.tick spans have pack/dispatch/sync-or-evict children on the
    # serve track
    serve_names = {e["name"] for e in evs
                   if e["ph"] == "X" and e.get("cat") == "serve"}
    assert {"serve.tick", "serve.pack", "serve.dispatch",
            "serve.evict"} <= serve_names
    # counter events: the retrace registry fold-in (driver emits one per
    # factorization) and serve occupancy
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert "retraces" in counters and "occupancy" in counters
    # one thread-name metadata row per used track
    tids_meta = {e["tid"] for e in evs
                 if e["ph"] == "M" and e["name"] == "thread_name"}
    tids_used = {e["tid"] for e in evs if e["ph"] in ("X", "C")}
    assert tids_used <= tids_meta


def test_span_tree_nesting_in_trace():
    """Factorization spans nest: every chol.panel/chol.diag span lies
    inside the chol.factorize root's [ts, ts+dur] window."""
    op = _problem(n=128, b=32, seed=1)
    obs.enable()
    op.cholesky(CholOptions(eps=1e-8, algo="left"))
    tel = obs.disable()
    roots = [s for s in tel.spans if s.name == "chol.factorize"]
    assert len(roots) == 1
    r = roots[0]
    phases = [s for s in tel.spans if s.name in ("chol.diag", "chol.panel")]
    assert phases
    for s in phases:
        assert r.ts - 1e-9 <= s.ts
        assert s.ts + s.dur <= r.ts + r.dur + 1e-9
        assert s.depth == r.depth + 1


# -- metrics parity with existing stats ----------------------------------------


def test_metrics_parity_with_driver_stats():
    op = _problem()
    obs.enable()
    fact = op.cholesky(CholOptions(eps=1e-8, algo="right",
                                   batching="ranked"))
    obs.disable()
    stats = fact.stats
    snap = stats["telemetry"]
    # the plan-level analytic ratio is copied verbatim from stats["policy"]
    assert snap["padded_flop_ratio_plan"] == \
        stats["policy"]["padded_flop_ratio"]
    # per-column phases: one chol.diag per column, one chol.panel per
    # off-diagonal column (matching column_events), flushes matching stats
    nb = op.nb
    ph = snap["phases"]
    assert ph["chol.diag"]["count"] == nb
    assert ph["chol.panel"]["count"] == len(stats["column_events"]) == nb - 1
    if stats["flushes"]:
        assert ph["chol.flush"]["count"] == stats["flushes"]
    # phase seconds aggregate real wall time: the panel phase total is
    # bounded by the column_events seconds (panel span nests inside the
    # timed column section)
    col_s = sum(e["seconds"] for e in stats["column_events"])
    assert 0 < ph["chol.panel"]["seconds"] <= col_s * 1.5 + 0.5
    # FLOP attribution flows up: padded >= useful > 0 where attached
    if "padded_flop_ratio" in snap:
        assert snap["padded_flop_ratio"] >= 1.0
        assert snap["flops_padded"] >= snap["flops"] > 0
    # retraces snapshot mirrors the registry
    assert set(snap["retraces"]) <= set(trace_counts())


def test_bucket_flops_match_plan_estimates():
    """round.bucket spans carry the same cost_analysis FLOPs as
    TilePlan.bucket_flops at the dispatched shapes."""
    from repro.core.batching import bucketed_round_tiles

    rng = np.random.default_rng(3)
    n, b, w = 24, 16, 16
    ranks = np.zeros(n, np.int64)
    ranks[:20] = rng.integers(1, w + 1, 20)
    U = jnp.asarray(rng.standard_normal((n, b, w)))
    for t in range(n):
        U = U.at[t, :, ranks[t]:].set(0.0)
    V = U
    plan = tile_plan(ranks, w)
    obs.enable()
    bucketed_round_tiles(U, V, ranks, 1e-10, r_out=w)
    tel = obs.disable()
    spans = [s for s in tel.spans if s.name == "round.bucket"]
    assert len(spans) == len(plan.buckets)
    est = plan.bucket_flops(b, w)
    got = sorted(s.args["flops_padded"] for s in spans)
    assert got == sorted(est)
    for s in spans:
        assert 0 < s.args["flops"] <= s.args["flops_padded"]
        assert s.args["bytes"] > 0


def test_server_stats_telemetry_merge_and_null_latencies():
    """ServerStats: empty kinds report null percentiles (not a crash, not
    a fake 0.0), zero-tick servers summarize cleanly, and an enabled
    recording merges the serve-category snapshot into summary()."""
    from repro.serve.stats import ServerStats

    st = ServerStats(slots=4)
    p = st.latency_percentiles("solve")
    assert p["count"] == 0
    assert p["p50_s"] is None and p["p99_s"] is None
    summ = st.summary()           # zero ticks: no NaN, no divide-by-zero
    assert summ["ticks"] == 0 and summ["requests_per_s"] == 0.0
    assert summ["latency"]["p50_s"] is None
    assert "telemetry" not in summ  # disabled mode adds nothing
    json.dumps(summ)               # null-safe JSON

    obs.enable()
    with obs.span("serve.tick", cat="serve"):
        pass
    summ = st.summary()
    obs.disable()
    assert summ["telemetry"]["phases"]["serve.tick"]["count"] == 1


# -- disabled-mode pin ---------------------------------------------------------


def test_disabled_mode_no_registry_drift_and_same_results():
    """With telemetry off, a factorization leaves the compile-count
    registry exactly as the instrumentation-free code would (spans live
    outside jitted bodies), and enabling telemetry afterwards neither
    recompiles nor changes results."""
    op = _problem(n=128, b=32, seed=2)
    o = CholOptions(eps=1e-8, algo="right", batching="ranked")
    fact_cold = op.cholesky(o)           # warm the executables
    snap = trace_counts()
    fact_off = op.cholesky(o)
    assert trace_counts_diff(snap) == {}  # no telemetry, no drift
    assert "telemetry" not in fact_off.stats
    obs.enable()
    fact_on = op.cholesky(o)
    obs.disable()
    assert trace_counts_diff(snap) == {}  # enabled: still zero recompiles
    assert "telemetry" in fact_on.stats
    np.testing.assert_array_equal(np.asarray(fact_on.L.ranks),
                                  np.asarray(fact_off.L.ranks))
    np.testing.assert_allclose(np.asarray(fact_on.L.D),
                               np.asarray(fact_off.L.D), rtol=0, atol=0)
    del fact_cold


def test_disabled_span_overhead_bound():
    """The disabled fast path is a dict-free global check: even a
    pessimistic per-call bound (< 5 us on CPU) keeps any real driver loop
    (thousands of span sites per factorization) under the 5% wall-time
    budget -- a per-call microbench is stable where an end-to-end ratio
    on a ~1 s factorization is timer noise."""
    assert not obs.enabled()
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("x", cat="factor"):
            pass
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 5e-6, f"disabled span cost {per_call * 1e9:.0f} ns"


@pytest.mark.slow
def test_disabled_mode_wall_time_overhead():
    """End-to-end: a warmed factorization with telemetry off stays within
    5% of itself re-run (the instrumented code *is* the disabled path --
    this guards against accidentally un-gating attribute computation)."""
    op = _problem(n=256, b=32, seed=4)
    o = CholOptions(eps=1e-8, algo="right", batching="ranked")
    op.cholesky(o)                       # warm
    reps = 3
    times = []
    for _ in range(2 * reps):
        t0 = time.perf_counter()
        op.cholesky(o)
        times.append(time.perf_counter() - t0)
    base = min(times[:reps])
    again = min(times[reps:])
    # two interleaved samples of the same disabled path: generous 25%
    # band absorbs CI jitter while still catching a hot un-gated loop
    assert again <= base * 1.25 + 0.05


def test_export_without_recording_raises():
    with pytest.raises(RuntimeError):
        obs.to_chrome_trace()
