"""Operator-first API: TLROperator / TLRFactorization handles, batched
compression (rank parity with the per-tile SVD oracle, no host SVD loop on
the hot path), pcg duck-typing, and the remaining deprecation shim."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, TLRFactorization, TLROperator, covariance_problem,
    from_dense, num_tiles, pcg, tlr_round,
)


@pytest.fixture(scope="module")
def cov():
    _, K = covariance_problem(512, 3, 64)
    return K


# -- batched compression (tentpole acceptance) ---------------------------------


def test_compress_ranks_match_svd_oracle(cov):
    """Tile ranks within +-2 of the per-tile SVD oracle at eps=1e-6."""
    K, b, eps = cov, 64, 1e-6
    op = TLROperator.compress(jnp.asarray(K), b, b, eps)
    nb = K.shape[0] // b
    oracle = np.zeros(num_tiles(nb), np.int32)
    t = 0
    for i in range(1, nb):
        for j in range(i):
            s = np.linalg.svd(K[i * b:(i + 1) * b, j * b:(j + 1) * b],
                              compute_uv=False)
            oracle[t] = max(1, min(int((s > eps).sum()), b))
            t += 1
    assert np.abs(np.asarray(op.ranks) - oracle).max() <= 2
    # reconstruction at the threshold
    err = np.linalg.norm(np.asarray(op.to_dense()) - K, 2)
    assert err < 100 * eps


def test_compress_no_host_svd_loop(cov, monkeypatch):
    """The construction hot path never calls the host (numpy) SVD."""
    def _boom(*a, **k):
        raise AssertionError("host numpy SVD called on the compress hot path")

    monkeypatch.setattr(np.linalg, "svd", _boom)
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-6)
    assert int(np.asarray(op.ranks).sum()) > 0


def test_compress_matches_old_from_dense_semantics(cov):
    """Batched path reproduces the old per-tile loop: same ranks, same
    factors up to SVD sign/roundoff (checked through reconstruction)."""
    K, b = cov, 64
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FutureWarning)
        A_old = from_dense(jnp.asarray(K), b, b, 1e-7)
    op = TLROperator.compress(jnp.asarray(K), b, b, 1e-7)
    # LAPACK vs batched-XLA singular values may differ in the last ulp at
    # the cutoff: ranks agree to +-1, reconstructions to the threshold
    assert np.abs(np.asarray(op.ranks) - np.asarray(A_old.ranks)).max() <= 1
    np.testing.assert_allclose(np.asarray(op.to_dense()),
                               np.asarray(A_old.to_dense()),
                               rtol=1e-6, atol=1e-6)


def test_compress_host_fallback_matches_device_path(cov):
    """The host-precision fallback (taken when jnp.asarray would narrow an
    f64 input, i.e. jax_enable_x64 off) has the same truncation semantics
    as the device path."""
    op_dev = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-7)
    op_host = TLROperator._compress_host(np.asarray(cov), 8, 64, 64, 1e-7,
                                         rel=False, store_dtype=None)
    assert np.abs(np.asarray(op_host.ranks)
                  - np.asarray(op_dev.ranks)).max() <= 1
    np.testing.assert_allclose(np.asarray(op_host.to_dense()),
                               np.asarray(op_dev.to_dense()),
                               rtol=1e-6, atol=1e-6)


def test_compress_rel_and_rmax(cov):
    op_abs = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-6)
    op_rel = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-6, rel=True)
    assert np.asarray(op_rel.ranks).sum() <= np.asarray(op_abs.ranks).sum()
    op_r8 = TLROperator.compress(jnp.asarray(cov), 64, 8, 1e-9)
    assert op_r8.r_max == 8
    assert np.asarray(op_r8.ranks).max() <= 8
    # r_max beyond the tile size pads with inert zero columns
    op_r96 = TLROperator.compress(jnp.asarray(cov), 64, 96, 1e-6)
    assert op_r96.A.U.shape[2] == 96
    assert np.all(np.asarray(op_r96.A.U)[:, :, 64:] == 0.0)


def test_compress_ara_method(cov):
    """The batched-ARA construction path detects comparable ranks."""
    op_svd = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-6)
    op_ara = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-6,
                                  method="ara", bs=8)
    err = np.linalg.norm(np.asarray(op_ara.to_dense()) - cov, 2)
    assert err < 1e-4
    # ARA appends in blocks of bs and its residual estimator is
    # conservative: never below the oracle, overshoot < 3 blocks
    diff = np.asarray(op_ara.ranks) - np.asarray(op_svd.ranks)
    assert diff.min() >= -1 and diff.max() <= 3 * 8
    with pytest.raises(ValueError, match="rel"):
        TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-6, method="ara",
                             rel=True)


def test_from_kernel_matches_compress(cov):
    pts, K = covariance_problem(512, 3, 64)
    op_k = TLROperator.from_kernel(pts, "exp", tile=64, eps=1e-8)
    op_d = TLROperator.compress(jnp.asarray(K), 64, eps=1e-8)
    np.testing.assert_allclose(np.asarray(op_k.to_dense()),
                               np.asarray(op_d.to_dense()),
                               rtol=1e-10, atol=1e-10)
    # callable kernels work too
    from repro.core import matern32_covariance
    op_m = TLROperator.from_kernel(pts, lambda p: matern32_covariance(p, 0.2),
                                   tile=64, eps=1e-8)
    assert op_m.shape == (512, 512)
    with pytest.raises(ValueError, match="kernel"):
        TLROperator.from_kernel(pts, "cauchy", tile=64)


# -- operator algebra ----------------------------------------------------------


def test_operator_matvec_and_matmul(cov):
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-9)
    x = np.random.default_rng(0).standard_normal(op.n)
    y = np.asarray(op @ jnp.asarray(x))
    np.testing.assert_allclose(y, cov @ x, rtol=1e-7, atol=1e-7)
    X = np.random.default_rng(1).standard_normal((op.n, 3))
    Y = np.asarray(op.matvec(jnp.asarray(X)))
    np.testing.assert_allclose(Y, cov @ X, rtol=1e-7, atol=1e-7)
    assert op.shape == (512, 512)
    assert op.dtype == jnp.float64
    assert op.nb == 8 and op.b == 64


@pytest.mark.slow
def test_handles_are_pytrees(cov):
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-6)
    leaves = jax.tree_util.tree_leaves(op)
    assert len(leaves) == 4  # D, U, V, ranks
    op2 = jax.tree_util.tree_map(lambda x: x, op)
    assert isinstance(op2, TLROperator)
    fact = op.cholesky(CholOptions(eps=1e-6, bs=8))
    fact2 = jax.tree_util.tree_map(lambda x: x, fact)
    assert isinstance(fact2, TLRFactorization)
    # static aux (perm, stats) survives tree ops untouched
    assert fact2.perm is fact.perm and fact2.stats is fact.stats


@pytest.mark.slow
def test_factorization_handle_workflow(cov):
    """compress -> factor -> solve/logdet/sample through the handles only."""
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-9)
    fact = op.cholesky(CholOptions(eps=1e-8, bs=8))
    rng = np.random.default_rng(2)
    x_true = rng.standard_normal(op.n)
    x = np.asarray(fact.solve(jnp.asarray(cov @ x_true)))
    assert np.linalg.norm(x - x_true) / np.linalg.norm(x_true) < 1e-3
    assert abs(float(fact.logdet()) - np.linalg.slogdet(cov)[1]) < 1e-2
    s = fact.sample(jax.random.PRNGKey(0), num=3)
    assert s.shape == (op.n, 3)
    assert not fact.is_ldlt and fact.shape == op.shape


# -- pcg duck-typing -----------------------------------------------------------


@pytest.mark.slow
def test_pcg_accepts_handles(cov):
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-9)
    fact = op.cholesky(CholOptions(eps=1e-6, bs=8))
    rhs = jnp.asarray(np.random.default_rng(3).standard_normal(op.n))
    x_op, it_op, hist = pcg(op, rhs, precond=fact, tol=1e-10, maxiter=100)
    x_fn, it_fn, _ = pcg(lambda v: op.matvec(v), rhs,
                         precond=lambda r: fact.solve(r), tol=1e-10,
                         maxiter=100)
    assert it_op == it_fn
    np.testing.assert_allclose(np.asarray(x_op), np.asarray(x_fn),
                               rtol=1e-10, atol=1e-12)
    assert hist[-1] < 1e-10
    with pytest.raises(TypeError, match="matvec"):
        pcg(object(), rhs)


def test_pcg_zero_rhs_guard(cov):
    """||b|| = 0 returns x = 0 immediately with an empty history (no NaNs)."""
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-6)
    x, it, history = pcg(op, jnp.zeros(op.n, jnp.float64))
    assert it == 0 and history == []
    assert np.all(np.asarray(x) == 0.0)


# -- deprecation shims ---------------------------------------------------------


def test_from_dense_shim_warns_and_delegates(cov):
    """``from_dense`` is the one surviving shim; the PR-2 solve/logdet/
    sample shims were removed in PR 6 (use the handle methods)."""
    with pytest.warns(FutureWarning):
        A = from_dense(jnp.asarray(cov), 64, 64, 1e-8)
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-8)
    np.testing.assert_array_equal(np.asarray(A.ranks), np.asarray(op.ranks))
    import repro.core as core
    for gone in ("tlr_factor_solve", "tlr_logdet", "mvn_sample"):
        assert not hasattr(core, gone)


# -- trace / diagonal accessors (PR 3 satellites) ------------------------------


def test_trace_and_diagonal_dense_oracle(cov):
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-8)
    assert float(op.trace()) == pytest.approx(float(np.trace(cov)), rel=1e-12)
    np.testing.assert_allclose(np.asarray(op.diagonal()), np.diag(cov),
                               rtol=1e-12, atol=1e-12)
    # diagonal() follows the diagonal tiles even when off-diagonals change
    scaled = 3.0 * op
    assert float(scaled.trace()) == pytest.approx(3.0 * float(op.trace()),
                                                  rel=1e-12)


def test_scalar_mul_accepts_numpy_scalar_types(cov):
    """np.float32(2.0) is an np.number, not an ndarray -- __mul__ must treat
    it like any other scalar instead of returning NotImplemented."""
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-8)
    want = float((2.0 * op).trace())
    for alpha in (np.float32(2.0), np.float64(2.0), np.int64(2),
                  jnp.asarray(2.0), np.asarray(2.0)):
        scaled = alpha * op
        assert float(scaled.trace()) == pytest.approx(want, rel=1e-6)
        assert float((op * alpha).trace()) == pytest.approx(want, rel=1e-6)


# -- rank-truncation floor (ISSUE 4 satellite) ---------------------------------


def _block_diag_spd(n=128, b=64, seed=0):
    rng = np.random.default_rng(seed)
    K = np.zeros((n, n))
    for s in range(0, n, b):
        M = rng.standard_normal((b, b))
        K[s:s + b, s:s + b] = M @ M.T + b * np.eye(b)
    return K


def test_zero_tiles_compress_to_rank_zero():
    """A numerically-zero off-diagonal tile must compress to rank 0, not a
    phantom rank-1 factor -- the same floor the algebra's rounding pass
    uses, so compression and tlr_round agree (and memory_stats counts no
    bytes for empty tiles)."""
    K = _block_diag_spd()
    op = TLROperator.compress(jnp.asarray(K), 64, 64, 1e-10)
    assert int(np.asarray(op.ranks).max()) == 0
    assert op.memory_stats()["lowrank_bytes_logical"] == 0
    # the zeroed factors reconstruct the matrix exactly
    np.testing.assert_allclose(np.asarray(op.to_dense()), K,
                               rtol=0, atol=1e-12)
    # rounding keeps the floor: no resurrection to rank 1
    R = tlr_round(op.A, 1e-10)
    assert int(np.asarray(R.ranks).max()) == 0
    # the host-precision fallback path agrees
    op_host = TLROperator._compress_host(K, 2, 64, 64, 1e-10,
                                         rel=False, store_dtype=None)
    assert int(np.asarray(op_host.ranks).max()) == 0


def test_rank_zero_operator_is_usable():
    """Factorization and solve work through rank-0 off-diagonal tiles."""
    K = _block_diag_spd()
    op = TLROperator.compress(jnp.asarray(K), 64, 64, 1e-10)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(op.n))
    np.testing.assert_allclose(np.asarray(op @ x), K @ np.asarray(x),
                               rtol=1e-12, atol=1e-9)
    fact = op.cholesky(CholOptions(eps=1e-8, bs=8))
    y = np.asarray(fact.solve(jnp.asarray(K @ np.asarray(x))))
    assert np.linalg.norm(y - np.asarray(x)) / np.linalg.norm(x) < 1e-6


# -- PCG breakdown guard (ISSUE 4 satellite) -----------------------------------


def test_pcg_breakdown_indefinite_preconditioner(cov):
    """A non-SPD preconditioner must stop PCG at the last finite iterate
    with the condition surfaced, not spin to maxiter on NaNs."""
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-8)
    rhs = jnp.asarray(np.random.default_rng(5).standard_normal(op.n))
    x, it, hist = pcg(op, rhs, precond=lambda r: -r, tol=1e-10, maxiter=50)
    assert hist.breakdown == "indefinite_preconditioner"
    assert it < 50
    assert np.isfinite(np.asarray(x)).all()
    assert np.isfinite(hist).all()


def test_pcg_breakdown_indefinite_operator():
    rhs = jnp.asarray(np.random.default_rng(6).standard_normal(64))
    x, it, hist = pcg(lambda v: -v, rhs, tol=1e-10, maxiter=50)
    assert hist.breakdown == "indefinite_curvature"
    assert np.all(np.asarray(x) == 0.0)  # never left the initial iterate


def test_pcg_breakdown_nonfinite(cov):
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-8)
    rhs = jnp.asarray(np.random.default_rng(7).standard_normal(op.n))
    x, it, hist = pcg(op, rhs, precond=lambda r: r * jnp.nan, maxiter=50)
    assert hist.breakdown == "nonfinite"
    assert np.isfinite(np.asarray(x)).all()
    assert np.isfinite(hist).all()


def test_pcg_clean_run_has_no_breakdown(cov):
    op = TLROperator.compress(jnp.asarray(cov), 64, 64, 1e-8)
    fact = op.cholesky(CholOptions(eps=1e-6, bs=8))
    rhs = jnp.asarray(np.random.default_rng(8).standard_normal(op.n))
    x, it, hist = pcg(op, rhs, precond=fact, tol=1e-8, maxiter=100)
    assert hist.breakdown is None
    assert hist[-1] < 1e-8
