import jax

# TLR numerical validation runs in f64 (the paper's precision). LM-side code
# passes explicit dtypes everywhere, so enabling x64 globally is safe.
jax.config.update("jax_enable_x64", True)
