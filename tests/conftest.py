import jax
import pytest

# TLR numerical validation runs in f64 (the paper's precision). LM-side code
# passes explicit dtypes everywhere, so enabling x64 globally is safe.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_executables_between_modules():
    """Release jit executables when a test module finishes.

    The CPU XLA backend in this toolchain segfaults once a single process
    accumulates enough compiled executables (the full suite compiles a few
    thousand: per-factorization pipelines retrace by design). No single
    module comes anywhere near the limit, so dropping the caches at module
    boundaries keeps the whole run bounded; tests that pin compile counts
    warm up and measure within one module, so they are unaffected.
    """
    yield
    jax.clear_caches()
