"""Integration tests: TLR Cholesky / LDL^T vs dense oracles (paper sections 4-5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, covariance_problem, fractional_diffusion_problem,
    from_dense, pcg, spectral_norm_est, tile_perm_to_element_perm,
    tlr_cholesky, tlr_ldlt, tlr_matvec,
    tlr_to_dense, tlr_tri_matvec, tlr_trsv, dense_ldlt_tile, robust_cholesky,
)


def _cov_tlr(n=512, d=3, b=64, eps=1e-7, r_max=64):
    _, K = covariance_problem(n, d, b)
    A = from_dense(jnp.asarray(K), b, r_max, eps)
    return K, A


def _factor_error(K, fact):
    """||P A P^T - L (D) L^T||_2 via dense reconstruction."""
    Ld = np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                 fact.L.nb, fact.L.b))
    # keep only the lower triangle (to_dense mirrors the off-diag tiles)
    Ld = np.tril(Ld)
    eperm = tile_perm_to_element_perm(fact.perm, fact.L.b)
    Ap = K[np.ix_(eperm, eperm)]
    if fact.d is not None:
        dd = np.asarray(fact.d).reshape(-1)
        R = Ld @ np.diag(dd) @ Ld.T
    else:
        R = Ld @ Ld.T
    return np.linalg.norm(Ap - R, 2)


@pytest.mark.parametrize("mode", ["fused", "dynamic"])
def test_cholesky_accuracy(mode):
    K, A = _cov_tlr()
    opts = CholOptions(eps=1e-6, bs=8, mode=mode, r_max_out=64)
    fact = tlr_cholesky(A, opts)
    err = _factor_error(K, fact)
    assert err < 1e-4, f"mode={mode}: ||A-LL^T|| = {err}"
    assert fact.stats["modified_chol"] == 0


@pytest.mark.slow
def test_cholesky_modes_agree():
    """Dynamic batching must not change the math, only the orchestration."""
    K, A = _cov_tlr(n=384, b=64)
    f1 = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, mode="fused"))
    f2 = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, mode="dynamic", bucket=3))
    e1, e2 = _factor_error(K, f1), _factor_error(K, f2)
    assert abs(e1 - e2) < 5e-5
    # Ranks agree to within one sample block: the math is identical, but a
    # refilled slot sees a different (equally fresh) Omega stream, which can
    # move a borderline tile by +-bs.
    r1, r2 = np.asarray(f1.L.ranks), np.asarray(f2.L.ranks)
    assert np.max(np.abs(r1 - r2)) <= 8


@pytest.mark.parametrize("share_omega", [True, False])
def test_share_omega_equivalent_accuracy(share_omega):
    K, A = _cov_tlr(n=384, b=64)
    opts = CholOptions(eps=1e-6, bs=8, share_omega=share_omega)
    err = _factor_error(K, tlr_cholesky(A, opts))
    assert err < 1e-4


@pytest.mark.parametrize("eps", [1e-2, 1e-4, 1e-6])
def test_accuracy_tracks_threshold(eps):
    """Factorization error scales with the compression threshold (Fig. 7 regime)."""
    K, A = _cov_tlr(n=512, b=64, eps=eps * 1e-2)
    fact = tlr_cholesky(A, CholOptions(eps=eps, bs=8))
    err = _factor_error(K, fact)
    assert err < 100 * eps


@pytest.mark.slow
def test_tighter_eps_higher_ranks():
    K, A = _cov_tlr(n=512, d=3, b=64, eps=1e-9, r_max=64)
    r_loose = np.asarray(
        tlr_cholesky(A, CholOptions(eps=1e-2, bs=4)).L.ranks).sum()
    r_tight = np.asarray(
        tlr_cholesky(A, CholOptions(eps=1e-6, bs=4)).L.ranks).sum()
    assert r_tight > r_loose


def test_trsv_and_solve():
    K, A = _cov_tlr()
    fact = tlr_cholesky(A, CholOptions(eps=1e-8, bs=8))
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(A.n)
    y = np.asarray(K) @ x_true
    x = np.asarray(fact.solve(jnp.asarray(y)))
    rel = np.linalg.norm(x - x_true) / np.linalg.norm(x_true)
    assert rel < 1e-3, f"solve relative error {rel}"


def test_tri_matvec_roundtrip():
    _, A = _cov_tlr(n=384, b=64)
    fact = tlr_cholesky(A, CholOptions(eps=1e-8, bs=8))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(A.n))
    y = tlr_tri_matvec(fact.L, x)
    x2 = tlr_trsv(fact.L, y)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(x), rtol=1e-8,
                               atol=1e-8)
    yt = tlr_tri_matvec(fact.L, x, trans=True)
    x3 = tlr_trsv(fact.L, yt, trans=True)
    np.testing.assert_allclose(np.asarray(x3), np.asarray(x), rtol=1e-8,
                               atol=1e-8)


def test_logdet_and_mvn():
    K, A = _cov_tlr(n=384, b=64)
    fact = tlr_cholesky(A, CholOptions(eps=1e-8, bs=8))
    ld = float(fact.logdet())
    _, ld_ref = np.linalg.slogdet(K)
    assert abs(ld - ld_ref) / abs(ld_ref) < 1e-3
    # value parity with the per-tile host loop the batched jnp.diagonal
    # implementation replaced
    ld_loop = 2.0 * float(sum(
        np.sum(np.log(np.abs(np.diag(np.asarray(fact.L.D[k])))))
        for k in range(fact.L.nb)))
    np.testing.assert_allclose(ld, ld_loop, rtol=1e-12)
    s = fact.sample(jax.random.PRNGKey(0), num=4)
    assert s.shape == (A.n, 4) and np.isfinite(np.asarray(s)).all()


@pytest.mark.slow
def test_pcg_preconditioned_by_tlr():
    """Fractional-diffusion PCG: looser eps => more iterations (Fig. 9)."""
    _, Kfd = fractional_diffusion_problem(512, 64)
    A = from_dense(jnp.asarray(Kfd), 64, 64, 1e-10)
    rng = np.random.default_rng(0)
    rhs = jnp.asarray(rng.standard_normal(512))

    iters = {}
    for eps in (1e-2, 1e-6):
        Keps = Kfd + eps * np.eye(512)
        Aeps = from_dense(jnp.asarray(Keps), 64, 64, eps * 1e-3)
        fact = tlr_cholesky(Aeps, CholOptions(eps=eps, bs=8))
        x, it, hist = pcg(
            lambda v: tlr_matvec(A, v), rhs,
            precond=lambda r: fact.solve(r),
            tol=1e-6, maxiter=300,
        )
        iters[eps] = it
        assert hist[-1] < 1e-6 or it == 300
    assert iters[1e-6] <= iters[1e-2]
    assert iters[1e-6] < 50  # tight preconditioner converges fast


def test_unpreconditioned_cg_is_worse():
    _, Kfd = fractional_diffusion_problem(512, 64)
    A = from_dense(jnp.asarray(Kfd), 64, 64, 1e-10)
    rhs = jnp.asarray(np.random.default_rng(0).standard_normal(512))
    _, it_plain, _ = pcg(lambda v: tlr_matvec(A, v), rhs, tol=1e-6,
                         maxiter=300)
    fact = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8))
    _, it_prec, _ = pcg(lambda v: tlr_matvec(A, v), rhs,
                        precond=lambda r: fact.solve(r),
                        tol=1e-6, maxiter=300)
    assert it_prec < it_plain


# -- robustness extensions (section 5) -----------------------------------------


@pytest.mark.slow
def test_schur_compensation_rescues_loose_eps():
    """At loose eps on an ill-conditioned matrix, compensation avoids breakdown."""
    _, Kfd = fractional_diffusion_problem(768, 64, s=0.9)
    A = from_dense(jnp.asarray(Kfd), 64, 64, 1e-10)
    f_comp = tlr_cholesky(A, CholOptions(eps=5e-3, bs=8, schur="diag",
                                         modified_chol=True))
    # factorization finished and L is finite
    assert np.isfinite(np.asarray(f_comp.L.D)).all()
    assert np.isfinite(np.asarray(f_comp.L.V)).all()


def test_modified_cholesky_fallback():
    # near-PSD tile: eigenvalue clamp keeps the factor finite
    rng = np.random.default_rng(0)
    Q, _ = np.linalg.qr(rng.standard_normal((32, 32)))
    w = np.linspace(1.0, -1e-8, 32)
    Aind = jnp.asarray((Q * w) @ Q.T)
    L, bad = robust_cholesky(Aind, delta=1e-6)
    assert bool(bad)
    assert np.isfinite(np.asarray(L)).all()
    resid = np.asarray(L @ L.T) - np.asarray(Aind)
    assert np.linalg.norm(resid, 2) < 1e-4


def test_dense_ldlt_tile():
    rng = np.random.default_rng(3)
    M = rng.standard_normal((48, 48))
    Aind = jnp.asarray(M + M.T)  # symmetric indefinite
    L, d = dense_ldlt_tile(Aind)
    R = np.asarray(L) @ np.diag(np.asarray(d)) @ np.asarray(L).T
    np.testing.assert_allclose(R, np.asarray(Aind), rtol=1e-6, atol=1e-8)
    assert (np.asarray(d) < 0).any(), "indefinite: some d must be negative"


def test_ldlt_factorization_spd():
    """LDL^T on an SPD matrix matches Cholesky accuracy (section 6.3)."""
    K, A = _cov_tlr(n=384, b=64)
    fact = tlr_ldlt(A, CholOptions(eps=1e-6, bs=8))
    err = _factor_error(K, fact)
    assert err < 1e-4
    assert (np.asarray(fact.d) > 0).all()


def test_ldlt_factorization_indefinite():
    """LDL^T factors a (mildly) indefinite TLR matrix."""
    K, _ = _cov_tlr(n=384, b=64)
    K = np.asarray(K) - 0.5 * np.eye(384)  # shift: indefinite but invertible
    A = from_dense(jnp.asarray(K), 64, 64, 1e-9)
    fact = tlr_ldlt(A, CholOptions(eps=1e-7, bs=8))
    err = _factor_error(K, fact)
    assert err < 1e-4
    assert (np.asarray(fact.d) < 0).any()
    # solve through the LDL^T factorization
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(384)
    y = K @ x_true
    x = np.asarray(fact.solve(jnp.asarray(y)))
    assert np.linalg.norm(x - x_true) / np.linalg.norm(x_true) < 1e-2


@pytest.mark.parametrize("pivot", ["frobenius", "power"])
def test_pivoted_cholesky(pivot):
    """Inter-tile pivoting (section 5.2): correct factorization of P A P^T."""
    K, A = _cov_tlr(n=384, b=64)
    fact = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, pivot=pivot))
    err = _factor_error(K, fact)
    assert err < 1e-4
    # the permutation should generally be non-trivial for covariance problems
    assert fact.perm.shape == (A.nb,)
    # solve must honor the permutation
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(384)
    y = K @ x_true
    x = np.asarray(fact.solve(jnp.asarray(y)))
    assert np.linalg.norm(x - x_true) / np.linalg.norm(x_true) < 1e-2
