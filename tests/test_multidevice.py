"""End-to-end multi-device factorization (forced-host-device lane).

Runs only when jax sees >= 2 devices -- CI's quick lane forces 8 virtual
CPU devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(see .github/workflows/ci.yml). Pins:

* a whole right-looking factorization on a 2x2 test mesh -- sharded
  accumulation buffers, sharded rounding scatter, sharded ``Lout``
  writes -- matches the single-device factor exactly, sequential and
  lookahead, and the resulting handle solves correctly,
* the ``set_tile_mesh`` indivisibility modes: ``"pad"`` zero-pads the
  leading axis (or replicates at preserve-shape call sites), ``"error"``
  raises with the offending sizes -- no silent identity fallback,
* the compile-count contract survives sharding (re-factoring on the mesh
  retraces nothing).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, TLROperator, algebra_trace_count, batching_trace_count,
    covariance_problem, pad_tile_batch, set_tile_mesh, shard_tile_batch,
    tile_dp_size, tlr_to_dense,
)
from repro.launch.mesh import make_test_mesh

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (CI forces 8 virtual host devices)")


def _cov_op(n, b, d=3, eps=1e-9):
    _, K = covariance_problem(n, d, b)
    K = np.asarray(K)
    return K, TLROperator.compress(jnp.asarray(K), b, b, eps)


def _Lmat(fact):
    return np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                           fact.L.nb, fact.L.b)))


@pytest.fixture
def mesh22():
    mesh = make_test_mesh((2, 2), ("data", "model"))
    prev = set_tile_mesh(mesh)
    yield mesh
    set_tile_mesh(prev)


# -- end-to-end sharded factorization ------------------------------------------


@pytest.mark.parametrize("lookahead", [False, True])
@pytest.mark.parametrize("batching", ["flat", "ranked"])
def test_right_factorization_sharded_parity(mesh22, lookahead, batching):
    """Full right-looking Cholesky on the mesh == single-device factor."""
    b, nb = 32, 8          # nt = 28, divisible by the DP size 2
    K, op = _cov_op(nb * b, b)
    opts = CholOptions(eps=1e-6, algo="right", batching=batching,
                       lookahead=lookahead)
    f = op.cholesky(opts)
    prev = set_tile_mesh(None)
    try:
        f1 = op.cholesky(opts)
    finally:
        set_tile_mesh(prev)
    np.testing.assert_array_equal(np.asarray(f.L.D), np.asarray(f1.L.D))
    np.testing.assert_array_equal(np.asarray(f.L.U), np.asarray(f1.L.U))
    np.testing.assert_array_equal(np.asarray(f.L.V), np.asarray(f1.L.V))
    np.testing.assert_array_equal(np.asarray(f.L.ranks),
                                  np.asarray(f1.L.ranks))
    # the telemetry attribution saw the mesh
    sched = f.stats["schedule"]
    assert sched["name"] == ("lookahead" if lookahead else "sequential")


def test_sharded_factorization_solves(mesh22):
    b, nb = 32, 8
    K, op = _cov_op(nb * b, b)
    f = op.cholesky(CholOptions(eps=1e-6, algo="right", lookahead=True))
    rng = np.random.default_rng(0)
    x = rng.standard_normal(op.n)
    y = np.asarray(f.solve(jnp.asarray(K @ x)))
    assert np.linalg.norm(y - x) / np.linalg.norm(x) < 1e-4


def test_left_factorization_sharded_parity(mesh22):
    b, nb = 32, 4
    K, op = _cov_op(nb * b, b)
    f = op.cholesky(CholOptions(eps=1e-6, algo="left"))
    prev = set_tile_mesh(None)
    try:
        f1 = op.cholesky(CholOptions(eps=1e-6, algo="left"))
    finally:
        set_tile_mesh(prev)
    np.testing.assert_array_equal(np.asarray(f.L.U), np.asarray(f1.L.U))
    np.testing.assert_array_equal(np.asarray(f.L.D), np.asarray(f1.L.D))


def test_compile_counts_stable_on_mesh(mesh22):
    """The compile-count contract survives sharding: a warm sharded
    factorization retraces none of the module-level algebra/batching cores,
    and the per-factorization pipeline rides the same bucket ladder every
    run (the pipeline jits are per-call by design, so their trace count is
    pinned run-to-run rather than to zero)."""
    b, nb = 32, 8
    _, op = _cov_op(nb * b, b)
    opts = CholOptions(eps=1e-6, algo="right", lookahead=True)
    f1 = op.cholesky(opts)                 # warm the global jit caches
    a0, b0 = algebra_trace_count(), batching_trace_count()
    f2 = op.cholesky(opts)
    assert algebra_trace_count() - a0 == 0
    assert batching_trace_count() - b0 == 0
    assert f2.stats["column_traces"] == f1.stats["column_traces"]
    # the shared scatter is cached process-wide: fully warm on run 2
    assert f2.stats["scatter_traces"] == 0


# -- indivisibility modes ------------------------------------------------------


def test_pad_mode_pads_batch_axis(mesh22):
    dp = tile_dp_size()
    assert dp == 2
    assert pad_tile_batch(7) == 8
    assert pad_tile_batch(8) == 8
    x = jnp.ones((7, 4, 4))
    y = shard_tile_batch(x)
    assert y.shape == (8, 4, 4)            # zero-padded up to the quantum
    assert float(jnp.abs(y[7]).max()) == 0.0
    np.testing.assert_array_equal(np.asarray(y[:7]), np.asarray(x))


def test_pad_mode_preserve_shape_replicates(mesh22):
    x = jnp.ones((7, 4, 4))
    y = shard_tile_batch(x, preserve_shape=True)
    assert y.shape == (7, 4, 4)            # caller-visible shape kept
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_error_mode_raises_with_sizes():
    mesh = make_test_mesh((2, 2), ("data", "model"))
    prev = set_tile_mesh(mesh, on_indivisible="error")
    try:
        with pytest.raises(ValueError, match=r"size 7.*divide.*2"):
            shard_tile_batch(jnp.ones((7, 4, 4)))
        with pytest.raises(ValueError, match="divide"):
            shard_tile_batch(jnp.ones((7, 4, 4)), preserve_shape=True)
        # divisible batches still shard fine under "error"
        y = shard_tile_batch(jnp.ones((8, 4, 4)))
        assert y.shape == (8, 4, 4)
    finally:
        set_tile_mesh(prev)


def test_error_mode_fails_factorization_on_indivisible_grid():
    """nb=5 -> nt=10 divides dp=2, but the nb=5 diagonal stack does not:
    the factorization must fail loudly, not silently fall back."""
    mesh = make_test_mesh((2, 2), ("data", "model"))
    b, nb = 32, 5
    _, op = _cov_op(nb * b, b)
    prev = set_tile_mesh(mesh, on_indivisible="error")
    try:
        with pytest.raises(ValueError, match="divide"):
            op.cholesky(CholOptions(eps=1e-6, algo="right"))
    finally:
        set_tile_mesh(prev)
    # ... while "pad" handles the same grid bit-exactly
    prev = set_tile_mesh(mesh, on_indivisible="pad")
    try:
        f = op.cholesky(CholOptions(eps=1e-6, algo="right"))
    finally:
        set_tile_mesh(prev)
    f1 = op.cholesky(CholOptions(eps=1e-6, algo="right"))
    np.testing.assert_array_equal(np.asarray(f.L.U), np.asarray(f1.L.U))


def test_invalid_mode_rejected():
    with pytest.raises(ValueError, match="on_indivisible"):
        set_tile_mesh(make_test_mesh((2, 2), ("data", "model")),
                      on_indivisible="ignore")
