"""Rank-aware execution plans (DESIGN.md section 9): TilePlan memoization /
invalidation, ranked-vs-flat parity on every read path (TRSM, matvec,
tri_matvec, sample) on skewed rank distributions with rank-0 tiles, the
unified trace-registry compile pin, the auto policy's decision record, and
the pcg ``check_every`` history regression."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, PCGHistory, TLROperator, TilePlan, choose_batching,
    covariance_problem, pcg, plan_rank_buckets, resolve_batching,
    resolve_policy, tile_plan, tlr_matvec, tlr_tri_matvec, tlr_trsv,
    tlr_trsv_reference, trace_count, trace_counts, trace_counts_diff,
)
from repro.core.tlr import TLRMatrix, num_tiles, tril_pairs


# -- fixtures: skewed-rank synthetic factors -----------------------------------


def _skewed_lower(nb=8, b=16, r_max=16, seed=0):
    """Lower-triangular TLR L with a skewed rank distribution: most tiles
    rank 1-2, a few at r_max, some exactly rank 0 -- the regime the ranked
    read paths exist for. Factors honor the storage invariant (columns past
    each tile's rank exactly zero); diagonal blocks are well-conditioned
    lower-triangular."""
    rng = np.random.default_rng(seed)
    nt = num_tiles(nb)
    ranks = np.ones(nt, np.int32)
    ranks[rng.permutation(nt)[: max(1, nt // 4)]] = 2
    ranks[rng.permutation(nt)[: max(1, nt // 8)]] = r_max
    ranks[rng.permutation(nt)[: max(1, nt // 8)]] = 0
    D = np.tril(rng.standard_normal((nb, b, b)) * 0.1)
    D[:, np.arange(b), np.arange(b)] = 2.0 + rng.random((nb, b))
    U = np.zeros((nt, b, r_max))
    V = np.zeros((nt, b, r_max))
    for t, r in enumerate(ranks):
        U[t, :, :r] = rng.standard_normal((b, r)) * 0.1
        V[t, :, :r] = rng.standard_normal((b, r)) * 0.1
    return TLRMatrix(D=jnp.asarray(D), U=jnp.asarray(U), V=jnp.asarray(V),
                     ranks=jnp.asarray(ranks))


def _skewed_sym(nb=8, b=16, r_max=16, seed=1):
    """Symmetric TLR A with the same skewed distribution (diag symmetric)."""
    L = _skewed_lower(nb, b, r_max, seed)
    D = np.asarray(L.D)
    D = D + np.swapaxes(D, 1, 2)
    return TLRMatrix(D=jnp.asarray(D), U=L.U, V=L.V, ranks=L.ranks)


# -- TilePlan: structure, memoization, invalidation ----------------------------


def test_tile_plan_memoized_on_ranks_identity():
    L = _skewed_lower()
    p1 = tile_plan(L.ranks, L.r_max)
    p2 = tile_plan(L.ranks, L.r_max)
    assert p1 is p2                       # same ranks array -> cached plan
    assert isinstance(p1, TilePlan)
    # a new ranks array (every functional update makes one) -> new plan
    ranks2 = jnp.asarray(np.asarray(L.ranks).copy())
    p3 = tile_plan(ranks2, L.r_max)
    assert p3 is not p1
    np.testing.assert_array_equal(p3.widths, p1.widths)


def test_tile_plan_invalidated_on_host_mutation():
    """np.ndarray ranks (the right driver's in-place ``tile_w``) are
    fingerprinted: mutating the array in place invalidates its cache slot."""
    rk = np.array([0, 1, 2, 8, 8, 3], np.int64)
    p1 = tile_plan(rk, 8)
    assert tile_plan(rk, 8) is p1
    rk[0] = 5                             # in-place mutation
    p2 = tile_plan(rk, 8)
    assert p2 is not p1
    assert p2.widths[0] == 8              # 5 buckets up to 8


def test_tile_plan_widths_and_histogram():
    ranks = np.array([0, 1, 2, 3, 4, 5, 8, 9, 0], np.int64)
    plan = plan_rank_buckets(ranks, 16)
    np.testing.assert_array_equal(plan.widths,
                                  [0, 1, 2, 4, 4, 8, 8, 16, 0])
    assert plan.max_rank == 9
    assert plan.median_rank == pytest.approx(4.0)  # positive ranks only
    assert plan.rank_skew == pytest.approx(9 / 4.0)
    assert plan.useful_cols() == 32
    assert plan.flat_cols() == 9 * 16
    assert plan.padded_flop_ratio() > 1.0


def test_plan_flop_estimates_ordered():
    """flop_estimate-backed per-bucket costs: the ranked dispatch lowers
    strictly fewer FLOPs than the flat r_max-wide pass on a skewed plan."""
    L = _skewed_lower()
    plan = tile_plan(L.ranks, L.r_max)
    per_bucket = plan.bucket_flops(L.b, dtype=np.float64)
    flat = plan.flat_flops(L.b, dtype=np.float64)
    assert len(per_bucket) == len(plan.buckets)
    assert all(f > 0 for f in per_bucket)
    assert sum(per_bucket) < flat


# -- the auto policy -----------------------------------------------------------


def test_choose_batching_thresholds():
    skew = tile_plan(jnp.asarray(np.array([1, 1, 1, 16], np.int32)), 16)
    assert choose_batching(skew) == "ranked"          # skew 16 >= 4
    flat = tile_plan(jnp.asarray(np.array([8, 12, 16], np.int32)), 16)
    assert choose_batching(flat) == "flat"            # skew 2 < 4
    empty = tile_plan(jnp.asarray(np.zeros(0, np.int32)), 16)
    assert choose_batching(empty) == "flat"
    zeros = tile_plan(jnp.asarray(np.zeros(5, np.int32)), 16)
    assert choose_batching(zeros) == "flat"


def test_resolve_batching_auto_needs_ranks():
    with pytest.raises(ValueError, match="auto"):
        resolve_batching("auto")
    assert resolve_batching("flat") == "flat"
    assert resolve_batching(None) == "flat"
    L = _skewed_lower()
    assert resolve_batching("auto", L.ranks, L.r_max) in ("flat", "ranked")


def test_resolve_policy_record():
    L = _skewed_lower()
    plan = tile_plan(L.ranks, L.r_max)
    pol = resolve_policy("auto", plan, b=L.b)
    assert pol["requested"] == "auto"
    assert pol["batching"] == choose_batching(plan)
    assert pol["rank_skew"] == pytest.approx(plan.rank_skew)
    assert pol["padded_flop_ratio"] == pytest.approx(plan.padded_flop_ratio())
    assert pol["right_flush"] >= 1
    # explicit knobs pass through but keep the audit record
    pol2 = resolve_policy("flat", plan, b=L.b, right_flush=3)
    assert pol2["batching"] == "flat" and pol2["right_flush"] == 3
    with pytest.raises(ValueError):
        resolve_policy("bogus", plan, b=L.b)


def test_factorization_stats_record_policy():
    _, K = covariance_problem(256, 2, 32)
    K = np.asarray(K) + 1e-2 * np.eye(256)
    op = TLROperator.compress(jnp.asarray(K), 32, 32, 1e-6)
    for algo in ("left", "right"):
        fact = op.cholesky(CholOptions(eps=1e-6, bs=8, algo=algo))
        pol = fact.stats["policy"]
        assert pol["requested"] == "auto"
        assert pol["batching"] == fact.stats["batching"]
        assert "padded_flop_ratio" in pol and "rank_skew" in pol
        assert pol["flops_flat"] >= pol["flops_ranked"] > 0


# -- ranked-vs-flat parity on the read paths -----------------------------------


@pytest.mark.parametrize("trans", [False, True])
@pytest.mark.parametrize("nrhs", [None, 4])
def test_trsm_ranked_matches_flat_and_reference(trans, nrhs):
    L = _skewed_lower()
    rng = np.random.default_rng(2)
    y = rng.standard_normal(L.n) if nrhs is None else rng.standard_normal(
        (L.n, nrhs))
    yj = jnp.asarray(y)
    x_r = np.asarray(tlr_trsv(L, yj, trans=trans, batching="ranked"))
    x_f = np.asarray(tlr_trsv(L, yj, trans=trans, batching="flat"))
    x_ref = np.asarray(tlr_trsv_reference(L, yj, trans=trans))
    assert x_r.shape == y.shape
    np.testing.assert_allclose(x_r, x_ref, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(x_f, x_ref, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("nrhs", [None, 3])
def test_matvec_ranked_matches_flat(nrhs):
    A = _skewed_sym()
    rng = np.random.default_rng(3)
    x = rng.standard_normal(A.n) if nrhs is None else rng.standard_normal(
        (A.n, nrhs))
    xj = jnp.asarray(x)
    y_r = np.asarray(tlr_matvec(A, xj, batching="ranked"))
    y_f = np.asarray(tlr_matvec(A, xj, batching="flat"))
    assert y_r.shape == x.shape
    np.testing.assert_allclose(y_r, y_f, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("trans", [False, True])
def test_tri_matvec_ranked_matches_flat(trans):
    L = _skewed_lower()
    x = jnp.asarray(np.random.default_rng(4).standard_normal((L.n, 2)))
    y_r = np.asarray(tlr_tri_matvec(L, x, trans=trans, batching="ranked"))
    y_f = np.asarray(tlr_tri_matvec(L, x, trans=trans, batching="flat"))
    np.testing.assert_allclose(y_r, y_f, rtol=1e-12, atol=1e-12)


def test_sample_runs_through_plan_dispatch():
    """fact.sample rides tri_matvec's plan dispatch; parity via the tri
    product itself (sampling is L z, a deterministic function of z)."""
    _, K = covariance_problem(256, 2, 32)
    K = np.asarray(K) + 1e-1 * np.eye(256)
    op = TLROperator.compress(jnp.asarray(K), 32, 32, 1e-8)
    fact = op.cholesky(CholOptions(eps=1e-8, bs=8))
    s = fact.sample(jax.random.PRNGKey(0), num=3)
    assert s.shape == (256, 3) and np.isfinite(np.asarray(s)).all()
    L = fact.L
    z = jnp.asarray(np.random.default_rng(5).standard_normal((256, 2)))
    np.testing.assert_allclose(
        np.asarray(tlr_tri_matvec(L, z, batching="ranked")),
        np.asarray(tlr_tri_matvec(L, z, batching="flat")),
        rtol=1e-12, atol=1e-12)


def test_zero_rank_reads_skip_plan_kernels():
    """An all-zero-rank operator's ranked matvec compiles no plan cores:
    the zero bucket never touches a kernel (it is diag-only)."""
    nb, b = 4, 8
    rng = np.random.default_rng(6)
    D = rng.standard_normal((nb, b, b))
    D = D + np.swapaxes(D, 1, 2)
    nt = num_tiles(nb)
    A = TLRMatrix(D=jnp.asarray(D), U=jnp.zeros((nt, b, b)),
                  V=jnp.zeros((nt, b, b)),
                  ranks=jnp.zeros(nt, jnp.int32))
    x = jnp.asarray(rng.standard_normal(A.n))
    snap = trace_counts()
    y = tlr_matvec(A, x, batching="ranked")
    assert trace_counts_diff(snap) == {}  # zero ranks touch no plan kernel
    want = np.zeros(A.n)
    for k in range(nb):
        want[k * b:(k + 1) * b] = D[k] @ np.asarray(x)[k * b:(k + 1) * b]
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-10, atol=1e-10)


# -- unified trace registry: the compile-count contract ------------------------


def test_unified_registry_keys_and_views():
    from repro.core import algebra, batching, solve

    counts = trace_counts()
    assert set(counts) <= {"trsm", "algebra", "batching", "plan"}
    assert trace_count() == sum(counts.values())
    assert solve.trsm_trace_count() == trace_count("trsm")
    assert algebra.algebra_trace_count() == trace_count("algebra")
    assert batching.batching_trace_count() == trace_count("batching")


def test_plan_core_compile_count_pinned():
    """Repeated ranked reads on one plan retrace nothing; a fresh run
    compiles at most (#buckets) sym-chain variants per rhs shape."""
    L = _skewed_lower(nb=8, b=16, seed=7)
    A = _skewed_sym(nb=8, b=16, seed=7)
    plan = tile_plan(A.ranks, A.r_max)
    x = jnp.asarray(np.random.default_rng(8).standard_normal(A.n))
    snap = trace_counts()
    tlr_matvec(A, x, batching="ranked")
    compiled = trace_counts_diff(snap).get("plan", 0)
    assert 0 < compiled <= len(plan.buckets)
    warm = trace_counts()
    tlr_matvec(A, x + 1.0, batching="ranked")
    tlr_matvec(A, 2.0 * x, batching="ranked")
    assert trace_counts_diff(warm) == {}   # steady state: zero retraces


def test_trsm_ranked_compile_count_additive():
    """Ranked TRSM keeps the flat path's jit-cache contract: at most one
    column-step variant per (row-bucket ladder entry, direction) -- the
    width ladder multiplies nothing."""
    L = _skewed_lower(nb=16, b=8, r_max=8, seed=9)
    ladder_len = int(math.log2(L.nb - 1)) + 2
    y = jnp.asarray(np.random.default_rng(10).standard_normal(L.n))
    snap = trace_counts()
    tlr_trsv(L, y, trans=False, batching="ranked")
    tlr_trsv(L, y, trans=True, batching="ranked")
    compiled = trace_counts_diff(snap).get("trsm", 0)
    assert 0 < compiled <= 2 * ladder_len
    warm = trace_counts()
    tlr_trsv(L, y + 1.0, trans=False, batching="ranked")
    assert trace_counts_diff(warm) == {}


# -- pcg check_every -----------------------------------------------------------


def _spd_problem(n=128, seed=11):
    rng = np.random.default_rng(seed)
    M = rng.standard_normal((n, n))
    A = M @ M.T + n * np.eye(n)
    b = rng.standard_normal(n)
    return jnp.asarray(A), jnp.asarray(b)


def test_pcg_check_every_identical_history():
    """The device op sequence per iteration is unchanged, so the iterate
    history is bit-for-bit identical for every ``check_every``."""
    A, b = _spd_problem()
    mv = lambda v: A @ v
    x1, it1, h1 = pcg(mv, b, tol=1e-10, maxiter=60, check_every=1)
    for ce in (2, 5, 16, 1000):
        xc, itc, hc = pcg(mv, b, tol=1e-10, maxiter=60, check_every=ce)
        assert itc == it1
        assert list(hc) == list(h1)        # bitwise-equal floats
        np.testing.assert_array_equal(np.asarray(xc), np.asarray(x1))
        assert hc.breakdown is None


def test_pcg_check_every_breakdown_parity():
    """Mid-window breakdowns replay to the exact per-iteration stopping
    point: same breakdown tag, same history, same final iterate."""
    n = 64
    rng = np.random.default_rng(12)
    M = rng.standard_normal((n, n))
    A = jnp.asarray(-(M @ M.T) - n * np.eye(n))    # negative definite
    b = jnp.asarray(rng.standard_normal(n))
    mv = lambda v: A @ v
    x1, it1, h1 = pcg(mv, b, tol=1e-12, maxiter=30, check_every=1)
    assert h1.breakdown == "indefinite_curvature"
    for ce in (3, 7, 30):
        xc, itc, hc = pcg(mv, b, tol=1e-12, maxiter=30, check_every=ce)
        assert hc.breakdown == h1.breakdown
        assert itc == it1 and list(hc) == list(h1)
        np.testing.assert_array_equal(np.asarray(xc), np.asarray(x1))


def test_pcg_check_every_converged_tail_not_overrun():
    """Convergence inside a window stops at the converged iterate: no
    history entries past the tolerance crossing."""
    A, b = _spd_problem(seed=13)
    mv = lambda v: A @ v
    _, it1, h1 = pcg(mv, b, tol=1e-8, maxiter=200, check_every=1)
    _, itc, hc = pcg(mv, b, tol=1e-8, maxiter=200, check_every=64)
    assert itc == it1 and len(hc) == len(h1)
    assert hc[-1] < 1e-8
    assert all(v >= 1e-8 for v in list(hc)[1:-1])


def test_pcg_zero_and_histories_are_pcghistory():
    A, b = _spd_problem(seed=14)
    x, it, h = pcg(lambda v: A @ v, jnp.zeros_like(b), check_every=8)
    assert it == 0 and isinstance(h, PCGHistory) and h == []


def test_pcg_scalar_maxiter_not_multiple_of_window():
    """maxiter that is not a multiple of check_every stops at exactly
    maxiter iterations (the window clamps to the remaining budget)."""
    A, b = _spd_problem(seed=15)
    mv = lambda v: A @ v
    x1, it1, h1 = pcg(mv, b, tol=1e-30, maxiter=10, check_every=1)
    assert it1 == 10 and len(h1) == 11
    for ce in (3, 4, 7, 64):
        xc, itc, hc = pcg(mv, b, tol=1e-30, maxiter=10, check_every=ce)
        assert itc == 10 and len(hc) == 11
        assert list(hc) == list(h1)
        np.testing.assert_array_equal(np.asarray(xc), np.asarray(x1))


# -- multi-RHS TRSM through the plan + batched-RHS pcg (PR 7) ------------------


def test_trsm_multirhs_ranked_compile_count_additive():
    """An (n, k) RHS rides the same plan bucket widths as the vector path:
    at most one column-step variant per (ladder entry, direction) for the
    new RHS shape, zero retraces steady-state, and no dependence on k
    beyond the one shape."""
    L = _skewed_lower(nb=16, b=8, r_max=8, seed=16)
    ladder_len = int(math.log2(L.nb - 1)) + 2
    Y = jnp.asarray(np.random.default_rng(17).standard_normal((L.n, 8)))
    snap = trace_counts()
    tlr_trsv(L, Y, trans=False, batching="ranked")
    tlr_trsv(L, Y, trans=True, batching="ranked")
    compiled = trace_counts_diff(snap).get("trsm", 0)
    assert 0 < compiled <= 2 * ladder_len
    warm = trace_counts()
    tlr_trsv(L, Y + 1.0, trans=False, batching="ranked")
    tlr_trsv(L, 2.0 * Y, trans=True, batching="ranked")
    assert trace_counts_diff(warm) == {}   # steady state: zero retraces
    # ranked multi-RHS parity against the reference sweep
    np.testing.assert_allclose(
        np.asarray(tlr_trsv(L, Y, trans=False, batching="ranked")),
        np.asarray(tlr_trsv_reference(L, Y, trans=False)),
        rtol=1e-12, atol=1e-12)


def test_pcg_batched_matches_scalar_per_column():
    """(n, k) right-hand sides run per-column CG: every column's iteration
    count and history match its own scalar pcg run (same recurrence, same
    stopping rules; reduction order differs so equality is to round-off)."""
    A, _ = _spd_problem(seed=18)
    mv = lambda v: A @ v
    rng = np.random.default_rng(19)
    B = jnp.asarray(rng.standard_normal((A.shape[0], 4)))
    X, iters, hists = pcg(mv, B, tol=1e-8, maxiter=200, check_every=8)
    assert X.shape == B.shape and iters.shape == (4,) and len(hists) == 4
    for j in range(4):
        xj, itj, hj = pcg(mv, B[:, j], tol=1e-8, maxiter=200, check_every=8)
        assert int(iters[j]) == itj
        assert hists[j].breakdown is None and hj.breakdown is None
        np.testing.assert_allclose(list(hists[j]), list(hj),
                                   rtol=1e-6, atol=1e-14)
        np.testing.assert_allclose(np.asarray(X[:, j]), np.asarray(xj),
                                   rtol=1e-8, atol=1e-12)


def test_pcg_batched_per_column_tolerance():
    """tol may be a (k,) array: each column stops at its own threshold --
    the loose column evicts early, the tight column keeps iterating (the
    serve path's per-request tolerance rides on this)."""
    A, _ = _spd_problem(seed=20)
    mv = lambda v: A @ v
    b = np.random.default_rng(21).standard_normal(A.shape[0])
    B = jnp.asarray(np.stack([b, b], axis=1))
    X, iters, hists = pcg(mv, B, tol=np.array([1e-2, 1e-10]), maxiter=200,
                          check_every=4)
    assert int(iters[0]) < int(iters[1])
    assert hists[0][-1] < 1e-2 and hists[1][-1] < 1e-10
    for j, tol in enumerate((1e-2, 1e-10)):
        _, itj, _ = pcg(mv, B[:, j], tol=tol, maxiter=200, check_every=4)
        assert int(iters[j]) == itj


def test_pcg_batched_per_column_breakdown():
    """A breakdown freezes only its own column: the healthy column keeps
    iterating to convergence while the indefinite one stops with the same
    tag its scalar run reports."""
    n = 64
    rng = np.random.default_rng(22)
    M = rng.standard_normal((n, n))
    Apos = jnp.asarray(M @ M.T + n * np.eye(n))
    Aneg = -Apos
    mv = lambda V: jnp.stack([Apos @ V[:, 0], Aneg @ V[:, 1]], axis=1)
    B = jnp.asarray(rng.standard_normal((n, 2)))
    X, iters, hists = pcg(mv, B, tol=1e-8, maxiter=50, check_every=4)
    assert hists[0].breakdown is None and hists[0][-1] < 1e-8
    assert hists[1].breakdown == "indefinite_curvature"
    x0, it0, h0 = pcg(lambda v: Apos @ v, B[:, 0], tol=1e-8, maxiter=50,
                      check_every=4)
    x1, it1, h1 = pcg(lambda v: Aneg @ v, B[:, 1], tol=1e-8, maxiter=50,
                      check_every=4)
    assert int(iters[0]) == it0 and int(iters[1]) == it1
    np.testing.assert_allclose(np.asarray(X[:, 0]), np.asarray(x0),
                               rtol=1e-10, atol=1e-12)
    np.testing.assert_array_equal(np.asarray(X[:, 1]), np.asarray(x1))


def test_pcg_batched_maxiter_window_guard():
    """Per-column budgets that are not multiples of the window stop at
    exactly maxiter iterations (stop_at + replay, never an overrun)."""
    A, _ = _spd_problem(seed=23)
    mv = lambda v: A @ v
    B = jnp.asarray(np.random.default_rng(24).standard_normal(
        (A.shape[0], 3)))
    X, iters, hists = pcg(mv, B, tol=1e-30, maxiter=10, check_every=4)
    np.testing.assert_array_equal(np.asarray(iters), [10, 10, 10])
    assert all(len(h) == 11 for h in hists)


def test_pcg_batched_zero_column():
    """A zero column completes instantly (x = 0, empty history) without
    touching the recurrence; live columns are unaffected."""
    A, b = _spd_problem(seed=25)
    mv = lambda v: A @ v
    B = jnp.stack([b, jnp.zeros_like(b)], axis=1)
    X, iters, hists = pcg(mv, B, tol=1e-8, maxiter=200, check_every=8)
    assert int(iters[1]) == 0 and hists[1] == []
    np.testing.assert_array_equal(np.asarray(X[:, 1]), 0.0)
    assert hists[0][-1] < 1e-8
