"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and finiteness (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, supported_shapes
from repro.models import (
    ModelConfig, ShapeSpec, build_loss_fn, build_prefill_fn,
    build_serve_step, init_decode_caches, init_model, materialize_inputs,
)
from repro.models.api import _enc_len, input_specs


def _smoke_shape(kind: str) -> ShapeSpec:
    if kind == "train":
        return ShapeSpec("smoke_train", seq_len=64, global_batch=2, kind="train")
    if kind == "prefill":
        return ShapeSpec("smoke_prefill", seq_len=64, global_batch=2,
                         kind="prefill")
    return ShapeSpec("smoke_decode", seq_len=64, global_batch=2, kind="decode")


def _materialize(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, spec)

    def make(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = max(2, cfg.vocab_size - 1) if s.shape else 63
            return jnp.asarray(rng.integers(0, hi, s.shape), s.dtype)
        return jnp.asarray(rng.standard_normal(s.shape) * 0.02, s.dtype)

    return jax.tree.map(make, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _materialize(cfg, _smoke_shape("train"))
    loss_fn = build_loss_fn(cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g))), grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"
    # loss starts near ln(V) for random init
    assert float(loss) < 3 * np.log(cfg.vocab_size) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _materialize(cfg, _smoke_shape("prefill"))
    logits = build_prefill_fn(cfg)(params, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 64
    caches = init_decode_caches(cfg, B, S, ctx_len=_enc_len(cfg, S))
    token = jnp.ones((B, 1), jnp.int32)
    logits, new_caches = build_serve_step(cfg)(
        params, caches, token, jnp.asarray(3, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure preserved
    jax.tree.map(lambda a, b: (a.shape, a.dtype) == (b.shape, b.dtype)
                 or (_ for _ in ()).throw(AssertionError("cache mismatch")),
                 caches, new_caches)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_analytic_matches(arch):
    """cfg.param_count() agrees with the actual initialized tree."""
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    expect = cfg.param_count()
    assert abs(actual - expect) / max(actual, 1) < 0.05, (
        f"{arch}: analytic {expect} vs actual {actual}")


def test_supported_shapes_assignment():
    """long_500k runs exactly for the ssm/hybrid archs (DESIGN section 5)."""
    long_archs = {a for a in ARCHS
                  if "long_500k" in supported_shapes(get_config(a))}
    assert long_archs == {"jamba_v0_1_52b", "mamba2_130m"}


def test_full_configs_param_counts():
    """Full (published) configs land near their nameplate sizes."""
    expect = {
        "jamba_v0_1_52b": (45e9, 60e9),
        "qwen1_5_0_5b": (0.3e9, 0.7e9),
        "mistral_nemo_12b": (10e9, 14e9),
        "stablelm_1_6b": (1.2e9, 2.2e9),
        "phi3_mini_3_8b": (3.2e9, 4.5e9),
        "llama4_maverick_400b_a17b": (340e9, 440e9),
        "granite_moe_3b_a800m": (2.4e9, 4.2e9),
        "mamba2_130m": (0.1e9, 0.2e9),
        "llama_3_2_vision_90b": (80e9, 110e9),
        "whisper_large_v3": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("llama4_maverick_400b_a17b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < 0.15 * total  # ~17B of 400B
