"""The TLR inference server (repro.serve; DESIGN.md section 10):
batched-vs-sequential parity for every request kind, eviction/refill
invariants under a randomized schedule, the zero-recompile-after-warmup
pin via the unified trace registry, per-request tolerances, multi-resident
routing, and submit-time validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TLROperator, trace_counts, trace_counts_diff
from repro.serve import (
    KINDS, RequestQueue, ServeRequest, ServerStats, TLRServer,
)


# -- fixtures ------------------------------------------------------------------


N, B = 128, 32


def _spd(n=N, seed=0, shift=2.0):
    rng = np.random.default_rng(seed)
    M = rng.standard_normal((n, n))
    return M @ M.T / n + shift * np.eye(n)


@pytest.fixture(scope="module")
def problem():
    A = _spd()
    op = TLROperator.compress(jnp.asarray(A), B, eps=1e-10)
    fact = op.cholesky()
    return A, op, fact


@pytest.fixture(scope="module")
def iterative_problem():
    """PCG that actually iterates: the resident factorization comes from a
    heavily truncated compression (a genuine TLR *preconditioner*), the
    operator is near-exact -- so per-request tolerances spread the
    iteration counts instead of everything converging in one step."""
    A = _spd(seed=4)
    op = TLROperator.compress(jnp.asarray(A), B, eps=1e-10)
    loose = TLROperator.compress(jnp.asarray(A), B, eps=0.5)
    return A, op, loose.cholesky()


def _mixed_requests(n, count, seed=100):
    """A deterministic mixed schedule cycling through every kind."""
    rng = np.random.default_rng(seed)
    reqs = []
    for k in range(count):
        kind = KINDS[k % len(KINDS)]
        rhs = (rng.standard_normal(n)
               if kind in ("solve", "pcg_solve") else None)
        reqs.append(ServeRequest(kind, rhs=rhs, tol=1e-8, maxiter=150,
                                 seed=k))
    return reqs


# -- parity + the no-recompile pin (the acceptance drain) ----------------------


def test_mixed_drain_parity_and_zero_recompiles(problem):
    """>= 32 mixed requests drain with zero recompiles after warmup and
    every batched result matches its sequential counterpart."""
    A, op, fact = problem
    srv = fact.serve(operator=op, slots=8, check_every=4)
    snap = trace_counts()                 # closed executable set post-warmup
    reqs = _mixed_requests(N, 36)
    rids = [srv.submit(r) for r in reqs]
    results = srv.run()
    assert trace_counts_diff(snap) == {}  # the fixed-shape guarantee
    assert len(results) == 36 and srv.pending == 0 and srv.active == 0
    for r, rid in zip(reqs, rids):
        out = results[rid]
        assert out.kind == r.kind and out.rid == rid
        if r.kind == "solve":
            ref = np.asarray(fact.solve(jnp.asarray(r.rhs)))
            np.testing.assert_allclose(out.value, ref, rtol=1e-12,
                                       atol=1e-12)
        elif r.kind == "logdet":
            assert out.value == pytest.approx(float(fact.logdet()),
                                              abs=1e-12)
        elif r.kind == "sample":
            ref = np.asarray(fact.sample(jax.random.PRNGKey(r.seed), 1))
            np.testing.assert_allclose(out.value, ref, rtol=1e-12,
                                       atol=1e-12)
        else:                              # pcg_solve vs the dense solve
            assert out.converged and out.breakdown is None
            ref = np.linalg.solve(A, r.rhs)
            np.testing.assert_allclose(out.value, ref, rtol=1e-5,
                                       atol=1e-6)
            assert out.iterations > 0
            assert out.history[-1] < 1e-8


def test_stats_record(problem):
    A, op, fact = problem
    srv = fact.serve(operator=op, slots=4, check_every=4)
    for r in _mixed_requests(N, 16, seed=101):
        srv.submit(r)
    srv.run()
    st = srv.stats
    assert st.completed == st.admitted == 16
    # slot-ticks conservation: every occupied slot-tick belongs to exactly
    # one request's residency
    assert sum(st.tick_active) == sum(res.ticks
                                      for res in srv.results.values())
    assert 0.0 < st.occupancy() <= 1.0
    summ = st.summary()
    assert summ["slots"] == 4 and summ["completed"] == 16
    assert summ["latency"]["count"] == 16
    assert summ["latency"]["p99_s"] >= summ["latency"]["p50_s"] > 0.0
    for kind in KINDS:
        assert summ[f"latency_{kind}"]["count"] == 4
    assert all(res.latency_s > 0 and res.ticks >= 1
               for res in srv.results.values())


# -- eviction / refill invariants under a randomized schedule ------------------


def test_eviction_refill_invariants_randomized(problem):
    """Random interleaving of submits and ticks: occupancy never exceeds
    the slot count, direct kinds complete in their admission tick, every
    request completes exactly once, and admission follows FIFO order."""
    A, op, fact = problem
    rng = np.random.default_rng(7)
    srv = fact.serve(operator=op, slots=3, check_every=2)
    reqs = _mixed_requests(N, 24, seed=102)
    pending = list(reqs)
    submitted = []
    while pending or srv.pending or srv.active:
        if pending and (rng.random() < 0.6 or not (srv.pending
                                                   or srv.active)):
            burst = rng.integers(1, 5)
            for r in pending[:burst]:
                submitted.append(srv.submit(r))
            pending = pending[burst:]
        else:
            srv.tick()
        assert srv.active <= srv.slots
        assert all(a <= srv.slots for a in srv.stats.tick_active)
    results = srv.run()
    assert sorted(results) == sorted(submitted)   # exactly-once completion
    for r in reqs:
        out = results[r.rid]
        if r.kind in ("solve", "logdet", "sample"):
            assert out.ticks == 1                  # admission-tick completion
        else:
            assert out.ticks >= 1 and out.converged
    # FIFO: within one kind, completion order follows submission order for
    # the direct kinds (they finish the tick they are admitted)
    for kind in ("solve", "logdet", "sample"):
        rids = [r.rid for r in reqs if r.kind == kind]
        by_first_tick = sorted(rids, key=lambda q: results[q].ticks)
        assert rids == sorted(rids) == sorted(by_first_tick)


def test_slot_starvation_free_under_long_pcg(iterative_problem):
    """A slow pcg request does not stall the block: direct requests stream
    through the remaining slots while it iterates."""
    A, op, fact = iterative_problem
    srv = fact.serve(operator=op, slots=2, check_every=1)
    rng = np.random.default_rng(8)
    slow = ServeRequest("pcg_solve", rhs=rng.standard_normal(N), tol=1e-12,
                        maxiter=200)
    srv.submit(slow)
    quick = [ServeRequest("solve", rhs=rng.standard_normal(N))
             for _ in range(4)]
    for r in quick:
        srv.submit(r)
    results = srv.run()
    assert results[slow.rid].ticks > 1
    assert all(results[r.rid].ticks == 1 for r in quick)
    # the quick stream drained long before the slow request finished
    assert max(results[r.rid].ticks for r in quick) == 1


# -- per-request tolerance / iteration budgets ---------------------------------


def test_per_request_tolerance_and_budget(iterative_problem):
    A, op, fact = iterative_problem
    rng = np.random.default_rng(9)
    b = rng.standard_normal(N)
    srv = fact.serve(operator=op, slots=4, check_every=4)
    loose = ServeRequest("pcg_solve", rhs=b, tol=1e-2)
    tight = ServeRequest("pcg_solve", rhs=b, tol=1e-11)
    capped = ServeRequest("pcg_solve", rhs=b, tol=1e-30, maxiter=3)
    for r in (loose, tight, capped):
        srv.submit(r)
    results = srv.run()
    lo, hi, cap = (results[r.rid] for r in (loose, tight, capped))
    assert lo.iterations < hi.iterations
    assert lo.history[-1] < 1e-2 and hi.history[-1] < 1e-11
    assert cap.iterations == 3 and not cap.converged
    for res in (lo, hi):
        rel = np.linalg.norm(A @ res.value - b) / np.linalg.norm(b)
        assert rel < (1e-2 if res is lo else 1e-10)


# -- multi-resident routing ----------------------------------------------------


def test_multi_factorization_routing():
    A1, A2 = _spd(seed=1), _spd(seed=2, shift=3.0)
    op1 = TLROperator.compress(jnp.asarray(A1), B, eps=1e-10)
    op2 = TLROperator.compress(jnp.asarray(A2), B, eps=1e-10)
    f1, f2 = op1.cholesky(), op2.cholesky()
    srv = TLRServer(slots=4, check_every=4)
    srv.register("a", f1, operator=op1)
    srv.register("b", f2, operator=op2)
    srv.warmup()
    rng = np.random.default_rng(10)
    y = rng.standard_normal(N)
    with pytest.raises(ValueError, match="fid is required"):
        srv.submit(ServeRequest("solve", rhs=y))
    ra = ServeRequest("solve", rhs=y, fid="a")
    rb = ServeRequest("solve", rhs=y, fid="b")
    rl = ServeRequest("logdet", fid="b")
    for r in (ra, rb, rl):
        srv.submit(r)
    results = srv.run()
    np.testing.assert_allclose(results[ra.rid].value,
                               np.asarray(f1.solve(jnp.asarray(y))),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(results[rb.rid].value,
                               np.asarray(f2.solve(jnp.asarray(y))),
                               rtol=1e-12, atol=1e-12)
    assert results[rl.rid].value == pytest.approx(float(f2.logdet()))
    with pytest.raises(ValueError, match="already registered"):
        srv.register("a", f1)


# -- validation / error paths --------------------------------------------------


def test_submit_validation(problem):
    A, op, fact = problem
    srv = TLRServer(slots=2)
    srv.register("f", fact)               # no operator: pcg unavailable
    y = np.ones(N)
    with pytest.raises(ValueError, match="unknown request kind"):
        srv.submit(ServeRequest("inverse", rhs=y))
    with pytest.raises(ValueError, match="requires rhs"):
        srv.submit(ServeRequest("solve"))
    with pytest.raises(ValueError, match="rhs length"):
        srv.submit(ServeRequest("solve", rhs=np.ones(N + 1)))
    with pytest.raises(ValueError, match="registered with its operator"):
        srv.submit(ServeRequest("pcg_solve", rhs=y))
    with pytest.raises(ValueError, match="unknown factorization"):
        srv.submit(ServeRequest("solve", rhs=y, fid="nope"))
    with pytest.raises(KeyError):
        srv.result(123)
    assert srv.pending == 0               # nothing invalid was enqueued


def test_sample_requires_cholesky():
    Ad = _spd(n=64, seed=3)
    op = TLROperator.compress(jnp.asarray(Ad), 32, eps=1e-10)
    fact = op.ldlt()
    srv = TLRServer(slots=2)
    srv.register("f", fact)
    with pytest.raises(ValueError, match="Cholesky"):
        srv.submit(ServeRequest("sample"))
    # solve / logdet still serve fine off an LDL^T resident
    y = np.ones(64)
    r = ServeRequest("solve", rhs=y)
    srv.submit(r)
    results = srv.run()
    np.testing.assert_allclose(results[r.rid].value,
                               np.asarray(fact.solve(jnp.asarray(y))),
                               rtol=1e-10, atol=1e-10)


def test_request_queue_fifo():
    q = RequestQueue()
    rids = [q.submit(ServeRequest("logdet")) for _ in range(3)]
    assert rids == [0, 1, 2] and len(q) == 3
    assert q.peek().rid == 0
    assert [q.pop().rid for _ in range(3)] == rids
    assert q.pop() is None and not q


def test_server_stats_empty():
    st = ServerStats(slots=4)
    assert st.occupancy() == 0.0
    assert st.latency_percentiles()["count"] == 0
    assert st.summary()["requests_per_s"] == 0.0