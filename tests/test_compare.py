"""``benchmarks/compare.py`` error reporting (ISSUE 10 satellite): a
missing, corrupt, or schema-drifted bench file must fail with an
actionable message -- which file, which record, which key, and the exact
command that regenerates it -- never a bare traceback."""

import json

import pytest

from benchmarks.compare import (
    BenchFileError, load_payload, main, parse_derived,
)


GOOD = {
    "bench_scale": 1.0,
    "topology": {"device_count": 1, "backend": "cpu", "mesh": None,
                 "lookahead": False},
    "records": [
        {"name": "suite/a", "us_per_call": 10.0,
         "derived": "x=1;padded_flop_ratio=1.2"},
    ],
}


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(payload if isinstance(payload, str)
                 else json.dumps(payload))
    return str(p)


def test_happy_path_exits_zero(tmp_path, capsys):
    p = _write(tmp_path, "BENCH_x.json", GOOD)
    assert main([p, p]) == 0
    assert "0 failure(s)" in capsys.readouterr().out


def test_missing_file_names_file_and_regen_command(tmp_path, capsys):
    missing = str(tmp_path / "BENCH_faults.json")
    current = _write(tmp_path, "BENCH_cur.json", GOOD)
    assert main([missing, current]) == 2
    out = capsys.readouterr().out
    assert missing in out
    assert "does not exist" in out
    assert "--suite faults" in out          # regen command recovered from name


def test_corrupt_json_names_location(tmp_path, capsys):
    bad = _write(tmp_path, "BENCH_x.json", '{"records": [trunca')
    good = _write(tmp_path, "BENCH_y.json", GOOD)
    assert main([bad, good]) == 2
    out = capsys.readouterr().out
    assert bad in out and "not valid JSON" in out and "line 1" in out


def test_missing_records_key_names_actual_keys(tmp_path, capsys):
    bad = _write(tmp_path, "BENCH_x.json", {"rows": []})
    good = _write(tmp_path, "BENCH_y.json", GOOD)
    assert main([bad, good]) == 2
    out = capsys.readouterr().out
    assert "no 'records' key" in out and "'rows'" in out


def test_schema_drift_names_record_and_keys(tmp_path, capsys):
    drift = dict(GOOD)
    drift["records"] = [{"name": "suite/a", "us_per_call": 1.0},
                        {"name": "suite/b", "us_per_call": 1.0,
                         "derived": ""}]
    bad = _write(tmp_path, "BENCH_x.json", drift)
    good = _write(tmp_path, "BENCH_y.json", GOOD)
    assert main([bad, good]) == 2
    out = capsys.readouterr().out
    assert "'suite/a'" in out               # *which* record
    assert "'derived'" in out               # *which* key
    assert "schema drift" in out


def test_role_distinguishes_baseline_from_current(tmp_path, capsys):
    base = _write(tmp_path, "BENCH_x.json", GOOD)
    assert main([base, str(tmp_path / "BENCH_y.json")]) == 2
    assert "current run" in capsys.readouterr().out


def test_load_payload_raises_typed_error(tmp_path):
    with pytest.raises(BenchFileError, match="does not exist"):
        load_payload(str(tmp_path / "nope.json"))
    top_list = _write(tmp_path, "BENCH_l.json", [1, 2])
    with pytest.raises(BenchFileError, match="JSON list"):
        load_payload(top_list)


def test_regressions_still_detected(tmp_path, capsys):
    """The error handling didn't soften the diff itself: a lost record and
    a rising padded_flop_ratio still hard-fail."""
    cur = dict(GOOD)
    cur["records"] = [{"name": "suite/a", "us_per_call": 10.0,
                       "derived": "x=1;padded_flop_ratio=1.5"}]
    base = dict(GOOD)
    base["records"] = GOOD["records"] + [
        {"name": "suite/gone", "us_per_call": 5.0, "derived": ""}]
    b = _write(tmp_path, "BENCH_b.json", base)
    c = _write(tmp_path, "BENCH_c.json", cur)
    assert main([b, c]) == 1
    out = capsys.readouterr().out
    assert "missing record" in out and "padded_flop_ratio" in out


def test_parse_derived_roundtrip():
    d = parse_derived("a=1.5;b=text;c=2")
    assert d == {"a": 1.5, "b": "text", "c": 2.0}
