"""Batched TLR tile algebra (core/algebra.py) and the Newton-Schulz
preconditioner (core/precond.py).

The deterministic tests always run; the hypothesis property tests ride
along when hypothesis is installed (same pattern as test_properties.py,
but scoped per-test so the load-bearing assertions here -- dense parity,
the trace-count contract, the acceptance-scale GEMM -- never skip.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, TLROperator, TLRTiles, algebra_trace_count, exp_covariance,
    generalize, kd_tree_ordering, num_tiles, offd_index, offd_pairs, pcg,
    symmetrize, tlr_add_diag, tlr_axpy, tlr_gemm, tlr_newton_schulz,
    tlr_round, tlr_scale, tlr_syrk, tlr_transpose,
)

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    HYP_SET = dict(deadline=None, max_examples=6,
                   suppress_health_check=[HealthCheck.too_slow,
                                          HealthCheck.data_too_large])
except ImportError:  # hypothesis optional: deterministic tests still run
    HAVE_HYPOTHESIS = False


def _spd_operator(seed, nb, b, eps=1e-10, kind="random"):
    rng = np.random.default_rng(seed)
    n = nb * b
    if kind == "random":
        M = rng.standard_normal((n, n)) / np.sqrt(n)
        A = M @ M.T + np.eye(n)
    else:
        pts = rng.random((n, 3))
        A = exp_covariance(pts[kd_tree_ordering(pts, b)], 0.3)
    return TLROperator.compress(jnp.asarray(A), b, b, eps)


# -- structured ops -----------------------------------------------------------


def test_axpy_exact_concat_and_round():
    opA = _spd_operator(0, 4, 32)
    opB = _spd_operator(1, 4, 32)
    Ad, Bd = np.asarray(opA.to_dense()), np.asarray(opB.to_dense())
    S = tlr_axpy(2.0, opA.A, opB.A)
    # exact: ranks add, r_max doubles, dense parity to machine precision
    assert S.r_max == opA.r_max + opB.r_max
    np.testing.assert_allclose(np.asarray(S.to_dense()), 2 * Ad + Bd,
                               rtol=1e-13, atol=1e-13)
    # rounded: error bounded by the threshold, storage back to one r_max
    Sr = tlr_axpy(2.0, opA.A, opB.A, eps=1e-8)
    assert Sr.r_max == min(S.r_max, opA.b)
    err = np.linalg.norm(np.asarray(Sr.to_dense()) - (2 * Ad + Bd))
    assert err < 1e-6


def test_axpy_rejects_mismatched_structures():
    opA = _spd_operator(0, 4, 32)
    opB = _spd_operator(1, 2, 32)
    with pytest.raises(ValueError, match="matching structures"):
        tlr_axpy(1.0, opA.A, opB.A)
    with pytest.raises(ValueError, match="matching structures"):
        tlr_axpy(1.0, opA.A, generalize(opA.A))


def test_scale_and_add_diag():
    op = _spd_operator(2, 3, 32)
    Ad = np.asarray(op.to_dense())
    np.testing.assert_allclose(np.asarray(tlr_scale(-0.5, op.A).to_dense()),
                               -0.5 * Ad, rtol=1e-13, atol=1e-13)
    shifted = tlr_add_diag(op.A, 3.0)
    np.testing.assert_allclose(np.asarray(shifted.to_dense()),
                               Ad + 3.0 * np.eye(op.n), rtol=1e-13,
                               atol=1e-13)
    tiles = jnp.asarray(np.random.default_rng(0).standard_normal(
        op.A.D.shape))
    full = np.asarray(tlr_add_diag(op.A, tiles).D)
    np.testing.assert_allclose(full, np.asarray(op.A.D) + np.asarray(tiles))
    with pytest.raises(ValueError, match="scalar or shape"):
        tlr_add_diag(op.A, jnp.ones((2, 2)))


def test_round_error_bound_and_rank_monotonicity():
    op = _spd_operator(3, 4, 32, kind="cov")
    Ad = np.asarray(op.to_dense())
    normF = np.linalg.norm(Ad)
    prev_ranks = None
    for eps in (1e-10, 1e-6, 1e-3):
        R = tlr_round(op.A, eps)
        err = np.linalg.norm(np.asarray(R.to_dense()) - Ad)
        nt = num_tiles(op.nb)
        # error model (DESIGN.md section 6): <= sqrt(nt * r) * eps, and
        # loosely C * eps * ||A||_F with C covering the tile count
        assert err <= 10 * np.sqrt(nt * op.b) * eps + 1e-12
        assert err <= 100 * eps * normF + 1e-12
        ranks = np.asarray(R.ranks)
        assert (ranks <= np.asarray(op.A.ranks)).all()
        if prev_ranks is not None:
            assert (ranks <= prev_ranks).all()  # monotone in eps
        prev_ranks = ranks


def test_round_wide_concat_densifies():
    """After repeated concatenation r_max exceeds b; the rounding pass must
    switch to the densify path and still come back exact-to-eps."""
    op = _spd_operator(4, 3, 16)
    S = tlr_axpy(1.0, op.A, tlr_axpy(1.0, op.A, op.A))  # r_max = 3b > b
    assert S.r_max > op.b
    R = tlr_round(S, 1e-9)
    assert R.r_max == op.b
    np.testing.assert_allclose(np.asarray(R.to_dense()),
                               3 * np.asarray(op.to_dense()), rtol=1e-6,
                               atol=1e-7)


def test_transpose_and_generalize_symmetrize():
    op = _spd_operator(5, 4, 32)
    G = generalize(op.A)
    Ad = np.asarray(op.to_dense())
    np.testing.assert_allclose(np.asarray(G.to_dense()), Ad, rtol=1e-13,
                               atol=1e-13)
    Gt = tlr_transpose(G)
    np.testing.assert_allclose(np.asarray(Gt.to_dense()), Ad.T, rtol=1e-13,
                               atol=1e-13)
    assert tlr_transpose(op.A) is op.A  # symmetric: transpose is identity
    back = symmetrize(G, eps=1e-10)
    np.testing.assert_allclose(np.asarray(back.to_dense()), Ad, rtol=1e-8,
                               atol=1e-8)
    # matvec on the general grid
    x = np.random.default_rng(0).standard_normal(op.n)
    np.testing.assert_allclose(np.asarray(G @ jnp.asarray(x)), Ad @ x,
                               rtol=1e-11, atol=1e-11)


def test_offd_indexing_bijective():
    for nb in (2, 3, 5, 8):
        pairs = offd_pairs(nb)
        assert len(pairs) == nb * (nb - 1)
        seen = {offd_index(int(i), int(j), nb) for i, j in pairs}
        assert seen == set(range(nb * (nb - 1)))
    with pytest.raises(ValueError):
        offd_index(1, 1, 4)


# -- GEMM / SYRK --------------------------------------------------------------


def test_gemm_matches_dense():
    opA = _spd_operator(6, 4, 32, kind="cov")
    opB = _spd_operator(7, 4, 32)
    C = tlr_gemm(opA.A, opB.A, 1e-10)
    assert isinstance(C, TLRTiles)
    want = np.asarray(opA.to_dense()) @ np.asarray(opB.to_dense())
    got = np.asarray(C.to_dense())
    assert np.linalg.norm(got - want) / np.linalg.norm(want) < 1e-8


@pytest.mark.slow
def test_gemm_acceptance_scale():
    """Acceptance criterion: n=1024, b=64, eps=1e-6 -> 1e-4 Frobenius."""
    op = _spd_operator(8, 16, 64, eps=1e-8, kind="cov")
    C = tlr_gemm(op, op, 1e-6)
    want = np.asarray(op.to_dense()) @ np.asarray(op.to_dense())
    got = np.asarray(C.to_dense())
    assert np.linalg.norm(got - want) / np.linalg.norm(want) < 1e-4


def test_gemm_and_round_trace_counts():
    """The no-host-loop contract: tile math runs in jitted batched cores
    whose compile count is O(1) per shape family -- never O(nt) -- and a
    repeat call at the same shapes compiles nothing."""
    opA = _spd_operator(9, 6, 16)
    opB = _spd_operator(10, 6, 16)
    tlr_gemm(opA.A, opB.A, 1e-8)         # warm the shape family
    t0 = algebra_trace_count()
    tlr_gemm(opA.A, opB.A, 1e-8)
    assert algebra_trace_count() == t0   # steady state: zero new compiles
    t0 = algebra_trace_count()
    big = _spd_operator(11, 12, 16)      # 4x the tiles of nb=6
    tlr_gemm(big.A, big.A, 1e-8)
    first = algebra_trace_count() - t0
    assert first <= 4                    # gemm core + nested rounding pass
    t0 = algebra_trace_count()
    tlr_gemm(big.A, big.A, 1e-8)
    tlr_round(big.A, 1e-8)
    tlr_round(big.A, 1e-4)               # same shapes, new eps: no retrace
    assert algebra_trace_count() - t0 <= 1  # round's own family, once


def test_gemm_single_tile():
    """nb=1 degenerate grid: no off-diagonals, product is the dense D@D."""
    op = _spd_operator(30, 1, 32)
    C = tlr_gemm(op.A, op.A, 1e-10)
    want = np.asarray(op.to_dense()) @ np.asarray(op.to_dense())
    np.testing.assert_allclose(np.asarray(C.to_dense()), want, rtol=1e-11,
                               atol=1e-11)
    assert C.U.shape[0] == 0


@pytest.mark.slow
def test_syrk_matches_dense():
    op = _spd_operator(12, 8, 32, kind="cov")
    fact = op.cholesky(CholOptions(eps=1e-10, bs=8))
    assert (fact.perm == np.arange(op.nb)).all()
    C = tlr_syrk(op.A, fact.L, 1e-12)
    # A - L L^T vanishes to factorization accuracy
    resid = np.linalg.norm(np.asarray(C.to_dense()))
    assert resid < 1e-7 * np.linalg.norm(np.asarray(op.to_dense()))
    # steady state: a repeat call compiles nothing
    t0 = algebra_trace_count()
    tlr_syrk(op.A, fact.L, 1e-12)
    assert algebra_trace_count() == t0


def test_syrk_general_update():
    """C = A - L L^T for L that is NOT A's factor: dense-oracle parity."""
    op = _spd_operator(13, 4, 32)
    fact = _spd_operator(14, 4, 32, kind="cov").cholesky(
        CholOptions(eps=1e-9, bs=8))
    C = tlr_syrk(op.A, fact.L, 1e-10)
    Ld = np.tril(np.asarray(fact.L.to_dense()))
    want = np.asarray(op.to_dense()) - Ld @ Ld.T
    got = np.asarray(C.to_dense())
    # C is symmetric TLR, so only the symmetric part can match; L L^T is
    # symmetric by construction, so the whole thing must match
    assert np.linalg.norm(got - want) / np.linalg.norm(want) < 1e-7


# -- kernels-dispatch parity ---------------------------------------------------


def test_round_ref_vs_interpret_parity():
    """The rounding pass through the Pallas kernel bodies (interpret mode)
    agrees with the pure-jnp oracles."""
    op = _spd_operator(15, 3, 16)
    S = tlr_axpy(1.0, op.A, op.A)
    Rr = tlr_round(S, 1e-8, impl="ref")
    Ri = tlr_round(S, 1e-8, impl="interpret")
    np.testing.assert_array_equal(np.asarray(Rr.ranks), np.asarray(Ri.ranks))
    np.testing.assert_allclose(np.asarray(Ri.to_dense()),
                               np.asarray(Rr.to_dense()), rtol=1e-9,
                               atol=1e-9)


def test_gemm_ref_vs_interpret_parity():
    opA = _spd_operator(16, 3, 16)
    opB = _spd_operator(17, 3, 16)
    Cr = tlr_gemm(opA.A, opB.A, 1e-8, impl="ref")
    Ci = tlr_gemm(opA.A, opB.A, 1e-8, impl="interpret")
    np.testing.assert_allclose(np.asarray(Ci.to_dense()),
                               np.asarray(Cr.to_dense()), rtol=1e-9,
                               atol=1e-9)


# -- operator facade -----------------------------------------------------------


def test_operator_arithmetic():
    opA = _spd_operator(18, 4, 32)
    opB = _spd_operator(19, 4, 32)
    Ad, Bd = np.asarray(opA.to_dense()), np.asarray(opB.to_dense())
    np.testing.assert_allclose(np.asarray((opA + opB).to_dense()), Ad + Bd,
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray((opA - opB).to_dense()), Ad - Bd,
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray((2.5 * opA).to_dense()), 2.5 * Ad,
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray((-opA).to_dense()), -Ad,
                               rtol=1e-12, atol=1e-12)
    rounded = (opA + opB).round(1e-8)
    assert isinstance(rounded, TLROperator)
    assert rounded.r_max == opA.b
    C = opA.compose(opB, eps=1e-10)
    assert isinstance(C, TLRTiles)
    np.testing.assert_allclose(np.asarray(C.to_dense()), Ad @ Bd, rtol=1e-7,
                               atol=1e-8)
    with pytest.raises(TypeError):
        opA + 3  # scalar add is ambiguous (diag shift vs full): rejected


# -- Newton-Schulz preconditioner ----------------------------------------------


def test_newton_schulz_reduces_pcg_iterations():
    rng = np.random.default_rng(20)
    nb, b = 8, 32
    n = nb * b
    # ill-conditioned SPD: covariance with tiny nugget
    pts = rng.random((n, 3))
    K = exp_covariance(pts[kd_tree_ordering(pts, b)], 0.5, nugget=1e-4)
    op = TLROperator.compress(jnp.asarray(K), b, b, 1e-10)
    rhs = jnp.asarray(rng.standard_normal(n))
    _, it_plain, _ = pcg(op, rhs, tol=1e-8, maxiter=500)
    Xop, info = tlr_newton_schulz(op, iters=10, eps=1e-10, scale="norm",
                                  track_residual=True)
    x, it_pre, hist = pcg(op, rhs, precond=Xop, tol=1e-8, maxiter=500)
    assert it_pre < it_plain, (it_pre, it_plain)
    assert hist[-1] < 1e-8
    # the residual estimate must shrink across iterations
    assert info.residual_history[-1] < info.residual_history[0]
    # X stays SPD enough for PCG: solution actually solves the system
    resid = np.linalg.norm(K @ np.asarray(x) - np.asarray(rhs))
    assert resid / np.linalg.norm(np.asarray(rhs)) < 1e-6


def test_newton_schulz_adaptive_eps_and_stopping_rule():
    """ROADMAP "Newton-Schulz at scale": adaptive per-iteration eps (loose
    early, tight late) plus the residual-estimate stopping rule -- the
    fixed-count fixed-eps path stays the default (its signature and info
    fields are covered by the tests above)."""
    op = _spd_operator(22, 4, 32)
    Xop, info = tlr_newton_schulz(op, iters=30, eps=1e-10, scale="norm",
                                  adaptive=True, tol=1e-6,
                                  track_residual=True)
    # the stopping rule fired well before the iteration cap
    assert info.converged and info.iters < 30
    assert info.residual_history[-1] < 1e-6
    # loose early, tight late: the rounding eps never widens over time
    assert len(info.eps_history) == info.iters
    assert info.eps_history[-1] <= info.eps_history[0]
    assert info.eps_history[0] > 1e-10  # actually loose at the start
    # the adaptive iterate is still a usable SPD preconditioner
    rng = np.random.default_rng(3)
    rhs = jnp.asarray(rng.standard_normal(op.n))
    _, it_plain, _ = pcg(op, rhs, tol=1e-8, maxiter=500)
    _, it_pre, hist = pcg(op, rhs, precond=Xop, tol=1e-8, maxiter=500)
    assert it_pre < it_plain and hist[-1] < 1e-8
    # unconverged cap: tol unreachable in 1 iteration reports converged=False
    _, info1 = tlr_newton_schulz(op, iters=1, eps=1e-8, scale="trace",
                                 adaptive=True, tol=1e-12)
    assert info1.iters == 1 and not info1.converged
    # eps coarser than loose_eps must be honored, not clipped down to it
    _, info2 = tlr_newton_schulz(op, iters=2, eps=5e-2, scale="trace",
                                 adaptive=True)
    assert all(e >= 5e-2 for e in info2.eps_history)


def test_newton_schulz_ranked_batching_matches_flat():
    op = _spd_operator(23, 4, 32)
    Xf, _ = tlr_newton_schulz(op, iters=4, eps=1e-9, scale="norm")
    Xr, _ = tlr_newton_schulz(op, iters=4, eps=1e-9, scale="norm",
                              batching="ranked")
    np.testing.assert_allclose(np.asarray(Xr.to_dense()),
                               np.asarray(Xf.to_dense()), rtol=1e-8,
                               atol=1e-8)


def test_newton_schulz_trace_scaling_converges():
    op = _spd_operator(21, 4, 32)  # well-conditioned: trace scaling fine
    Xop, info = tlr_newton_schulz(op, iters=12, eps=1e-12, scale="trace",
                                  track_residual=True)
    assert info.alpha == pytest.approx(1.0 / float(op.trace()))
    assert info.residual_history[-1] < 1e-3
    with pytest.raises(ValueError, match="scale"):
        tlr_newton_schulz(op, iters=1, scale="bogus")


# -- hypothesis property tests (optional, like test_properties.py) -------------


if HAVE_HYPOTHESIS:

    @settings(**HYP_SET)
    @given(seed=st.integers(0, 10_000), nb=st.sampled_from([3, 5]),
           b=st.sampled_from([16, 32]))
    def test_property_add_dense_parity(seed, nb, b):
        opA = _spd_operator(seed, nb, b)
        opB = _spd_operator(seed + 1, nb, b)
        got = np.asarray((opA + opB).to_dense())
        want = np.asarray(opA.to_dense()) + np.asarray(opB.to_dense())
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)

    @settings(**HYP_SET)
    @given(seed=st.integers(0, 10_000), nb=st.sampled_from([3, 4]))
    def test_property_gemm_dense_parity(seed, nb):
        opA = _spd_operator(seed, nb, 16)
        opB = _spd_operator(seed + 2, nb, 16)
        got = np.asarray(tlr_gemm(opA.A, opB.A, 1e-10).to_dense())
        want = np.asarray(opA.to_dense()) @ np.asarray(opB.to_dense())
        assert np.linalg.norm(got - want) / np.linalg.norm(want) < 1e-7

    @settings(**HYP_SET)
    @given(seed=st.integers(0, 10_000),
           eps=st.sampled_from([1e-8, 1e-5, 1e-2]))
    def test_property_round_error_and_rank(seed, eps):
        op = _spd_operator(seed, 4, 16, kind="cov")
        R = tlr_round(op.A, eps)
        Ad = np.asarray(op.to_dense())
        err = np.linalg.norm(np.asarray(R.to_dense()) - Ad)
        assert err <= 100 * eps * np.linalg.norm(Ad) + 1e-12
        assert (np.asarray(R.ranks) <= np.asarray(op.A.ranks)).all()
