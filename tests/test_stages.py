"""Column-stage graph + schedules (DESIGN.md section 12).

Both Cholesky drivers now execute an explicit stage graph (``core/stages.py``)
instead of an interleaved host loop: diag / panel / trailing-update nodes
with declared ``reads`` / ``writes`` / ``destroys`` tokens, ordered by a
list scheduler. These tests pin:

* the dependency builder: RAW edges, versioned-token WAW rejection, the
  donation anti-dependency (a destroyer runs after every other reader,
  regardless of declaration order), cycle detection,
* the lookahead schedule's interleave -- ``update_tail(k)`` sinks below
  ``diag(k+1)`` + ``panel(k+1)`` -- and its legality re-validation,
* driver integration: ``CholOptions.lookahead`` produces bit-identical
  factors to the sequential default on one device (same compiled column
  steps, only the host dispatch order changes), the stats schema carries
  the executed schedule, and the left driver records but ignores the flag,
* buffer donation (the stage graph's enabler): the donating
  ``tlr_syrk_column`` variant matches the copying default, head+tail
  splitting matches one "all" call, and a factorization emits no jax
  donation warnings.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, LookaheadSchedule, SequentialSchedule, Stage, TLROperator,
    build_deps, covariance_problem, run_graph, tlr_syrk_column, tlr_to_dense,
)


def _cov_op(n, b, d=3, eps=1e-9):
    _, K = covariance_problem(n, d, b)
    K = np.asarray(K)
    return K, TLROperator.compress(jnp.asarray(K), b, b, eps)


def _Lmat(fact):
    return np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                           fact.L.nb, fact.L.b)))


def _stage(name, kind="diag", k=0, reads=(), writes=(), destroys=(), seq=0,
           log=None):
    fn = (lambda: log.append(name)) if log is not None else (lambda: None)
    return Stage(name=name, kind=kind, k=k, fn=fn, reads=tuple(reads),
                 writes=tuple(writes), destroys=tuple(destroys), seq=seq)


# -- dependency builder --------------------------------------------------------


def test_build_deps_raw_edges():
    stages = [
        _stage("w", writes=[("t", 0)], seq=0),
        _stage("r1", reads=[("t", 0)], seq=1),
        _stage("r2", reads=[("t", 0)], seq=2),
    ]
    deps = build_deps(stages)
    assert deps["w"] == set()
    assert deps["r1"] == {"w"}
    assert deps["r2"] == {"w"}


def test_build_deps_rejects_double_write():
    stages = [
        _stage("a", writes=[("t", 0)], seq=0),
        _stage("b", writes=[("t", 0)], seq=1),
    ]
    with pytest.raises(ValueError, match="written twice"):
        build_deps(stages)


def test_build_deps_rejects_double_destroy():
    stages = [
        _stage("a", writes=[("t", 0)], seq=0),
        _stage("b", destroys=[("t", 0)], seq=1),
        _stage("c", destroys=[("t", 0)], seq=2),
    ]
    with pytest.raises(ValueError, match="destroyed twice"):
        build_deps(stages)


def test_destroy_anti_dependency_is_order_independent():
    """The destroyer must run after every other reader, even readers
    declared AFTER it -- exactly the lookahead shape, where update_tail(k)
    (the destroyer) is constructed before panel(k+1) (the reader)."""
    stages = [
        _stage("w", writes=[("t", 0)], seq=0),
        _stage("destroyer", destroys=[("t", 0)], seq=1),
        _stage("late-reader", reads=[("t", 0)], seq=2),
    ]
    deps = build_deps(stages)
    assert deps["destroyer"] == {"w", "late-reader"}
    order = [s.name for s in SequentialSchedule().order(stages)]
    assert order.index("late-reader") < order.index("destroyer")


def test_cycle_detection():
    stages = [
        _stage("a", reads=[("u", 0)], writes=[("t", 0)], seq=0),
        _stage("b", reads=[("t", 0)], writes=[("u", 0)], seq=1),
    ]
    with pytest.raises(ValueError, match="cycle"):
        SequentialSchedule().order(stages)


# -- schedules -----------------------------------------------------------------


def _right_looking_graph(nb, lookahead):
    """The right-looking driver's token shape, with no-op stage bodies."""
    stages = []

    def add(name, kind, k, **kw):
        stages.append(_stage(name, kind=kind, k=k, seq=len(stages), **kw))

    for k in range(nb):
        dtok = ("Dh", k - 1) if lookahead else ("Dv", k - 1)
        add(f"diag:{k}", "diag", k, reads=[dtok] if k else [],
            writes=[("Lkk", k)])
        if k + 1 >= nb:
            continue
        atok = ("acch", k - 1) if lookahead else ("acc", k - 1)
        add(f"panel:{k}", "panel", k,
            reads=([atok] if k else []) + [("Lkk", k)],
            writes=[("panel", k)])
        prev = [("acc", k - 1), ("Dv", k - 1)] if k else []
        if lookahead:
            add(f"update_head:{k}", "update_head", k, reads=[("panel", k)],
                destroys=prev, writes=[("acch", k), ("Dh", k)])
            add(f"update_tail:{k}", "update_tail", k, reads=[("panel", k)],
                destroys=[("acch", k), ("Dh", k)],
                writes=[("acc", k), ("Dv", k)])
        else:
            add(f"update:{k}", "update", k, reads=[("panel", k)],
                destroys=prev, writes=[("acc", k), ("Dv", k)])
    return stages


def test_sequential_schedule_is_program_order():
    stages = _right_looking_graph(5, lookahead=False)
    order = [s.name for s in SequentialSchedule().order(stages)]
    assert order == [s.name for s in stages]


def test_lookahead_schedule_interleaves():
    """update_tail(k) sinks below diag(k+1) + panel(k+1): the wide trailing
    update overlaps the next column's panel dispatch."""
    stages = _right_looking_graph(4, lookahead=True)
    order = [s.name for s in LookaheadSchedule().order(stages)]
    assert order == [
        "diag:0", "panel:0", "update_head:0",
        "diag:1", "panel:1", "update_tail:0", "update_head:1",
        "diag:2", "panel:2", "update_tail:1", "update_head:2",
        "diag:3", "update_tail:2",
    ]


def test_run_graph_executes_and_reports():
    log = []
    stages = [
        _stage("a", kind="diag", writes=[("t", 0)], seq=0, log=log),
        _stage("b", kind="panel", reads=[("t", 0)], seq=1, log=log),
    ]
    rec = run_graph(stages, SequentialSchedule())
    assert log == ["a", "b"]
    assert rec["name"] == "sequential"
    assert rec["order"] == ["a", "b"]
    assert set(rec["kind_seconds"]) == {"diag", "panel"}


# -- driver integration --------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("batching", ["flat", "ranked"])
def test_lookahead_matches_sequential(batching):
    """Same compiled column steps, only the dispatch order changes: the
    lookahead factor must match the sequential one exactly."""
    K, op = _cov_op(8 * 32, 32)
    fs = op.cholesky(CholOptions(eps=1e-6, algo="right", batching=batching))
    fl = op.cholesky(CholOptions(eps=1e-6, algo="right", batching=batching,
                                 lookahead=True))
    assert fs.stats["schedule"]["name"] == "sequential"
    assert fl.stats["schedule"]["name"] == "lookahead"
    assert fl.stats["schedule"]["requested_lookahead"] is True
    np.testing.assert_array_equal(np.asarray(fs.L.D), np.asarray(fl.L.D))
    np.testing.assert_array_equal(np.asarray(fs.L.U), np.asarray(fl.L.U))
    np.testing.assert_array_equal(np.asarray(fs.L.V), np.asarray(fl.L.V))
    np.testing.assert_array_equal(np.asarray(fs.L.ranks),
                                  np.asarray(fl.L.ranks))
    # the executed order actually interleaved
    order = fl.stats["schedule"]["order"]
    assert order.index("update_tail:0") > order.index("panel:1")


@pytest.mark.slow
def test_lookahead_ldlt_matches_sequential():
    K, op = _cov_op(8 * 32, 32)
    fs = op.ldlt(CholOptions(eps=1e-6, algo="right"))
    fl = op.ldlt(CholOptions(eps=1e-6, algo="right", lookahead=True))
    np.testing.assert_array_equal(np.asarray(fs.d), np.asarray(fl.d))
    np.testing.assert_array_equal(np.asarray(fs.L.U), np.asarray(fl.L.U))


@pytest.mark.slow
def test_left_driver_records_but_ignores_lookahead():
    """The left driver's column graph is a serial chain -- the flag is
    recorded in the schedule stats but the order stays sequential."""
    K, op = _cov_op(4 * 32, 32)
    f = op.cholesky(CholOptions(eps=1e-6, algo="left", lookahead=True))
    assert f.stats["schedule"]["name"] == "sequential"
    assert f.stats["schedule"]["requested_lookahead"] is True
    # the shared scatter's executable cache is process-wide, so a warm
    # suite may see 0 fresh compiles here -- only the key is pinned
    assert f.stats["scatter_traces"] >= 0


@pytest.mark.slow
def test_schedule_stats_schema():
    K, op = _cov_op(4 * 32, 32)
    f = op.cholesky(CholOptions(eps=1e-6, algo="right", lookahead=True))
    sched = f.stats["schedule"]
    assert set(sched) >= {"name", "stages", "order", "kind_seconds",
                          "requested_lookahead"}
    assert sched["stages"] == len(sched["order"])
    # one diag per column, one panel + head + tail per off-diagonal column
    nb = op.nb
    assert sched["stages"] == nb + 3 * (nb - 1)


# -- donation (the stage graph's zero-copy enabler) ----------------------------


def _syrk_args(nb=6, b=16, r=4, k=1, seed=0):
    rng = np.random.default_rng(seed)
    nt = nb * (nb - 1) // 2
    w = 3 * r + b
    T = nb - 1 - k
    accU = jnp.asarray(rng.standard_normal((nt, b, w)))
    accV = jnp.asarray(rng.standard_normal((nt, b, w)))
    D = jnp.asarray(rng.standard_normal((nb, b, b)))
    Up = jnp.asarray(rng.standard_normal((T, b, r)))
    Vn = jnp.asarray(rng.standard_normal((T, b, r)))
    ranks = jnp.full((T,), r, jnp.int32)
    return accU, accV, D, Up, Vn, ranks


def test_syrk_head_plus_tail_equals_all():
    accU, accV, D, Up, Vn, ranks = _syrk_args()
    k, used = 1, 16
    aU, aV, aD = tlr_syrk_column(accU, accV, used, D, Up, Vn, ranks, None, k)
    hU, hV, hD = tlr_syrk_column(accU, accV, used, D, Up, Vn, ranks, None, k,
                                 part="head")
    tU, tV, tD = tlr_syrk_column(hU, hV, used, hD, Up, Vn, ranks, None, k,
                                 part="tail")
    np.testing.assert_allclose(np.asarray(tU), np.asarray(aU), atol=1e-12)
    np.testing.assert_allclose(np.asarray(tV), np.asarray(aV), atol=1e-12)
    np.testing.assert_allclose(np.asarray(tD), np.asarray(aD), atol=1e-12)


def test_syrk_donate_matches_copying_default():
    accU, accV, D, Up, Vn, ranks = _syrk_args()
    k, used = 1, 16
    want = tlr_syrk_column(accU, accV, used, D, Up, Vn, ranks, None, k)
    # the copying default leaves its inputs alive (reusable)
    assert not accU.is_deleted()
    got = tlr_syrk_column(accU, accV, used, D, Up, Vn, ranks, None, k,
                          donate=True)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-12)
    # the donating variant consumed the buffers: callers must rebind
    assert accU.is_deleted() and accV.is_deleted() and D.is_deleted()


def test_bad_part_rejected():
    accU, accV, D, Up, Vn, ranks = _syrk_args()
    with pytest.raises(ValueError, match="part"):
        tlr_syrk_column(accU, accV, 16, D, Up, Vn, ranks, None, 1,
                        part="middle")


@pytest.mark.slow
@pytest.mark.parametrize("algo", ["left", "right"])
def test_factorization_emits_no_donation_warnings(algo):
    """Every donated buffer must actually be consumable -- jax warns when a
    donate_argnums argument cannot be aliased, which would mean the driver
    silently fell back to copying."""
    K, op = _cov_op(4 * 32, 32)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        f = op.cholesky(CholOptions(eps=1e-6, algo=algo, lookahead=True))
    donation = [w for w in rec if "donat" in str(w.message).lower()]
    assert donation == [], [str(w.message) for w in donation]
    err = np.linalg.norm(K - _Lmat(f) @ _Lmat(f).T, 2)
    assert err < (1e-2 if algo == "left" else 1e-4)
