"""Miniature dry-run: 8 forced host devices in a subprocess, smoke configs.

Validates the full lower->compile->analyze pipeline (sharding rules,
collective parsing) at CI scale; the real 512-device sweep runs via
``python -m repro.launch.dryrun --all``.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import (abstract_params, build_loss_fn, build_prefill_fn,
                          build_serve_step, input_specs)
from repro.models.config import ShapeSpec
from repro.models.api import _enc_len
from repro.models import init_decode_caches
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import (caches_shardings, inputs_shardings,
                                   params_shardings)
from repro.launch.dryrun import parse_collectives
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

arch, kind, mesh_kind = sys.argv[1], sys.argv[2], sys.argv[3]
cfg = get_config(arch, smoke=True)
if mesh_kind == "multi":
    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
else:
    mesh = make_test_mesh((2, 4), ("data", "model"))

spec = ShapeSpec("mini", seq_len=64, global_batch=8, kind=kind)
specs = input_specs(cfg, spec)
params = abstract_params(cfg)
pshard = params_shardings(params, mesh, fsdp=True)

if kind == "train":
    loss_fn = build_loss_fn(cfg)
    ocfg = AdamWConfig()
    ostate = jax.eval_shape(lambda p: adamw_init(p, ocfg), params)
    oshard = type(ostate)(step=NamedSharding(mesh, P()),
                          m=params_shardings(ostate.m, mesh, fsdp=True),
                          v=params_shardings(ostate.v, mesh, fsdp=True))
    def step(params, ostate, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        p2, s2 = adamw_update(grads, ostate, params, ocfg)
        return loss, p2, s2
    args = (params, ostate, specs)
    in_sh = (pshard, oshard, inputs_shardings(specs, mesh))
elif kind == "prefill":
    step = build_prefill_fn(cfg)
    args = (params, specs)
    in_sh = (pshard, inputs_shardings(specs, mesh))
else:
    serve = build_serve_step(cfg)
    step = lambda p, c, t, n: serve(p, c, t, n)
    args = (params, specs["caches"], specs["token"], specs["cache_len"])
    in_sh = (pshard, caches_shardings(specs["caches"], mesh),
             inputs_shardings(specs["token"], mesh),
             NamedSharding(mesh, P()))

lowered = jax.jit(step, in_shardings=in_sh).lower(*args)
compiled = lowered.compile()
ma = compiled.memory_analysis()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per computation
    ca = ca[0] if ca else {}
coll = parse_collectives(compiled.as_text())
print(json.dumps({
    "flops": ca.get("flops", 0.0),
    "temp_bytes": ma.temp_size_in_bytes,
    "coll_bytes": coll["total_bytes"],
    "coll_counts": coll["counts"],
}))
"""


def _run(arch, kind, mesh_kind):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, kind, mesh_kind],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"{arch}/{kind}/{mesh_kind}:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch,kind", [
    ("qwen1_5_0_5b", "train"),
    ("granite_moe_3b_a800m", "train"),
    ("jamba_v0_1_52b", "train"),
    ("whisper_large_v3", "train"),
    ("llama_3_2_vision_90b", "prefill"),
    ("mamba2_130m", "decode"),
    ("llama4_maverick_400b_a17b", "decode"),
])
@pytest.mark.slow
def test_mini_dryrun_single(arch, kind):
    r = _run(arch, kind, "single")
    assert r["flops"] > 0
    # SPMD over a non-trivial mesh must produce collectives
    assert r["coll_bytes"] > 0, f"no collectives found: {r}"


@pytest.mark.parametrize("arch,kind", [
    ("qwen1_5_0_5b", "train"),
    ("mamba2_130m", "train"),
])
def test_mini_dryrun_multipod(arch, kind):
    r = _run(arch, kind, "multi")
    assert r["flops"] > 0
    assert r["coll_bytes"] > 0
