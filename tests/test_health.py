"""ISSUE 10: breakdown detection, bounded recovery, and the deterministic
fault-injection matrix (DESIGN.md section 13).

Factorization side: ``CholOptions(check=True)`` must reproduce clean-path
factors bitwise, recover injected indefiniteness/rank spikes through the
``RetryPolicy`` ladders (every action a recorded ``HealthEvent``), and
raise a structured :class:`FactorizationBreakdown` -- never return
non-finite factors -- when remedies exhaust. Serve side: non-finite RHS
rejected at submit, poisoned columns isolated from co-batched blocks,
deadlines evict, PCG breakdowns retry with backoff, evicted residents
answer with typed errors.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import faults
from repro.core import (
    CholOptions, FactorizationBreakdown, RetryPolicy, SequentialSchedule,
    Stage, TLROperator, column_flags, covariance_problem, from_dense,
    run_graph, tlr_cholesky,
)
from repro.serve import RequestRejected, ServeRequest


# -- fixtures ------------------------------------------------------------------


@pytest.fixture(scope="module")
def prob3():
    """3-D covariance, nb=4: the generic SPD operand."""
    _, K = covariance_problem(256, 3, 64)
    with pytest.warns(FutureWarning):
        A = from_dense(jnp.asarray(K), 64, 64, 1e-9)
    return K, A


@pytest.fixture(scope="module")
def prob1():
    """1-D covariance, b=32: rank-1 off-diagonal tiles, so a spiked tile
    is the only thing near a hard rank cap (3-D tiles at this size are
    near-full-rank and would overflow a 16-cap everywhere)."""
    _, K = covariance_problem(256, 1, 32)
    with pytest.warns(FutureWarning):
        A = from_dense(jnp.asarray(K), 32, 32, 1e-10)
    return A


@pytest.fixture(scope="module")
def serve_prob():
    rng = np.random.default_rng(0)
    n = 128
    M = rng.standard_normal((n, n))
    A = M @ M.T / n + 2.0 * np.eye(n)
    op = TLROperator.compress(jnp.asarray(A), 32, eps=1e-10)
    return A, op, op.cholesky()


DRIVERS = [("left", False), ("right", False), ("right", True)]
IDS = ["left", "right", "right-lookahead"]


def _finite(fact) -> bool:
    return all(bool(np.isfinite(np.asarray(x)).all())
               for x in (fact.L.D, fact.L.U, fact.L.V))


def _events(fact):
    return fact.stats["health"]["events"]


# -- clean path: checks read, never write --------------------------------------


@pytest.mark.parametrize("algo,lookahead", DRIVERS, ids=IDS)
def test_clean_path_bitwise_parity(prob3, algo, lookahead):
    """check=True on a healthy operand reproduces the unchecked factors
    bitwise (detection only reads), records zero events, and stamps the
    health summary into stats; check=False carries no health machinery."""
    _, A = prob3
    off = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, algo=algo,
                                      lookahead=lookahead))
    on = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, algo=algo,
                                     lookahead=lookahead, check=True))
    for a, b in ((off.L.D, on.L.D), (off.L.U, on.L.U), (off.L.V, on.L.V)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert "health" not in off.stats
    h = on.stats["health"]
    assert h["events"] == []
    assert h["columns_checked"] == A.nb
    assert on.stats["schedule"]["checks"] > 0


# -- recovery ladders ----------------------------------------------------------


@pytest.mark.parametrize("algo,lookahead", DRIVERS, ids=IDS)
def test_indefinite_diag_recovers(prob3, algo, lookahead):
    """A genuinely indefinite diagonal tile recovers through the recorded
    SPD ladder (clamp, then escalating jitter as needed) with finite
    factors -- through both drivers and the lookahead schedule."""
    _, A = prob3
    Abad = faults.make_diag_indefinite(A, 2, magnitude=4.0)
    fact = tlr_cholesky(Abad, CholOptions(eps=1e-6, bs=8, algo=algo,
                                          lookahead=lookahead, check=True))
    assert _finite(fact)
    spd = [e for e in _events(fact) if e["kind"] == "spd_breakdown"]
    assert spd, "no spd_breakdown event recorded for an indefinite tile"
    assert all(e["remedy"] in ("clamp", "jitter") for e in spd)
    assert any(e["column"] == 2 for e in spd)


def test_rank_spike_recovers_left(prob1):
    """A planted rank spike under a hard cap recovers through the
    eps-loosen / densify ladder (left driver); the factors stay finite and
    every remedy is on the record."""
    As = faults.spike_rank(prob1, 4, 1, seed=3, scale=1e-4)
    fact = tlr_cholesky(As, CholOptions(eps=1e-6, bs=8, r_max_out=16,
                                        check=True))
    assert _finite(fact)
    over = [e for e in _events(fact) if e["kind"] == "rank_overflow"]
    assert over and {"eps_loosen"} <= {e["remedy"] for e in over}


def test_rank_spike_accepts_right(prob1):
    """The right driver's rounding is already SVD-optimal, so the same
    spike resolves as a recorded 'accept' (truncation error within the
    policy floor) rather than a re-pass."""
    As = faults.spike_rank(prob1, 4, 1, seed=3, scale=3e-4)
    fact = tlr_cholesky(As, CholOptions(eps=1e-6, bs=8, r_max_out=16,
                                        algo="right", check=True))
    assert _finite(fact)
    over = [e for e in _events(fact) if e["kind"] == "rank_overflow"]
    assert over and all(e["remedy"] == "accept" for e in over)


@pytest.mark.parametrize("algo", ["left", "right"])
def test_rank_spike_breakdown(prob1, algo):
    """A spike too large for any remedy is a typed breakdown carrying the
    column and the remedies tried -- not a silently degraded factor."""
    As = faults.spike_rank(prob1, 4, 1, seed=3, scale=1e-3)
    with pytest.raises(FactorizationBreakdown) as ei:
        tlr_cholesky(As, CholOptions(eps=1e-6, bs=8, r_max_out=16,
                                     algo=algo, check=True))
    rep = ei.value.report
    assert rep.reason == "rank_overflow"
    assert rep.column >= 0
    assert "rank_overflow" in str(ei.value)


# -- unrecoverable faults: structured breakdown, never NaN factors -------------


@pytest.mark.parametrize("algo", ["left", "right"])
def test_nan_diag_breakdown(prob3, algo):
    """A NaN diagonal tile exhausts the jitter ladder (NaN is not fixable
    by shifting) and raises with the remedies it tried."""
    _, A = prob3
    with faults.inject(faults.Fault(site="chol.diag", kind="nan",
                                    column=2)):
        with pytest.raises(FactorizationBreakdown) as ei:
            tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, algo=algo,
                                        check=True))
    rep = ei.value.report
    assert rep.column == 2 and rep.reason == "spd_breakdown"
    assert "jitter" in rep.remedies
    assert "column 2" in str(ei.value)


@pytest.mark.parametrize("algo", ["left", "right"])
def test_nan_panel_breakdown(prob3, algo):
    """A NaN produced mid-panel (healthy pivots) is unrecoverable: the
    check at the stage boundary raises instead of letting the NaN
    propagate through every later column."""
    _, A = prob3
    with faults.inject(faults.Fault(site="chol.panel", kind="nan",
                                    column=1)):
        with pytest.raises(FactorizationBreakdown) as ei:
            tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, algo=algo,
                                        check=True))
    rep = ei.value.report
    assert rep.column == 1 and rep.reason == "nonfinite_panel"


def test_poisoned_input_tile_detected(prob3):
    """A NaN planted in the *operand* poisons the unchecked factorization
    silently (the pre-ISSUE-10 behavior this subsystem exists to stop);
    with check=True the same operand is a structured breakdown at the
    first column that touches the tile."""
    _, A = prob3
    Ap = faults.poison_tile(A, 2, 0)
    silent = tlr_cholesky(Ap, CholOptions(eps=1e-6, bs=8))
    assert not _finite(silent)            # NaN factors, no error raised
    with pytest.raises(FactorizationBreakdown) as ei:
        tlr_cholesky(Ap, CholOptions(eps=1e-6, bs=8, check=True))
    assert ei.value.report.reason == "nonfinite_panel"
    assert ei.value.report.column == 0


# -- units: policy, flags, stage hook ------------------------------------------


def test_retry_policy_schedules():
    rp = RetryPolicy(max_retries=2, shift0=1e-8, growth=16.0,
                     eps_growth=4.0)
    assert rp.shift(0) == pytest.approx(1e-8)
    assert rp.shift(2) == pytest.approx(1e-8 * 256)
    assert rp.eps_at(1e-6, 1) == pytest.approx(4e-6)
    assert rp.eps_floor(1e-6) == pytest.approx(1.6e-5)


def test_column_flags_reductions():
    """The fused device-side scan: non-finite counts, min pivot + argmin,
    and the rank-overflow count, in one host pull."""
    pivots = jnp.asarray([1.0, -2.0, jnp.nan, 3.0])
    arr = jnp.asarray([[1.0, jnp.inf], [0.0, 2.0]])
    flags = column_flags(pivots, (arr,))
    assert flags[0] == 1          # non-finite array entries
    assert flags[1] == 1          # non-finite pivots
    assert flags[2] == -2.0       # min finite pivot
    assert flags[3] == 1          # its index
    ranks = jnp.asarray([4, 2, 4])
    err = jnp.asarray([1e-3, 1e-9, 1e-9])
    flags = column_flags(jnp.ones(2), ranks=ranks, err=err, r_cap=4,
                         eps=1e-6)
    assert flags[4] == 1          # only the at-cap, over-eps tile counts


def test_stage_check_hooks_run_and_time():
    """`Stage.check` runs after the stage body, is counted and timed
    separately, and absent hooks cost nothing (the obs contract)."""
    ran = []
    stages = [
        Stage(name="diag[0]", kind="diag", k=0,
              fn=lambda: ran.append("fn0"),
              check=lambda: ran.append("chk0"), writes=(("x", 0),), seq=0),
        Stage(name="panel[0]", kind="panel", k=0,
              fn=lambda: ran.append("fn1"),
              reads=(("x", 0),), writes=(("y", 0),), seq=1),
    ]
    sched = run_graph(stages, SequentialSchedule())
    assert ran == ["fn0", "chk0", "fn1"]
    assert sched["checks"] == 1
    assert sched["kind_seconds"]["check"] >= 0.0


# -- serve-side degradation ----------------------------------------------------


def test_submit_rejects_nonfinite_rhs(serve_prob):
    _, op, fact = serve_prob
    srv = fact.serve(operator=op, slots=2)
    rhs = np.ones(fact.n)
    rhs[3] = np.inf
    with pytest.raises(RequestRejected, match="non-finite"):
        srv.submit(ServeRequest("solve", rhs=rhs))
    # ValueError compatibility: pre-ISSUE-10 callers guard with ValueError
    with pytest.raises(ValueError):
        srv.submit(ServeRequest("pcg_solve", rhs=rhs))
    assert srv.stats.rejected == 2
    assert srv.pending == 0 and srv.active == 0


def _named_server(fact, op):
    from repro.serve import TLRServer

    srv = TLRServer(slots=2)
    srv.register("f0", fact, operator=op)
    return srv


def test_unknown_and_evicted_fid(serve_prob):
    _, op, fact = serve_prob
    srv = _named_server(fact, op)
    with pytest.raises(RequestRejected, match="unknown factorization"):
        srv.submit(ServeRequest("logdet", fid="nope"))
    rid = srv.submit(ServeRequest("logdet"))
    srv.evict_resident("f0")
    # queued request completed as a typed error, not dropped
    res = srv.results[rid]
    assert not res.ok and res.error == "resident_evicted"
    with pytest.raises(RequestRejected, match="was evicted"):
        srv.submit(ServeRequest("logdet", fid="f0"))
    assert srv.stats.errors >= 1


def test_deadline_timeout_isolated(serve_prob):
    """A stalled request times out at its deadline; the co-batched healthy
    request completes normally in the same server."""
    A, op, fact = serve_prob
    srv = fact.serve(operator=op, slots=2)
    rng = np.random.default_rng(1)
    slow = ServeRequest("solve", rhs=rng.standard_normal(fact.n),
                        deadline_ticks=2)
    ok = ServeRequest("solve", rhs=rng.standard_normal(fact.n))
    rs, ro = srv.submit(slow), srv.submit(ok)
    with faults.inject(faults.Fault(site="serve.admit", rid=rs, delay=6)):
        results = srv.run(max_ticks=10)
    assert results[rs].error == "timeout" and not results[rs].ok
    assert results[rs].value is None
    assert results[ro].ok
    assert np.allclose(results[ro].value, np.linalg.solve(A, ok.rhs),
                       atol=1e-7)
    assert srv.stats.timeouts == 1


def test_poisoned_column_isolated(serve_prob):
    """A NaN column inside a packed solve block degrades only its own
    request; co-batched results are bit-for-bit unaffected."""
    A, op, fact = serve_prob
    srv = fact.serve(operator=op, slots=4)
    rng = np.random.default_rng(2)
    reqs = [ServeRequest("solve", rhs=rng.standard_normal(fact.n))
            for _ in range(3)]
    rids = [srv.submit(r) for r in reqs]
    with faults.inject(faults.Fault(site="serve.solve", rid=rids[1])):
        results = srv.run()
    bad = results[rids[1]]
    assert not bad.ok and bad.error == "nonfinite_result"
    assert bad.value is None
    for r, rid in zip(reqs, rids):
        if rid == rids[1]:
            continue
        out = results[rid]
        assert out.ok and np.isfinite(out.value).all()
        assert np.allclose(out.value, np.linalg.solve(A, r.rhs), atol=1e-7)
    assert srv.stats.errors == 1


def test_pcg_breakdown_retries_with_backoff(serve_prob):
    """PCG against an indefinite operator breaks down; the request
    re-admits with exponential backoff up to its retry budget, then
    completes as a typed degraded result (last finite iterate kept)."""
    A, op, fact = serve_prob
    neg = TLROperator.compress(jnp.asarray(-A), 32, eps=1e-10)
    srv = fact.serve(operator=neg, slots=2)
    rng = np.random.default_rng(3)
    req = ServeRequest("pcg_solve", rhs=rng.standard_normal(fact.n),
                       tol=1e-10, retries=2)
    rid = srv.submit(req)
    results = srv.run(max_ticks=50)
    out = results[rid]
    assert not out.ok and out.error == "pcg_breakdown"
    assert out.breakdown is not None
    assert out.attempts == 3              # 1 admission + 2 retries
    assert srv.stats.pcg_retries == 2
    assert srv.stats.errors == 1


def test_health_counters_in_summary(serve_prob):
    _, op, fact = serve_prob
    srv = fact.serve(operator=op, slots=2)
    h = srv.stats.summary()["health"]
    assert set(h) == {"rejected", "timeouts", "errors", "pcg_retries"}
