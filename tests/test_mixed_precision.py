"""Mixed-precision TLR storage (the paper's section 7 proposal):
off-diagonal factors stored low-precision, sampling in high precision."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, TLROperator, covariance_problem, from_dense, tlr_cholesky,
    tlr_matvec, tlr_to_dense,
)


def _problem(n=512, b=64):
    _, K = covariance_problem(n, 3, b)
    return K


def test_f32_storage_halves_lowrank_memory():
    K = _problem()
    A64 = TLROperator.compress(jnp.asarray(K), 64, 64, 1e-8).A
    A32 = TLROperator.compress(jnp.asarray(K), 64, 64, 1e-8,
                               store_dtype=np.float32).A
    m64 = A64.memory_stats()
    m32 = A32.memory_stats()
    assert m32["lowrank_bytes_logical"] * 2 == m64["lowrank_bytes_logical"]
    # reconstruction error bounded by f32 resolution of the tiles
    err = np.linalg.norm(np.asarray(A32.to_dense()) - K, 2)
    assert err < 1e-5


def test_memory_stats_uses_stored_dtype_consistently():
    """Every low-rank byte count follows the *stored* U/V dtype; dense
    diagonal and dense-equivalent counts follow the compute dtype."""
    K = _problem()
    op = TLROperator.compress(jnp.asarray(K), 64, 64, 1e-8,
                              store_dtype=np.float32)
    A = op.A
    m = op.memory_stats()
    assert m["compute_dtype"] == "float64"
    assert m["store_dtype"] == "float32"
    ranks = np.asarray(A.ranks)
    # logical: paper's Sum 2*b*k_ij at the f32 itemsize
    assert m["lowrank_bytes_logical"] == 2 * 64 * int(ranks.sum()) * 4
    # padded: the full zero-padded buffers at the f32 itemsize
    assert m["lowrank_bytes_padded"] == (A.U.size + A.V.size) * 4
    # dense diagonal + dense equivalent at the f64 itemsize
    assert m["dense_diag_bytes"] == A.D.size * 8
    assert m["full_dense_bytes"] == A.n * A.n * 8
    assert m["dense_equivalent_gb"] == pytest.approx(
        m["full_dense_bytes"] / 2**30)
    assert m["total_bytes_logical"] == (m["dense_diag_bytes"]
                                        + m["lowrank_bytes_logical"])
    assert m["total_bytes_padded"] == (m["dense_diag_bytes"]
                                       + m["lowrank_bytes_padded"])


def test_mixed_precision_solve_through_handle():
    """f32-stored operator factors and solves through the handle API."""
    K = _problem()
    op = TLROperator.compress(jnp.asarray(K), 64, 64, 1e-8,
                              store_dtype=np.float32)
    fact = op.cholesky(CholOptions(eps=1e-5, bs=8))
    rng = np.random.default_rng(1)
    X_true = rng.standard_normal((op.n, 2))
    X = np.asarray(fact.solve(jnp.asarray(K @ X_true)))
    assert np.linalg.norm(X - X_true) / np.linalg.norm(X_true) < 1e-2


def test_factorization_with_f32_stored_tiles():
    """Factor a mixed-precision TLR matrix at eps=1e-5: accuracy holds
    (sampling promotes to f64; storage error ~1e-7 stays below eps)."""
    K = _problem()
    A32 = from_dense(jnp.asarray(K), 64, 64, 1e-8, store_dtype=np.float32)
    fact = tlr_cholesky(A32, CholOptions(eps=1e-5, bs=8))
    Ld = np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                         A32.nb, A32.b)))
    err = np.linalg.norm(K - Ld @ Ld.T, 2)
    assert err < 1e-3, err
    # solve still works through the factorization
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(A32.n)
    x = np.asarray(fact.solve(jnp.asarray(K @ x_true)))
    assert np.linalg.norm(x - x_true) / np.linalg.norm(x_true) < 1e-2


def test_matvec_mixed_precision():
    K = _problem()
    A32 = from_dense(jnp.asarray(K), 64, 64, 1e-10, store_dtype=np.float32)
    x = np.random.default_rng(1).standard_normal(A32.n)
    y = np.asarray(tlr_matvec(A32, jnp.asarray(x)))
    np.testing.assert_allclose(y, K @ x, rtol=1e-4, atol=1e-4)
