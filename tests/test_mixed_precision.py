"""Mixed-precision TLR storage (the paper's section 7 proposal):
off-diagonal factors stored low-precision, sampling in high precision."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, covariance_problem, from_dense, tlr_cholesky,
    tlr_factor_solve, tlr_matvec, tlr_to_dense,
)


def _problem(n=512, b=64):
    _, K = covariance_problem(n, 3, b)
    return K


def test_f32_storage_halves_lowrank_memory():
    K = _problem()
    A64 = from_dense(jnp.asarray(K), 64, 64, 1e-8)
    A32 = from_dense(jnp.asarray(K), 64, 64, 1e-8, store_dtype=np.float32)
    m64 = A64.memory_stats()
    m32 = A32.memory_stats()
    assert m32["lowrank_bytes_logical"] * 2 == m64["lowrank_bytes_logical"]
    # reconstruction error bounded by f32 resolution of the tiles
    err = np.linalg.norm(np.asarray(A32.to_dense()) - K, 2)
    assert err < 1e-5


def test_factorization_with_f32_stored_tiles():
    """Factor a mixed-precision TLR matrix at eps=1e-5: accuracy holds
    (sampling promotes to f64; storage error ~1e-7 stays below eps)."""
    K = _problem()
    A32 = from_dense(jnp.asarray(K), 64, 64, 1e-8, store_dtype=np.float32)
    fact = tlr_cholesky(A32, CholOptions(eps=1e-5, bs=8))
    Ld = np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                         A32.nb, A32.b)))
    err = np.linalg.norm(K - Ld @ Ld.T, 2)
    assert err < 1e-3, err
    # solve still works through the factorization
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(A32.n)
    x = np.asarray(tlr_factor_solve(fact, jnp.asarray(K @ x_true)))
    assert np.linalg.norm(x - x_true) / np.linalg.norm(x_true) < 1e-2


def test_matvec_mixed_precision():
    K = _problem()
    A32 = from_dense(jnp.asarray(K), 64, 64, 1e-10, store_dtype=np.float32)
    x = np.random.default_rng(1).standard_normal(A32.n)
    y = np.asarray(tlr_matvec(A32, jnp.asarray(x)))
    np.testing.assert_allclose(y, K @ x, rtol=1e-4, atol=1e-4)
