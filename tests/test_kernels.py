"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle.

Sweeps shapes and dtypes per the deliverable spec; tolerances scale with
dtype (bf16 accumulates in f32 inside the kernels).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.batched_gemm import batched_gemm_pallas
from repro.kernels.batched_qr import batched_qr_pallas
from repro.kernels.lr_sample import lr_sample_pallas
from repro.kernels.small_svd import small_svd_pallas
from repro.kernels.tlr_matvec import tile_chain_pallas

TOL = {
    jnp.float64: dict(rtol=1e-12, atol=1e-12),
    jnp.float32: dict(rtol=1e-5, atol=1e-5),
    jnp.bfloat16: dict(rtol=5e-2, atol=5e-2),
}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.bfloat16])
@pytest.mark.parametrize("T,k,b,r,s", [
    (1, 1, 32, 8, 8),
    (3, 4, 64, 16, 8),
    (2, 7, 128, 32, 16),
    (5, 2, 96, 24, 4),
])
def test_lr_sample_kernel(T, k, b, r, s, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    Ui = _rand(ks[0], (T, k, b, r), dtype)
    Vi = _rand(ks[1], (T, k, b, r), dtype)
    W2 = _rand(ks[2], (k, b, s), dtype)
    got = lr_sample_pallas(Ui, Vi, W2, interpret=True)
    want = ref.lr_sample_ref(Ui, Vi, W2)
    assert got.dtype == dtype
    tol = TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64),
        rtol=tol["rtol"], atol=tol["atol"] * k * np.sqrt(b),
    )


def test_lr_sample_k_zero():
    Ui = jnp.zeros((2, 0, 32, 8))
    Vi = jnp.zeros((2, 0, 32, 8))
    W2 = jnp.zeros((0, 32, 4))
    out = lr_sample_pallas(Ui, Vi, W2, interpret=True)
    assert out.shape == (2, 32, 4)
    assert (np.asarray(out) == 0).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.bfloat16])
@pytest.mark.parametrize("T,m,k,n", [
    (1, 16, 8, 16),
    (4, 64, 32, 8),
    (3, 128, 64, 128),
])
def test_batched_gemm_kernel(T, m, k, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    A = _rand(ks[0], (T, m, k), dtype)
    B = _rand(ks[1], (T, k, n), dtype)
    ranks = jnp.asarray(np.random.default_rng(0).integers(0, k + 1, T),
                        jnp.int32)
    got = batched_gemm_pallas(A, B, ranks, interpret=True)
    want = ref.batched_gemm_ref(A, B, ranks)
    tol = TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64),
        rtol=tol["rtol"], atol=tol["atol"] * np.sqrt(k),
    )


def test_batched_gemm_blocked_grid():
    """Output gridding (bm, bn) must not change results."""
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    A = _rand(ks[0], (2, 128, 32), jnp.float32)
    B = _rand(ks[1], (2, 32, 64), jnp.float32)
    ranks = jnp.asarray([32, 17], jnp.int32)
    got = batched_gemm_pallas(A, B, ranks, bm=64, bn=32, interpret=True)
    want = ref.batched_gemm_ref(A, B, ranks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_batched_gemm_rank_masking():
    """rank=0 rows give exactly zero; full rank gives plain GEMM."""
    A = jnp.ones((2, 8, 4), jnp.float32)
    B = jnp.ones((2, 4, 8), jnp.float32)
    ranks = jnp.asarray([0, 4], jnp.int32)
    got = np.asarray(batched_gemm_pallas(A, B, ranks, interpret=True))
    assert (got[0] == 0).all()
    assert (got[1] == 4).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.bfloat16])
@pytest.mark.parametrize("T,b,r,s", [
    (1, 32, 8, 1),
    (6, 64, 16, 4),
    (3, 128, 48, 2),
])
def test_tile_chain_kernel(T, b, r, s, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    U = _rand(ks[0], (T, b, r), dtype)
    V = _rand(ks[1], (T, b, r), dtype)
    X = _rand(ks[2], (T, b, s), dtype)
    got = tile_chain_pallas(U, V, X, interpret=True)
    want = ref.tile_chain_ref(U, V, X)
    tol = TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64),
        rtol=tol["rtol"], atol=tol["atol"] * np.sqrt(b),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("T,b,r", [(1, 16, 4), (4, 32, 8), (3, 64, 16)])
def test_batched_qr_kernel(T, b, r, dtype):
    """MGS kernel vs the Householder oracle: both must satisfy the
    rounding-pass contract (Y ~= Q R, orthonormal live columns, R upper
    triangular) -- Q itself is not unique, so parity is on the contract."""
    Y = _rand(jax.random.PRNGKey(7), (T, b, r), dtype)
    for Q, R in (batched_qr_pallas(Y, interpret=True), ref.batched_qr_ref(Y)):
        tol = TOL[dtype]
        np.testing.assert_allclose(
            np.asarray(jnp.einsum("tbr,trs->tbs", Q, R), np.float64),
            np.asarray(Y, np.float64), rtol=tol["rtol"],
            atol=tol["atol"] * np.sqrt(b))
        gram = np.asarray(jnp.einsum("tbr,tbs->trs", Q, Q))
        np.testing.assert_allclose(gram, np.broadcast_to(np.eye(r), gram.shape),
                                   atol=10 * tol["atol"])
        assert np.allclose(np.asarray(R), np.triu(np.asarray(R)),
                           atol=tol["atol"])


def test_batched_qr_rank_deficient_drops_columns():
    """Dependent / zero columns must come out exactly zero in Q (inert in
    every downstream product), with the factorization still valid."""
    rng = np.random.default_rng(3)
    Y = rng.standard_normal((2, 24, 6))
    Y[0][:, 4] = 2.0 * Y[0][:, 1] - Y[0][:, 0]
    Y[1][:, 2] = 0.0
    Q, R = batched_qr_pallas(jnp.asarray(Y), interpret=True)
    Q = np.asarray(Q)
    assert np.abs(Q[0][:, 4]).max() == 0.0
    assert np.abs(Q[1][:, 2]).max() == 0.0
    np.testing.assert_allclose(np.einsum("tbr,trs->tbs", Q, np.asarray(R)),
                               Y, atol=1e-10)


@pytest.mark.parametrize("scale", [1e5, 1e-5])
def test_batched_qr_extreme_column_scales(scale):
    """Regression: the drop tolerance must follow the *current* column norms
    each sweep. With tol frozen at rel * max input norm, an f32 panel scaled
    by 1e5 makes tol >= 1 and sweep 2 (unit columns) zeroes everything."""
    Y = scale * _rand(jax.random.PRNGKey(11), (3, 32, 8), jnp.float32)
    Q, R = batched_qr_pallas(Y, interpret=True)
    np.testing.assert_allclose(
        np.asarray(jnp.einsum("tbr,trs->tbs", Q, R), np.float64),
        np.asarray(Y, np.float64), rtol=1e-4, atol=1e-4 * scale)
    gram = np.asarray(jnp.einsum("tbr,tbs->trs", Q, Q))
    np.testing.assert_allclose(gram, np.broadcast_to(np.eye(8), gram.shape),
                               atol=1e-3)


def test_batched_qr_rejects_wide_panels():
    with pytest.raises(ValueError, match="tall panels"):
        batched_qr_pallas(jnp.zeros((1, 8, 16)), interpret=True)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("T,n", [(1, 4), (3, 8), (2, 16)])
def test_small_svd_kernel(T, n, dtype):
    """Jacobi kernel vs the LAPACK oracle: singular values and the
    reconstruction must agree (U/V columns carry a sign ambiguity)."""
    M = _rand(jax.random.PRNGKey(9), (T, n, n), dtype)
    got = ops.small_svd(M, impl="interpret")
    want = ref.small_svd_ref(M)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(got[1], np.float64),
                               np.asarray(want[1], np.float64),
                               rtol=100 * tol["rtol"],
                               atol=100 * tol["atol"])
    for U, s, V in (got, want):
        rec = jnp.einsum("tmn,tn,tkn->tmk", U, s, V)
        np.testing.assert_allclose(np.asarray(rec, np.float64),
                                   np.asarray(M, np.float64),
                                   rtol=tol["rtol"],
                                   atol=100 * tol["atol"] * np.sqrt(n))


def test_small_svd_low_rank_and_sorting():
    rng = np.random.default_rng(5)
    M = np.einsum("tm,tn->tmn", rng.standard_normal((3, 10)),
                  rng.standard_normal((3, 10)))  # rank-1 batch
    U, s, V = ops.small_svd(jnp.asarray(M), impl="interpret")
    s = np.asarray(s)
    assert (np.diff(s, axis=-1) <= 1e-12).all()  # descending
    assert (s[:, 1:] < 1e-10 * s[:, :1]).all()   # rank 1
    with pytest.raises(ValueError, match="n <= m"):
        small_svd_pallas(jnp.zeros((1, 4, 8)), interpret=True)


def test_resolve_impl_rejects_pallas_off_tpu():
    """Satellite contract: impl='pallas' off-TPU must fail *up front* with
    an actionable message, not deep inside pallas_call."""
    if jax.default_backend() == "tpu":  # pragma: no cover
        pytest.skip("on TPU the pallas path is the real one")
    with pytest.raises(RuntimeError, match="requires a TPU backend"):
        ops.resolve_impl("pallas")
    with pytest.raises(RuntimeError, match="interpret"):
        ops.batched_gemm(jnp.zeros((1, 4, 4)), jnp.zeros((1, 4, 4)),
                         jnp.zeros((1,), jnp.int32), impl="pallas")
    with pytest.raises(ValueError, match="must be one of"):
        ops.resolve_impl("cuda")
    assert ops.resolve_impl(None) in ("ref", "pallas")
    assert ops.resolve_impl("interpret") == "interpret"


def test_lr_sample_matches_factorization_sampling():
    """Kernel output == the einsum used inside the factorization samplers."""
    rng = np.random.default_rng(0)
    T, k, b, r, s = 3, 5, 64, 16, 8
    Ui = jnp.asarray(rng.standard_normal((T, k, b, r)))
    Vi = jnp.asarray(rng.standard_normal((T, k, b, r)))
    Uk = jnp.asarray(rng.standard_normal((k, b, r)))
    Vk = jnp.asarray(rng.standard_normal((k, b, r)))
    Om = jnp.asarray(rng.standard_normal((b, s)))
    # shared-omega hoisted intermediate
    W2 = jnp.einsum("jbr,jrs->jbs", Vk, jnp.einsum("jbr,bs->jrs", Uk, Om))
    got = lr_sample_pallas(Ui, Vi, W2, interpret=True)
    T3 = jnp.einsum("tjbr,jbs->tjrs", Vi, W2)
    want = jnp.einsum("tjbr,tjrs->tbs", Ui, T3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10,
                               atol=1e-10)
