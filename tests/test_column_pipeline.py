"""Shape-stable column pipeline: compile-count regression + impl parity.

The factorization driver pads every column's row batch up to a power-of-two
bucket ladder (DESIGN.md section 2) so a handful of compiled ARA-step
variants serve all nb columns. These tests pin that contract:

* the trace counter in ``stats`` stays at O(log nb) executables,
* bucket padding does not change the math (padded slots are inert),
* the Pallas kernels dispatched through ``CholOptions.impl`` match the
  pure-jnp reference end-to-end through a full factorization.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, covariance_problem, from_dense, tlr_cholesky, tlr_ldlt,
    tlr_to_dense,
)
from repro.core.cholesky import _bucket_ladder, _bucket_up, _column_buckets


def _problem(n=512, b=64, r_max=None, eps=1e-7):
    _, K = covariance_problem(n, 3, b)
    A = from_dense(jnp.asarray(K), b, r_max or b, eps)
    return K, A


def _dense_L(fact):
    return np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                           fact.L.nb, fact.L.b)))


# -- bucket ladder unit behavior ----------------------------------------------


def test_bucket_ladder_shape():
    assert _bucket_ladder(1) == [1]
    assert _bucket_ladder(7) == [1, 2, 4, 7]
    assert _bucket_ladder(8) == [1, 2, 4, 8]
    assert _bucket_ladder(15) == [1, 2, 4, 8, 15]
    assert _bucket_up(3, [1, 2, 4, 7]) == 4
    assert _bucket_up(7, [1, 2, 4, 7]) == 7


@pytest.mark.parametrize("nb", [2, 5, 8, 16, 23])
def test_column_buckets_cover_and_bound(nb):
    """Every column fits its bucket pair; #distinct pairs <= ladder length."""
    ladder = _bucket_ladder(nb - 1)
    pairs = set()
    for k in range(nb - 1):
        T, J = nb - 1 - k, k
        Tb, Jb = _column_buckets(nb, k, ladder)
        assert Tb >= T and Jb >= J and Jb >= 1
        pairs.add((Tb, Jb))
    assert len(pairs) <= len(ladder)
    assert len(pairs) <= math.ceil(math.log2(max(2, nb - 1))) + 1


# -- compile-count regression (tentpole acceptance) ----------------------------


@pytest.mark.parametrize("mode", ["dynamic", "fused"])
def test_column_step_compile_count(mode):
    """nb=8, b=64: the ARA column step compiles <= log2(nb)+1 variants."""
    _, A = _problem(n=512, b=64)
    assert A.nb == 8
    fact = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, mode=mode))
    bound = int(math.log2(A.nb)) + 1
    assert fact.stats["column_traces"] <= bound, fact.stats["column_events"]
    # projection / diagonal executables are ladder-bounded too
    assert fact.stats["project_traces"] <= bound
    assert fact.stats["diag_traces"] <= 1
    # steady state: each bucket compiles once, later columns reuse it
    events = fact.stats["column_events"]
    seen = set()
    for ev in events:
        key = (ev["Tb"], ev["Jb"])
        assert ev["traced"] == (key not in seen)
        seen.add(key)


def test_explicit_bucket_still_bounded():
    """Algorithm 5 slot buffers (bucket>0) stay ladder-bounded as well."""
    _, A = _problem(n=512, b=64)
    fact = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, mode="dynamic",
                                       bucket=3))
    # slot batch (one bucketed size) + tail columns: still a handful
    assert fact.stats["column_traces"] <= 2 * (int(math.log2(A.nb)) + 1)


# -- padding is numerically inert ---------------------------------------------


def test_bucketed_accuracy_matches_dense():
    K, A = _problem(n=512, b=64)
    fact = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8))
    Ld = _dense_L(fact)
    err = np.linalg.norm(K - Ld @ Ld.T, 2)
    assert err < 1e-4
    # padded row slots must never leak into stored ranks
    for ev, ranks in zip(fact.stats["column_events"],
                         fact.stats["column_ranks"]):
        assert len(ranks) == ev["T"]


# -- kernel dispatch parity (impl knob) ---------------------------------------


@pytest.mark.parametrize("mode", ["dynamic", "fused"])
def test_impl_interpret_matches_ref(mode):
    """Pallas interpreter path == pure-jnp path through a full factorization."""
    _, A = _problem(n=256, b=64, r_max=32)
    facts = {}
    for impl in ("ref", "interpret"):
        f = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, mode=mode, impl=impl))
        facts[impl] = _dense_L(f)
        assert f.stats["impl"] == impl
    np.testing.assert_allclose(facts["interpret"], facts["ref"],
                               rtol=1e-12, atol=1e-12)


def test_impl_interpret_matches_ref_ldlt():
    """Same parity through the 5-product LDL^T chain (Eq. 3)."""
    _, A = _problem(n=256, b=64, r_max=32)
    facts = {}
    for impl in ("ref", "interpret"):
        f = tlr_ldlt(A, CholOptions(eps=1e-6, bs=8, impl=impl))
        facts[impl] = (_dense_L(f), np.asarray(f.d))
    np.testing.assert_allclose(facts["interpret"][0], facts["ref"][0],
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(facts["interpret"][1], facts["ref"][1],
                               rtol=1e-12, atol=1e-12)


def test_impl_knob_validated():
    _, A = _problem(n=256, b=64, r_max=16)
    with pytest.raises(ValueError, match="impl"):
        tlr_cholesky(A, CholOptions(eps=1e-4, bs=8, impl="cuda"))


def test_dynamic_safety_valve_flushes_live_slots():
    """Regression: when the per-column iteration budget trips the safety
    valve, still-live slots must be flushed with their partial bases.
    Before the fix the loop broke with rows missing from the result dict
    and the assembly crashed with a KeyError."""
    _, A = _problem(n=256, b=64)
    # max_iters=1 with an unreachable eps: nothing converges before the
    # valve (rank cap would need r_max/bs = 16 iterations, valve trips
    # after T_col+1), so every column exercises the flush path.
    with pytest.warns(RuntimeWarning, match="safety valve"):
        fact = tlr_cholesky(A, CholOptions(eps=1e-13, bs=4, mode="dynamic",
                                           max_iters=1))
    assert fact.stats["safety_valve"] is True
    assert np.isfinite(np.asarray(fact.L.V)).all()
    assert np.isfinite(np.asarray(fact.L.U)).all()
    # flushed partial bases still carry the ranks accumulated so far
    for ranks in fact.stats["column_ranks"]:
        assert (np.asarray(ranks) > 0).any()


def test_no_safety_valve_on_converging_problems():
    _, A = _problem(n=256, b=64)
    fact = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, mode="dynamic"))
    assert fact.stats["safety_valve"] is False


@pytest.mark.parametrize("mode", ["dynamic", "fused"])
def test_column_events_report_per_tile_err(mode):
    """Stats-schema parity: dynamic-mode columns report the same per-tile
    ARA error estimates fused mode always has."""
    _, A = _problem(n=256, b=64)
    fact = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, mode=mode))
    assert fact.stats["column_events"], "no columns recorded"
    for ev in fact.stats["column_events"]:
        assert ev["err"].shape == (ev["T"],)
        assert np.isfinite(ev["err"]).all()
        # converged tiles report their final residual estimate, <= eps
        # up to the calibration constant
        assert (ev["err"] <= 1e-4).all()


def test_share_omega_false_through_ops_layer():
    """The per-tile-Omega sampling path also routes through the ops layer."""
    K, A = _problem(n=256, b=64)
    f = tlr_cholesky(A, CholOptions(eps=1e-6, bs=8, share_omega=False,
                                    impl="ref"))
    Ld = _dense_L(f)
    assert np.linalg.norm(K - Ld @ Ld.T, 2) < 1e-4
