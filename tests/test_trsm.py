"""Jitted bucketed TRSM: dense-reference accuracy, parity with the old
host-loop TRSV, compile-count bounds, and LDL^T solves through the handle.

The solve phase gets the same shape-stable contract as the factorization's
column pipeline (tests/test_column_pipeline.py): the column step compiles
one variant per bucket-ladder size and direction, ~log2(nb) executables per
solve shape instead of a host loop over per-block lists.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholOptions, TLROperator, covariance_problem, tlr_trsv,
    tlr_trsv_reference, trsm_trace_count,
)


def _factored(n=512, b=64, eps=1e-8, ldl=False):
    _, K = covariance_problem(n, 3, b)
    op = TLROperator.compress(jnp.asarray(K), b, b, 1e-9)
    opts = CholOptions(eps=eps, bs=8)
    return K, (op.ldlt(opts) if ldl else op.cholesky(opts))


@pytest.fixture(scope="module")
def chol():
    return _factored()


# -- accuracy vs dense reference ----------------------------------------------


@pytest.mark.parametrize("trans", [False, True])
@pytest.mark.parametrize("nrhs", [None, 1, 5])
def test_trsv_matches_dense_reference(chol, trans, nrhs):
    """L x = y (and L^T x = y) against a dense triangular solve, for single
    vectors and batched (n, m) right-hand sides."""
    K, fact = chol
    n = fact.n
    rng = np.random.default_rng(0)
    y = rng.standard_normal(n) if nrhs is None else rng.standard_normal(
        (n, nrhs))
    x = np.asarray(tlr_trsv(fact.L, jnp.asarray(y), trans=trans))
    from repro.core import tlr_to_dense
    Ld = np.tril(np.asarray(tlr_to_dense(fact.L.D, fact.L.U, fact.L.V,
                                         fact.L.nb, fact.L.b)))
    x_ref = np.linalg.solve(Ld.T if trans else Ld, y)
    assert x.shape == y.shape
    np.testing.assert_allclose(x, x_ref, rtol=1e-9, atol=1e-9)


# -- parity with the pre-PR-2 host-loop implementation -------------------------


@pytest.mark.parametrize("trans", [False, True])
@pytest.mark.parametrize("nrhs", [None, 3])
def test_trsv_matches_old_host_loop(chol, trans, nrhs):
    """The jitted bucketed TRSM is the same math as the old python loop;
    f64 round-off only."""
    _, fact = chol
    rng = np.random.default_rng(1)
    y = rng.standard_normal(fact.n) if nrhs is None else rng.standard_normal(
        (fact.n, nrhs))
    yj = jnp.asarray(y)
    new = np.asarray(tlr_trsv(fact.L, yj, trans=trans))
    old = np.asarray(tlr_trsv_reference(fact.L, yj, trans=trans))
    np.testing.assert_allclose(new, old, rtol=1e-13, atol=1e-13)


# -- compile-count regression (tentpole acceptance) ----------------------------


@pytest.mark.slow
def test_trsm_compile_count_bounded():
    """A fresh (nb, b, m) solve shape compiles <= ladder * 2 directions
    variants; repeat solves compile nothing."""
    _, fact = _factored(n=1024, b=64)   # nb = 16, a fresh solve shape
    nb = fact.nb
    rng = np.random.default_rng(2)
    y = jnp.asarray(rng.standard_normal((fact.n, 2)))

    before = trsm_trace_count()
    fact.solve(y)                       # both triangles
    compiled = trsm_trace_count() - before
    bound = 2 * (int(math.log2(nb - 1)) + 2)   # ladder len * 2 directions
    assert 0 < compiled <= bound, compiled

    again = trsm_trace_count()
    fact.solve(y + 1.0)
    fact.solve(2.0 * y)
    assert trsm_trace_count() == again  # steady state: zero retraces


def test_trsm_trace_counter_monotone(chol):
    _, fact = chol
    y = jnp.asarray(np.random.default_rng(3).standard_normal(fact.n))
    c0 = trsm_trace_count()
    tlr_trsv(fact.L, y)
    c1 = trsm_trace_count()
    tlr_trsv(fact.L, y)
    assert c1 >= c0 and trsm_trace_count() == c1


# -- solves through the factorization handle -----------------------------------


def test_cholesky_handle_solve(chol):
    K, fact = chol
    rng = np.random.default_rng(4)
    x_true = rng.standard_normal(fact.n)
    x = np.asarray(fact.solve(jnp.asarray(K @ x_true)))
    assert np.linalg.norm(x - x_true) / np.linalg.norm(x_true) < 1e-3


def test_ldlt_handle_solve_single_and_multi():
    K, fact = _factored(eps=1e-7, ldl=True)
    assert fact.is_ldlt
    rng = np.random.default_rng(5)
    X_true = rng.standard_normal((fact.n, 4))
    X = np.asarray(fact.solve(jnp.asarray(K @ X_true)))
    assert X.shape == X_true.shape
    assert np.linalg.norm(X - X_true) / np.linalg.norm(X_true) < 1e-2
    x1 = np.asarray(fact.solve(jnp.asarray(K @ X_true[:, 0])))
    np.testing.assert_allclose(x1, X[:, 0], rtol=1e-8, atol=1e-10)


def test_tri_solve_roundtrip(chol):
    """fact.tri_solve inverts fact.tri_matvec on both triangles."""
    _, fact = chol
    x = jnp.asarray(np.random.default_rng(6).standard_normal((fact.n, 2)))
    for trans in (False, True):
        y = fact.tri_matvec(x, trans=trans)
        x2 = fact.tri_solve(y, trans=trans)
        np.testing.assert_allclose(np.asarray(x2), np.asarray(x),
                                   rtol=1e-8, atol=1e-8)


def test_trsv_single_tile_matrix():
    """nb == 1 degenerates to one dense triangular solve."""
    rng = np.random.default_rng(7)
    M = rng.standard_normal((64, 64))
    K = M @ M.T + 64 * np.eye(64)
    op = TLROperator.compress(jnp.asarray(K), 64, 64, 1e-10)
    fact = op.cholesky(CholOptions(eps=1e-8, bs=8))
    x_true = rng.standard_normal(64)
    x = np.asarray(fact.solve(jnp.asarray(K @ x_true)))
    np.testing.assert_allclose(x, x_true, rtol=1e-6, atol=1e-8)
