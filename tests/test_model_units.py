"""Unit tests for model substrate: chunked attention and SSD vs oracles,
decode-vs-forward consistency, MoE dispatch invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.models.config import ModelConfig, MoEConfig, SSMConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM


# -- attention ------------------------------------------------------------------


def _naive_attention(q, k, v, causal):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qh, k.astype(jnp.float32))
    logits = logits / np.sqrt(hd).astype(np.float32)
    if causal:
        Sk = k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H * hd)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("S,H,KV,hd,qc,kc", [
    (128, 4, 2, 16, 32, 32),
    (96, 6, 6, 8, 32, 48),
    (64, 8, 2, 32, 64, 16),
])
def test_chunked_attention_matches_naive(S, H, KV, hd, qc, kc, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    got = L.chunked_attention(q, k, v, causal=causal, q_chunk=qc, k_chunk=kc)
    want = _naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_prefix():
    """Decoding token t against a cache == full attention at position t."""
    cfg = get_config("qwen1_5_0_5b", smoke=True)
    p = L.init_attention(jax.random.PRNGKey(1), cfg, jnp.float32)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model),
                          jnp.float32) * 0.1
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    full = L.attention_block(p, x, cfg, pos, causal=True)

    cache = L.KVCache(
        k=jnp.zeros((B, S, cfg.num_kv_heads, cfg.hd), jnp.float32),
        v=jnp.zeros((B, S, cfg.num_kv_heads, cfg.hd), jnp.float32),
    )
    outs = []
    for t in range(S):
        out, cache = L.decode_attention(p, x[:, t : t + 1], cfg, cache,
                                        jnp.asarray(t, jnp.int32))
        outs.append(out)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


# -- SSD -----------------------------------------------------------------------


def _ssm_smoke_cfg():
    return ModelConfig(
        name="ssd-test", family="ssm", num_layers=1, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
        ssm=SSMConfig(d_state=8, expand=2, head_dim=8, conv_width=4, chunk=8),
        dtype="float32", remat=False,
    )


def test_ssd_forward_matches_decode_recurrence():
    """Chunked SSD forward == token-by-token recurrent decode."""
    cfg = _ssm_smoke_cfg()
    p = SSM.init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, Ln = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, Ln, cfg.d_model),
                          jnp.float32) * 0.5
    y_full = SSM.ssd_forward(p, x, cfg)

    state = SSM.ssm_init_state(cfg, B, jnp.float32)
    outs = []
    for t in range(Ln):
        out, state = SSM.ssd_decode_step(p, x[:, t : t + 1], cfg, state)
        outs.append(out)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunk_invariance():
    """Different SSD chunk sizes give identical results."""
    cfg8 = _ssm_smoke_cfg()
    import dataclasses
    cfg16 = dataclasses.replace(cfg8, ssm=dataclasses.replace(cfg8.ssm,
                                                              chunk=16))
    p = SSM.init_ssm(jax.random.PRNGKey(0), cfg8, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg8.d_model),
                          jnp.float32)
    y8 = SSM.ssd_forward(p, x, cfg8)
    y16 = SSM.ssd_forward(p, x, cfg16)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), rtol=1e-5,
                               atol=1e-5)


def test_ssd_causality():
    """Future tokens must not influence past outputs."""
    cfg = _ssm_smoke_cfg()
    p = SSM.init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, cfg.d_model),
                          jnp.float32)
    y1 = SSM.ssd_forward(p, x, cfg)
    x2 = x.at[:, 20:].set(0.0)
    y2 = SSM.ssd_forward(p, x2, cfg)
    np.testing.assert_allclose(np.asarray(y1[:, :20]), np.asarray(y2[:, :20]),
                               rtol=1e-5, atol=1e-6)


# -- MoE ------------------------------------------------------------------------


def _moe_cfg(top_k=2, experts=4, cf=10.0):
    return ModelConfig(
        name="moe-test", family="moe", num_layers=1, d_model=16,
        num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
        moe=MoEConfig(num_experts=experts, top_k=top_k, d_ff_expert=32,
                      group_size=32, capacity_factor=cf),
        dtype="float32", remat=False,
    )


def test_moe_matches_dense_routing_oracle():
    """With huge capacity (no drops), GShard dispatch == direct top-k oracle."""
    cfg = _moe_cfg()
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16), jnp.float32)
    y, aux = MOE.apply_moe(p, x, cfg)

    # oracle: per token, run its top-k experts densely
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.moe.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(x))
    xn = np.asarray(x)
    for b in range(2):
        for t in range(32):
            acc = np.zeros(16)
            for kk in range(cfg.moe.top_k):
                e = int(gi[b, t, kk])
                h = np.maximum(
                    xn[b, t] @ np.asarray(p["wg"][e]), 0) * 0  # placeholder
                hg = xn[b, t] @ np.asarray(p["wg"][e])
                hu = xn[b, t] @ np.asarray(p["wu"][e])
                silu = hg / (1 + np.exp(-hg)) * hu
                acc += float(gv[b, t, kk]) * (silu @ np.asarray(p["wd"][e]))
            want[b, t] = acc
    np.testing.assert_allclose(np.asarray(y[0]), want[0], rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """Tiny capacity factor must drop tokens (outputs shrink), not crash."""
    cfg_big = _moe_cfg(cf=10.0)
    cfg_small = _moe_cfg(cf=0.1)
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg_big, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16), jnp.float32)
    y_big, _ = MOE.apply_moe(p, x, cfg_big)
    y_small, _ = MOE.apply_moe(p, x, cfg_small)
    assert float(jnp.linalg.norm(y_small)) < float(jnp.linalg.norm(y_big))


def test_embedding_tied_vs_untied():
    cfg_t = get_config("qwen1_5_0_5b", smoke=True)   # tied
    cfg_u = get_config("phi3_mini_3_8b", smoke=True)  # untied
    pt = init_model(jax.random.PRNGKey(0), cfg_t)
    pu = init_model(jax.random.PRNGKey(0), cfg_u)
    assert "head" not in pt["emb"]
    assert "head" in pu["emb"]


def test_int8_kv_cache_decode_close():
    """int8-quantized KV cache decode stays close to the f32-cache result."""
    import dataclasses
    from repro.models import init_decode_caches, build_serve_step
    from repro.models.api import _enc_len
    cfg = get_config("qwen1_5_0_5b", smoke=True)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    tok = jnp.ones((B, 1), jnp.int32) * 5
    outs = {}
    for c in (cfg, cfg8):
        caches = init_decode_caches(c, B, S, ctx_len=_enc_len(c, S))
        logits = None
        cl = jnp.asarray(0, jnp.int32)
        serve = build_serve_step(c)
        for t in range(4):
            logits, caches = serve(params, caches, tok + t,
                                   jnp.asarray(t, jnp.int32))
        outs[c.kv_cache_dtype or "bf16"] = np.asarray(logits, np.float32)
    ref, q8 = outs["bf16"], outs["int8"]
    # top-1 prediction agreement + bounded logit error
    assert np.argmax(ref[0, 0]) == np.argmax(q8[0, 0])
    rel = np.abs(ref - q8).max() / (np.abs(ref).max() + 1e-9)
    assert rel < 0.15, rel
