"""Unit tests: TLR representation, generators, ordering, ARA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ARAParams, TLRMatrix, ara_compress_dense, covariance_problem,
    exp_covariance, fractional_diffusion, from_dense, grid_points,
    ball_points, kd_tree_ordering, morton_ordering, tlr_matvec, tril_index,
    tril_pairs, num_tiles,
)


def test_tril_indexing():
    nb = 7
    pairs = tril_pairs(nb)
    assert pairs.shape == (num_tiles(nb), 2)
    for t, (i, j) in enumerate(pairs):
        assert tril_index(int(i), int(j)) == t
        assert i > j


def test_grid_and_ball_points():
    for d in (2, 3):
        g = grid_points(1000, d)
        assert g.shape == (1000, d)
        assert g.min() >= 0 and g.max() <= 1
        b = ball_points(500, d, seed=1)
        assert (np.linalg.norm(b, axis=1) <= 1.0 + 1e-12).all()


def test_exp_covariance_spd():
    pts = ball_points(256, 3, seed=0)
    K = exp_covariance(pts, 0.2)
    w = np.linalg.eigvalsh(K)
    assert w.min() > 0


def test_fractional_diffusion_spd_illcond():
    pts = grid_points(512, 3)
    K = fractional_diffusion(pts, s=0.75)
    w = np.linalg.eigvalsh(K)
    assert w.min() > 0, "fractional diffusion matrix must stay SPD"
    assert w.max() / w.min() > 1e3, "should be ill-conditioned"


def test_kd_ordering_is_permutation():
    pts = ball_points(1024, 3, seed=2)
    perm = kd_tree_ordering(pts, 128)
    assert sorted(perm.tolist()) == list(range(1024))
    mperm = morton_ordering(pts)
    assert sorted(mperm.tolist()) == list(range(1024))


def test_kd_ordering_improves_ranks():
    """KD-tree ordering should lower off-diagonal tile ranks vs random order."""
    n, b = 1024, 128
    pts = ball_points(n, 3, seed=3)
    K_raw = exp_covariance(pts, 0.2)
    K_ord = exp_covariance(pts[kd_tree_ordering(pts, b)], 0.2)

    def total_rank(K):
        A = from_dense(jnp.asarray(K), b, b, 1e-6)
        return int(np.asarray(A.ranks).sum())

    assert total_rank(K_ord) < total_rank(K_raw)


def test_from_dense_roundtrip():
    n, b = 512, 64
    _, K = covariance_problem(n, 2, b)
    A = from_dense(jnp.asarray(K), b, b, 1e-8)
    err = np.linalg.norm(np.asarray(A.to_dense()) - K, 2)
    assert err < 1e-6
    stats = A.memory_stats()
    assert stats["compression_ratio"] > 1.0


def test_tlr_matvec_matches_dense():
    n, b = 512, 64
    _, K = covariance_problem(n, 3, b)
    A = from_dense(jnp.asarray(K), b, 48, 1e-7)
    x = np.random.default_rng(0).standard_normal(n)
    y_tlr = np.asarray(tlr_matvec(A, jnp.asarray(x)))
    y_ref = np.asarray(A.to_dense()) @ x
    np.testing.assert_allclose(y_tlr, y_ref, rtol=1e-10, atol=1e-10)
    # multi-vector
    X = np.random.default_rng(1).standard_normal((n, 3))
    Y = np.asarray(tlr_matvec(A, jnp.asarray(X)))
    np.testing.assert_allclose(Y, np.asarray(A.to_dense()) @ X, rtol=1e-10,
                               atol=1e-10)


@pytest.mark.parametrize("share_omega", [True, False])
def test_ara_dense_compression(share_omega):
    """ARA on a batch of dense low-rank-ish operators reaches eps accuracy."""
    rng = np.random.default_rng(0)
    T, b, true_rank = 5, 96, 12
    mats = []
    for t in range(T):
        u = rng.standard_normal((b, true_rank))
        s = np.geomspace(1.0, 1e-9, true_rank)
        v = rng.standard_normal((b, true_rank))
        mats.append((u * s) @ v.T)
    A = jnp.asarray(np.stack(mats))
    p = ARAParams(bs=8, r_max=64, eps=1e-6)
    Q, B, ranks, state = ara_compress_dense(
        A, jax.random.PRNGKey(0), p, share_omega=share_omega)
    approx = np.einsum("tbr,tmr->tbm", np.asarray(Q), np.asarray(B))
    for t in range(T):
        err = np.linalg.norm(np.asarray(A[t]) - approx[t], 2)
        assert err < 50 * p.eps, f"tile {t}: err {err}"
        assert int(ranks[t]) <= 40  # does not badly overshoot true rank 12


def test_ara_rank_adaptivity():
    """Tiles with different true ranks get different detected ranks."""
    rng = np.random.default_rng(1)
    b = 96
    mats = []
    for true_rank in (2, 30):
        u = rng.standard_normal((b, true_rank))
        v = rng.standard_normal((b, true_rank))
        mats.append(u @ v.T / true_rank)
    A = jnp.asarray(np.stack(mats))
    p = ARAParams(bs=4, r_max=64, eps=1e-8)
    _, _, ranks, _ = ara_compress_dense(A, jax.random.PRNGKey(0), p)
    assert int(ranks[0]) < int(ranks[1])
    assert int(ranks[0]) >= 2 and int(ranks[1]) >= 30


def test_ara_orthonormal_basis():
    rng = np.random.default_rng(2)
    b = 64
    A = jnp.asarray(rng.standard_normal((1, b, b)) @ np.diag(np.geomspace(1, 1e-10, b)))
    p = ARAParams(bs=8, r_max=64, eps=1e-5)
    Q, _, ranks, _ = ara_compress_dense(A, jax.random.PRNGKey(1), p)
    k = int(ranks[0])
    Qk = np.asarray(Q[0][:, :k])
    np.testing.assert_allclose(Qk.T @ Qk, np.eye(k), atol=1e-10)
    # padded columns stay exactly zero
    assert np.all(np.asarray(Q[0][:, k:]) == 0.0)
