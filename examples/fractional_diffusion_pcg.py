"""End-to-end driver for the paper's section 6.2 experiment: factor an
ill-conditioned 3D fractional-diffusion operator at low accuracy and use it
as a PCG preconditioner. ``pcg`` consumes the handles directly: the
``TLROperator`` is the matvec, the ``TLRFactorization`` the preconditioner.

Run:  PYTHONPATH=src python examples/fractional_diffusion_pcg.py [--n 2048]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import (  # noqa: E402
    CholOptions, TLROperator, fractional_diffusion_problem, pcg,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--tile", type=int, default=128)
    args = ap.parse_args()

    print(f"building 3D fractional-diffusion matrix, N={args.n}")
    _, Kfd = fractional_diffusion_problem(args.n, args.tile)
    cond = np.linalg.cond(Kfd) if args.n <= 4096 else float("nan")
    print(f"condition number ~ {cond:.2e}")
    op = TLROperator.compress(jnp.asarray(Kfd), args.tile, eps=1e-10)
    rhs = jnp.asarray(np.random.default_rng(0).standard_normal(args.n))

    print(f"{'eps':>8} {'factor_s':>9} {'cg_iters':>8} {'residual':>10}")
    for eps in (1e-1, 1e-2, 1e-4, 1e-6):
        # paper: factor A + eps*I to preserve definiteness at loose eps
        Keps = Kfd + eps * np.eye(args.n)
        op_eps = TLROperator.compress(jnp.asarray(Keps), args.tile,
                                      eps=min(eps * 1e-2, 1e-8))
        t0 = time.perf_counter()
        fact = op_eps.cholesky(CholOptions(eps=eps, bs=16, schur="diag"))
        t_fact = time.perf_counter() - t0
        x, iters, hist = pcg(op, rhs, precond=fact, tol=1e-6, maxiter=300)
        print(f"{eps:>8g} {t_fact:>9.2f} {iters:>8d} {hist[-1]:>10.2e}")

    _, it_plain, hist = pcg(op, rhs, tol=1e-6, maxiter=300)
    print(f"unpreconditioned CG: {it_plain} iters, residual {hist[-1]:.2e}")


if __name__ == "__main__":
    main()
