"""End-to-end driver for the paper's section 6.2 experiment: factor an
ill-conditioned 3D fractional-diffusion operator at low accuracy and use it
as a PCG preconditioner. ``pcg`` consumes the handles directly: the
``TLROperator`` is the matvec, the ``TLRFactorization`` the preconditioner.

Beyond the paper, the tile algebra of PR 3 adds a second preconditioner
family: a Newton-Schulz TLR approximate inverse (core/precond.py), built
from ``tlr_gemm`` + ``tlr_axpy`` + rounding alone -- no factorization --
whose ``.matvec`` plugs into the same ``pcg`` slot.

Run:  PYTHONPATH=src python examples/fractional_diffusion_pcg.py [--n 2048]
      ... --suite ns --check     # Newton-Schulz only + CI assertion
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import (  # noqa: E402
    CholOptions, TLROperator, fractional_diffusion_problem, pcg,
    tlr_newton_schulz,
)


def run_cholesky(op, Kfd, rhs, args):
    print(f"{'eps':>8} {'factor_s':>9} {'cg_iters':>8} {'residual':>10}")
    for eps in (1e-1, 1e-2, 1e-4, 1e-6):
        # paper: factor A + eps*I to preserve definiteness at loose eps
        Keps = Kfd + eps * np.eye(args.n)
        op_eps = TLROperator.compress(jnp.asarray(Keps), args.tile,
                                      eps=min(eps * 1e-2, 1e-8))
        t0 = time.perf_counter()
        fact = op_eps.cholesky(CholOptions(eps=eps, bs=16, schur="diag"))
        t_fact = time.perf_counter() - t0
        x, iters, hist = pcg(op, rhs, precond=fact, tol=1e-6, maxiter=300)
        print(f"{eps:>8g} {t_fact:>9.2f} {iters:>8d} {hist[-1]:>10.2e}")


def run_newton_schulz(op, rhs, it_plain, args):
    print(f"{'ns_iters':>8} {'build_s':>9} {'cg_iters':>8} {'residual':>10}"
          f" {'avg_rank':>8}")
    best = it_plain
    for ns_iters in sorted({4, args.ns_iters}):
        t0 = time.perf_counter()
        # norm scaling (alpha = 1/||A||_2 est) compresses the condition
        # number by ~2^iters; trace scaling is the always-safe default
        Xop, info = tlr_newton_schulz(op, iters=ns_iters, eps=args.ns_eps,
                                      scale="norm")
        t_build = time.perf_counter() - t0
        x, iters, hist = pcg(op, rhs, precond=Xop, tol=1e-6, maxiter=300)
        print(f"{ns_iters:>8d} {t_build:>9.2f} {iters:>8d} {hist[-1]:>10.2e}"
              f" {info.avg_rank:>8.1f}")
        best = min(best, iters)
    if args.check:
        assert best < it_plain, (
            f"Newton-Schulz PCG ({best} iters) did not beat "
            f"unpreconditioned PCG ({it_plain} iters)")
        print(f"check OK: {best} < {it_plain} unpreconditioned iters")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--suite", default="all",
                    choices=("all", "cholesky", "ns"))
    ap.add_argument("--ns-iters", type=int, default=8)
    ap.add_argument("--ns-eps", type=float, default=1e-8)
    ap.add_argument("--check", action="store_true",
                    help="assert the Newton-Schulz preconditioner reduces "
                         "PCG iterations (CI examples-smoke)")
    args = ap.parse_args()

    print(f"building 3D fractional-diffusion matrix, N={args.n}")
    _, Kfd = fractional_diffusion_problem(args.n, args.tile)
    cond = np.linalg.cond(Kfd) if args.n <= 4096 else float("nan")
    print(f"condition number ~ {cond:.2e}")
    op = TLROperator.compress(jnp.asarray(Kfd), args.tile, eps=1e-10)
    rhs = jnp.asarray(np.random.default_rng(0).standard_normal(args.n))

    if args.suite in ("all", "cholesky"):
        run_cholesky(op, Kfd, rhs, args)

    _, it_plain, hist = pcg(op, rhs, tol=1e-6, maxiter=300)
    print(f"unpreconditioned CG: {it_plain} iters, residual {hist[-1]:.2e}")

    if args.suite in ("all", "ns"):
        run_newton_schulz(op, rhs, it_plain, args)


if __name__ == "__main__":
    main()
