"""Quickstart: build a spatial-statistics covariance matrix, factor it in
TLR form with ARA, solve, and sample -- the paper's core workflow, through
the operator-first API (compress -> factor -> solve/logdet/sample).

Run:  PYTHONPATH=src python examples/quickstart.py [--n 2048] [--eps 1e-6]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import CholOptions, TLROperator, covariance_problem  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--eps", type=float, default=1e-6)
    ap.add_argument("--dim", type=int, default=3)
    args = ap.parse_args()

    print(f"building {args.dim}D exponential covariance, N={args.n}, "
          f"tile={args.tile}")
    pts, K = covariance_problem(args.n, args.dim, args.tile)
    op = TLROperator.compress(jnp.asarray(K), args.tile, eps=args.eps * 1e-2)
    mem = op.memory_stats()
    print(f"TLR memory: {mem['total_bytes_logical']/2**20:.1f} MiB "
          f"(dense {mem['full_dense_bytes']/2**20:.1f} MiB = "
          f"{mem['dense_equivalent_gb']:.3f} GiB, "
          f"compression {mem['compression_ratio']:.1f}x, "
          f"avg rank {mem['avg_rank']:.1f})")

    print(f"factoring with ARA Cholesky (eps={args.eps}, dynamic batching)")
    fact = op.cholesky(CholOptions(eps=args.eps, bs=16, mode="dynamic"))
    ranks = np.asarray(fact.L.ranks)
    print(f"factor ranks: avg {ranks.mean():.1f}, max {ranks.max()}")

    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(args.n)
    y = jnp.asarray(K @ x_true)
    x = fact.solve(y)
    rel = float(jnp.linalg.norm(x - x_true) / np.linalg.norm(x_true))
    print(f"solve relative error: {rel:.2e}")

    # batched right-hand sides go through the same jitted TRSM
    Y = jnp.asarray(K @ rng.standard_normal((args.n, 4)))
    X = fact.solve(Y)
    print(f"batched solve: rhs {Y.shape} -> {X.shape}")

    ld = float(fact.logdet())
    _, ld_ref = np.linalg.slogdet(K)
    print(f"logdet: {ld:.4f} (dense {ld_ref:.4f})")

    s = fact.sample(jax.random.PRNGKey(0), num=2)
    print(f"MVN samples: shape {s.shape}, std {float(jnp.std(s)):.3f}")

    r = op @ x - y
    print(f"matvec residual check: {float(jnp.linalg.norm(r)):.2e}")


if __name__ == "__main__":
    main()
