"""Quickstart: build a spatial-statistics covariance matrix, factor it in
TLR form with ARA, solve, and sample -- the paper's core workflow.

Run:  PYTHONPATH=src python examples/quickstart.py [--n 2048] [--eps 1e-6]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import (  # noqa: E402
    CholOptions, covariance_problem, from_dense, mvn_sample, tlr_cholesky,
    tlr_factor_solve, tlr_logdet, tlr_matvec,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--eps", type=float, default=1e-6)
    ap.add_argument("--dim", type=int, default=3)
    args = ap.parse_args()

    print(f"building {args.dim}D exponential covariance, N={args.n}, "
          f"tile={args.tile}")
    pts, K = covariance_problem(args.n, args.dim, args.tile)
    A = from_dense(jnp.asarray(K), args.tile, args.tile, args.eps * 1e-2)
    mem = A.memory_stats()
    print(f"TLR memory: {mem['total_bytes_logical']/2**20:.1f} MiB "
          f"(dense {mem['full_dense_bytes']/2**20:.1f} MiB, "
          f"compression {mem['compression_ratio']:.1f}x, "
          f"avg rank {mem['avg_rank']:.1f})")

    print(f"factoring with ARA Cholesky (eps={args.eps}, dynamic batching)")
    fact = tlr_cholesky(A, CholOptions(eps=args.eps, bs=16, mode="dynamic"))
    ranks = np.asarray(fact.L.ranks)
    print(f"factor ranks: avg {ranks.mean():.1f}, max {ranks.max()}")

    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(args.n)
    y = jnp.asarray(K @ x_true)
    x = tlr_factor_solve(fact, y)
    rel = float(jnp.linalg.norm(x - x_true) / np.linalg.norm(x_true))
    print(f"solve relative error: {rel:.2e}")

    ld = float(tlr_logdet(fact))
    _, ld_ref = np.linalg.slogdet(K)
    print(f"logdet: {ld:.4f} (dense {ld_ref:.4f})")

    s = mvn_sample(fact, jax.random.PRNGKey(0), num=2)
    print(f"MVN samples: shape {s.shape}, std {float(jnp.std(s)):.3f}")

    r = tlr_matvec(A, x) - y
    print(f"matvec residual check: {float(jnp.linalg.norm(r)):.2e}")


if __name__ == "__main__":
    main()
