"""Gaussian-process workflow on a TLR-factored covariance: log-likelihood
evaluation and posterior sampling (the paper's spatial-statistics use case).

Run:  PYTHONPATH=src python examples/gaussian_process.py [--n 2048]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import (  # noqa: E402
    CholOptions, covariance_problem, from_dense, mvn_sample, tlr_cholesky,
    tlr_factor_solve, tlr_logdet,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--tile", type=int, default=128)
    args = ap.parse_args()

    pts, K = covariance_problem(args.n, 2, args.tile, geometry="ball", seed=3)
    A = from_dense(jnp.asarray(K), args.tile, args.tile, 1e-8)
    fact = tlr_cholesky(A, CholOptions(eps=1e-6, bs=16))

    # draw a "true" field and observe it
    y = mvn_sample(fact, jax.random.PRNGKey(1))
    print(f"sampled GP field: n={args.n}, std={float(jnp.std(y)):.3f}")

    # log-likelihood:  -0.5 (y^T K^{-1} y + logdet K + n log 2pi)
    alpha = tlr_factor_solve(fact, y)
    ll = -0.5 * (float(y @ alpha) + float(tlr_logdet(fact))
                 + args.n * np.log(2 * np.pi))
    # dense reference
    ll_ref = -0.5 * (y @ np.linalg.solve(K, np.asarray(y))
                     + np.linalg.slogdet(K)[1] + args.n * np.log(2 * np.pi))
    print(f"TLR log-likelihood:   {ll:.3f}")
    print(f"dense log-likelihood: {float(ll_ref):.3f}")
    print(f"abs diff: {abs(ll - float(ll_ref)):.2e}")

    # sweep the correlation length: model selection via TLR loglik
    from repro.core.generators import exp_covariance
    print(f"{'ell':>6} {'loglik':>12}")
    for ell in (0.05, 0.1, 0.2, 0.4):
        Ke = exp_covariance(pts, ell)
        Ae = from_dense(jnp.asarray(Ke), args.tile, args.tile, 1e-8)
        fe = tlr_cholesky(Ae, CholOptions(eps=1e-6, bs=16))
        a = tlr_factor_solve(fe, y)
        l = -0.5 * (float(y @ a) + float(tlr_logdet(fe))
                    + args.n * np.log(2 * np.pi))
        print(f"{ell:>6} {l:>12.2f}")


if __name__ == "__main__":
    main()
