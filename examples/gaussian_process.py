"""Gaussian-process workflow on a TLR-factored covariance: log-likelihood
evaluation and posterior sampling (the paper's spatial-statistics use case),
through the operator-first API -- the correlation-length sweep builds each
candidate operator directly from the point cloud with
``TLROperator.from_kernel``.

Run:  PYTHONPATH=src python examples/gaussian_process.py [--n 2048]
      [--trace out.json]   # Perfetto trace of the whole workflow
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro import obs  # noqa: E402
from repro.core import CholOptions, TLROperator, covariance_problem  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record telemetry and write a Chrome-trace / "
                         "Perfetto JSON (load at ui.perfetto.dev)")
    args = ap.parse_args()

    if args.trace:
        obs.enable()

    pts, K = covariance_problem(args.n, 2, args.tile, geometry="ball", seed=3)
    op = TLROperator.compress(jnp.asarray(K), args.tile, eps=1e-8)
    fact = op.cholesky(CholOptions(eps=1e-6, bs=16))

    # draw a "true" field and observe it
    y = fact.sample(jax.random.PRNGKey(1))
    print(f"sampled GP field: n={args.n}, std={float(jnp.std(y)):.3f}")

    # log-likelihood:  -0.5 (y^T K^{-1} y + logdet K + n log 2pi)
    alpha = fact.solve(y)
    ll = -0.5 * (float(y @ alpha) + float(fact.logdet())
                 + args.n * np.log(2 * np.pi))
    # dense reference
    ll_ref = -0.5 * (y @ np.linalg.solve(K, np.asarray(y))
                     + np.linalg.slogdet(K)[1] + args.n * np.log(2 * np.pi))
    print(f"TLR log-likelihood:   {ll:.3f}")
    print(f"dense log-likelihood: {float(ll_ref):.3f}")
    print(f"abs diff: {abs(ll - float(ll_ref)):.2e}")

    # sweep the correlation length: model selection via TLR loglik, each
    # candidate operator built straight from the (KD-ordered) points
    print(f"{'ell':>6} {'loglik':>12}")
    for ell in (0.05, 0.1, 0.2, 0.4):
        oe = TLROperator.from_kernel(pts, "exp", tile=args.tile, eps=1e-8,
                                     ell=ell)
        fe = oe.cholesky(CholOptions(eps=1e-6, bs=16))
        a = fe.solve(y)
        l = -0.5 * (float(y @ a) + float(fe.logdet())
                    + args.n * np.log(2 * np.pi))
        print(f"{ell:>6} {l:>12.2f}")

    if args.trace:
        obs.record_retraces()
        obs.export_chrome_trace(args.trace)
        snap = obs.metrics_snapshot()
        obs.disable()
        print(f"wrote {args.trace}: {snap['spans']} spans, "
              f"wall {snap['wall_s']:.2f}s"
              + (f", padded/useful {snap['padded_flop_ratio']:.2f}"
                 if "padded_flop_ratio" in snap else ""))


if __name__ == "__main__":
    main()
