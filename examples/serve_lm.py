"""Batched serving demo: continuous batching over decode slots (the
serving-side mirror of the paper's dynamic batched ARA -- converged work
leaves the batch, queued work enters, shapes stay fixed).

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 8 --slots 3
"""

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import init_model
from repro.train import DecodeServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"initializing {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")
    params = init_model(jax.random.PRNGKey(0), cfg)
    srv = DecodeServer(cfg, params, slots=args.slots, max_len=128)

    reqs = [Request(prompt=[1 + i, 2 + i, 3 + i], max_new_tokens=args.max_new,
                    temperature=0.0 if i % 2 == 0 else 0.8, rid=i)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = srv.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(c.tokens) for c in done)
    print(f"served {len(done)} requests / {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s) with {args.slots} slots")
    for c in sorted(done, key=lambda c: c.rid):
        print(f"  request {c.rid}: {c.tokens}")


if __name__ == "__main__":
    main()
