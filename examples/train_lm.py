"""End-to-end LM training driver: any registry architecture, synthetic
corpus, checkpoint/restart, optional ARA gradient compression.

Presets:
  smoke -- reduced config, 200 steps (runs in minutes on CPU; CI default)
  100m  -- qwen1.5-0.5b-family config trimmed to ~100M params, a few hundred
           steps (hours on a single CPU core; sized for a real accelerator)

Run:  PYTHONPATH=src python examples/train_lm.py --arch qwen1.5-0.5b \
          --preset smoke --steps 200
Kill and re-run with the same --ckpt-dir to see auto-resume; SIGTERM
triggers a preemption checkpoint (fault-tolerance demo).
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.optim import AdamWConfig, CompressConfig
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=["smoke", "100m"], default="smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--compress-rank", type=int, default=0,
                    help="enable ARA low-rank gradient compression")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    if args.preset == "smoke":
        cfg = get_config(args.arch, smoke=True)
        batch, seq = args.batch or 8, args.seq or 128
    else:
        cfg = get_config(args.arch)
        # trim to ~100M: 12 layers of the published width
        cfg = dataclasses.replace(cfg, num_layers=12, dtype="float32",
                                  remat=False)
        batch, seq = args.batch or 8, args.seq or 512
        print(f"~{cfg.param_count()/1e6:.0f}M params")

    tcfg = TrainConfig(
        steps=args.steps, batch=batch, seq_len=seq,
        ckpt_dir=args.ckpt_dir, save_every=max(args.steps // 4, 10),
        log_every=10, metrics_path=f"{args.ckpt_dir}/metrics.jsonl",
        optimizer=AdamWConfig(lr=args.lr),
        compress=CompressConfig(rank=args.compress_rank)
        if args.compress_rank else None,
    )
    out = Trainer(cfg, tcfg).run()
    losses = out["losses"]
    if losses:
        print(f"status={out['status']} step={out['step']} "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
