"""GP posterior serving through the TLR inference server (ISSUE 7): one
resident Cholesky factorization of a spatial covariance answers a mixed
stream of per-user requests -- posterior-mean solves, marginal-likelihood
logdets, prior samples, and iterative solves at per-request tolerance --
continuously batched through fixed ``(n, slots)`` RHS blocks with zero
recompiles after warmup (the "millions of users" serving story, DESIGN.md
section 10).

Run:  PYTHONPATH=src python examples/serve_gp.py [--n 2048] [--slots 8]
      [--trace out.json]   # Perfetto trace: per-tick pack/dispatch/sync
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro import obs  # noqa: E402
from repro.core import (  # noqa: E402
    CholOptions, TLROperator, covariance_problem,
)
from repro.serve import KINDS, ServeRequest  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record telemetry and write a Chrome-trace / "
                         "Perfetto JSON (load at ui.perfetto.dev)")
    args = ap.parse_args()

    if args.trace:
        obs.enable()

    pts, K = covariance_problem(args.n, 2, args.tile, geometry="ball",
                                seed=3)
    op = TLROperator.compress(jnp.asarray(K), args.tile, eps=1e-8)
    fact = op.cholesky(CholOptions(eps=1e-6, bs=16))

    t0 = time.perf_counter()
    srv = fact.serve(operator=op, slots=args.slots, check_every=4)
    print(f"server up: n={args.n}, slots={args.slots}, "
          f"warmup {time.perf_counter() - t0:.2f}s "
          f"(all serve-path executables compiled)")

    # a mixed per-user request stream: each user brings observations y_u
    # and wants alpha_u = K^{-1} y_u (posterior mean weights), the model
    # evidence logdet, or a prior draw for their posterior sampler
    rng = np.random.default_rng(0)
    reqs = []
    for u in range(args.requests):
        kind = KINDS[u % len(KINDS)]
        y_u = (rng.standard_normal(args.n)
               if kind in ("solve", "pcg_solve") else None)
        reqs.append(ServeRequest(kind, rhs=y_u, tol=10.0 ** -rng.integers(4, 9),
                                 maxiter=100, seed=u))
    t0 = time.perf_counter()
    for r in reqs:
        srv.submit(r)
    results = srv.run()
    wall = time.perf_counter() - t0

    st = srv.stats
    print(f"drained {st.completed} requests in {st.ticks} ticks / "
          f"{wall:.3f}s ({st.completed / wall:.0f} req/s), "
          f"occupancy {st.occupancy():.2f}")
    for kind in KINDS:
        p = st.latency_percentiles(kind)
        if not p["count"]:
            print(f"  {kind:>10}: (no completions)")
            continue
        print(f"  {kind:>10}: p50 {p['p50_s']*1e3:7.1f} ms   "
              f"p99 {p['p99_s']*1e3:7.1f} ms   ({p['count']} requests)")

    # spot-check one posterior-mean solve against the sequential path
    r0 = next(r for r in reqs if r.kind == "solve")
    ref = np.asarray(fact.solve(jnp.asarray(r0.rhs)))
    err = float(np.max(np.abs(results[r0.rid].value - ref)))
    print(f"batched-vs-sequential solve max abs diff: {err:.2e}")
    pcg = [results[r.rid] for r in reqs if r.kind == "pcg_solve"]
    if pcg:
        print(f"pcg_solve: {sum(r.converged for r in pcg)}/{len(pcg)} "
              f"converged, iterations "
              f"{sorted(r.iterations for r in pcg)}")

    if args.trace:
        obs.record_retraces()
        obs.export_chrome_trace(args.trace)
        snap = obs.metrics_snapshot(cats=("serve",))
        obs.disable()
        tick = snap["phases"].get("serve.tick", {})
        print(f"wrote {args.trace}: {snap['spans']} serve spans over "
              f"{tick.get('count', 0)} ticks")


if __name__ == "__main__":
    main()
