"""Jitted dispatch wrappers for the Pallas kernels.

``impl`` selects the execution path:
  * "pallas"    -- compiled Pallas TPU kernel (real hardware),
  * "interpret" -- Pallas interpreter (CPU validation; kernel body runs in
                   python/XLA with identical semantics),
  * "ref"       -- pure-jnp oracle (also what XLA fuses best on CPU).

On this CPU container the default is "interpret" inside kernel tests and
"ref" inside the factorization (fastest correct path); on TPU the default
flips to "pallas".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref as _ref
from .batched_gemm import batched_gemm_pallas
from .batched_qr import batched_qr_pallas
from .lr_sample import lr_sample_pallas
from .small_svd import small_svd_pallas
from .tlr_matvec import tile_chain_pallas


IMPLS = ("ref", "interpret", "pallas")


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def default_impl() -> str:
    return "pallas" if _on_tpu() else "ref"


def resolve_impl(impl: str | None) -> str:
    """Resolve an impl knob (e.g. ``CholOptions.impl``) to a concrete path.

    ``impl="pallas"`` compiles the kernels for real TPU hardware; off-TPU
    that request used to die deep inside ``pallas_call`` with an opaque
    backend message, so it is rejected up front here instead.
    """
    impl = impl or default_impl()
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if impl == "pallas" and not _on_tpu():
        raise RuntimeError(
            "impl='pallas' compiles the Pallas TPU kernels and requires a "
            f"TPU backend, but jax.default_backend() is "
            f"{jax.default_backend()!r}; use impl='interpret' to validate "
            "the kernel bodies on CPU, or impl='ref' for the pure-jnp "
            "oracles (DESIGN.md section 3)")
    return impl


def flop_estimate(fn, *args, **kwargs) -> float:
    """XLA ``cost_analysis`` FLOPs for one jitted call at these operand
    shapes (compile only, nothing executes).

    The padded-vs-useful accounting the rank-bucketed dispatch layer
    (``core/batching.py``) is judged by: lower the flat r_max-wide core and
    the per-bucket cores at their real shapes, and the FLOP ratio is the
    arithmetic the flat path wastes on zero padding. Handles the jax 0.4.x
    convention where ``cost_analysis`` returns one dict per computation.
    Static/keyword arguments must already be bound (``functools.partial``).
    """
    compiled = jax.jit(fn).lower(*args, **kwargs).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per computation
        ca = ca[0] if ca else {}
    if ca is None:  # backends may report no cost model at all
        ca = {}
    return float(ca.get("flops", 0.0))


def lr_sample(Ui, Vi, W2, impl: str | None = None,
              width: int | None = None):
    """``width``: optional TilePlan bucket width -- the factor operands run
    at the bucket's ladder width instead of their padded r_max (sliced
    before the einsum on the ref path, before the ``pallas_call`` on the
    kernel paths so the BlockSpecs shrink with it)."""
    impl = resolve_impl(impl)
    if width is not None and width < Ui.shape[-1]:
        if impl == "ref":
            Ui, Vi = Ui[..., :width], Vi[..., :width]
    if impl == "ref":
        return _ref.lr_sample_ref(Ui, Vi, W2)
    return lr_sample_pallas(Ui, Vi, W2, interpret=(impl == "interpret"),
                            width=width)


def batched_gemm(A, B, ranks, impl: str | None = None):
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.batched_gemm_ref(A, B, ranks)
    return batched_gemm_pallas(A, B, ranks, interpret=(impl == "interpret"))


def tile_chain(U, V, X, impl: str | None = None,
               width: int | None = None):
    """``width``: optional TilePlan bucket width, same contract as
    :func:`lr_sample` (exact slice of the zero-padded factors)."""
    impl = resolve_impl(impl)
    if width is not None and width < U.shape[-1]:
        if impl == "ref":
            U, V = U[..., :width], V[..., :width]
    if impl == "ref":
        return _ref.tile_chain_ref(U, V, X)
    return tile_chain_pallas(U, V, X, interpret=(impl == "interpret"),
                             width=width)


def batched_qr(Y, impl: str | None = None):
    """Batched economy QR (T, b, r) -> (Q, R); rank-deficient columns inert."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.batched_qr_ref(Y)
    return batched_qr_pallas(Y, interpret=(impl == "interpret"))


def small_svd(M, impl: str | None = None):
    """Batched small-core SVD (T, m, n) -> (U, s, V), M ~= U diag(s) V^T,
    singular values sorted descending (the rounding pass truncates on that
    order)."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.small_svd_ref(M)
    U, s, V = small_svd_pallas(M, interpret=(impl == "interpret"))
    # Jacobi leaves values unsorted; sort here so every impl agrees.
    order = jnp.argsort(-s, axis=-1)
    s = jnp.take_along_axis(s, order, axis=-1)
    U = jnp.take_along_axis(U, order[:, None, :], axis=-1)
    V = jnp.take_along_axis(V, order[:, None, :], axis=-1)
    return U, s, V
