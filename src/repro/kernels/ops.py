"""Jitted dispatch wrappers for the Pallas kernels.

``impl`` selects the execution path:
  * "pallas"    -- compiled Pallas TPU kernel (real hardware),
  * "interpret" -- Pallas interpreter (CPU validation; kernel body runs in
                   python/XLA with identical semantics),
  * "ref"       -- pure-jnp oracle (also what XLA fuses best on CPU).

On this CPU container the default is "interpret" inside kernel tests and
"ref" inside the factorization (fastest correct path); on TPU the default
flips to "pallas".
"""

from __future__ import annotations

import jax

from . import ref as _ref
from .batched_gemm import batched_gemm_pallas
from .lr_sample import lr_sample_pallas
from .tlr_matvec import tile_chain_pallas


IMPLS = ("ref", "interpret", "pallas")


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def default_impl() -> str:
    return "pallas" if _on_tpu() else "ref"


def resolve_impl(impl: str | None) -> str:
    """Resolve an impl knob (e.g. ``CholOptions.impl``) to a concrete path."""
    impl = impl or default_impl()
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    return impl


def lr_sample(Ui, Vi, W2, impl: str | None = None):
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.lr_sample_ref(Ui, Vi, W2)
    return lr_sample_pallas(Ui, Vi, W2, interpret=(impl == "interpret"))


def batched_gemm(A, B, ranks, impl: str | None = None):
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.batched_gemm_ref(A, B, ranks)
    return batched_gemm_pallas(A, B, ranks, interpret=(impl == "interpret"))


def tile_chain(U, V, X, impl: str | None = None):
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.tile_chain_ref(U, V, X)
    return tile_chain_pallas(U, V, X, interpret=(impl == "interpret"))
