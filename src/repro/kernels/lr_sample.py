"""Pallas TPU kernel: fused low-rank update-chain sampling (Eq. 2 hot spot).

Computes, for every row tile ``t`` in a block column,

    Y[t] = sum_j  U[t, j] @ (V[t, j]^T @ W2[j])

where ``W2[j] = V(k,j) (U(k,j)^T Omega)`` is the shared per-column
intermediate (hoisted out of the row loop when Omega is shared -- the
beyond-paper optimization of DESIGN.md section 2).

On the GPU the paper launches this as two marshaled MAGMA batched GEMMs with
an HBM round trip for the (r x s) intermediate. The TPU-native version fuses
the two products per (t, j) grid cell: ``V^T W2`` stays in VMEM and feeds the
MXU immediately, and the j-axis reduction accumulates into a VMEM scratch
across sequential grid steps (a revisiting grid -- the Pallas analogue of the
paper's parallel-buffer row reduction, without materializing the buffers in
HBM).

Block shapes: the natural operands (b x r), (b x s) already fit VMEM for the
paper's tile sizes (b <= 1024, r <= 128: 1 MB at f32), so BlockSpecs map one
tile per grid cell and tile the *batch* dimensions; b and r are padded to
MXU-friendly multiples of 128 by construction of the TLR store. Accumulation
is f32 when inputs are bf16 (MXU-native mixed precision).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lr_sample_kernel(ui_ref, vi_ref, w2_ref, y_ref, acc_ref):
    """Grid cell (t, j): acc += U[t,j] @ (V[t,j]^T @ W2[j])."""
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (r, s) intermediate never leaves VMEM; both matmuls hit the MXU.
    t3 = jnp.dot(vi_ref[0, 0].T, w2_ref[0],
                 preferred_element_type=acc_ref.dtype)
    acc_ref[...] += jnp.dot(ui_ref[0, 0], t3,
                            preferred_element_type=acc_ref.dtype)

    @pl.when(j == nj - 1)
    def _flush():
        y_ref[0] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "width"))
def lr_sample_pallas(Ui, Vi, W2, *, interpret: bool = True,
                     width: int | None = None):
    """Y[t] = sum_j U[t,j] @ (V[t,j]^T @ W2[j]).

    Args:
      Ui, Vi: (T, k, b, r)  row tiles of L for the column being sampled.
      W2:     (k, b, s)     shared per-j intermediate.
      width:  optional TilePlan bucket width; the factor operands slice to
              it before the ``pallas_call`` so the BlockSpecs (VMEM blocks,
              MXU work per grid cell) shrink to the bucket's ladder width
              (exact: factor columns past each tile's rank are zero).
    Returns:
      Y: (T, b, s)
    """
    if width is not None and width < Ui.shape[-1]:
        Ui = Ui[:, :, :, :width]
        Vi = Vi[:, :, :, :width]
    T, k, b, r = Ui.shape
    s = W2.shape[-1]
    if k == 0:
        return jnp.zeros((T, b, s), Ui.dtype)
    acc_dtype = (
        jnp.float32 if Ui.dtype in (jnp.bfloat16, jnp.float16) else Ui.dtype
    )
    return pl.pallas_call(
        _lr_sample_kernel,
        grid=(T, k),
        in_specs=[
            pl.BlockSpec((1, 1, b, r), lambda t, j: (t, j, 0, 0)),
            pl.BlockSpec((1, 1, b, r), lambda t, j: (t, j, 0, 0)),
            pl.BlockSpec((1, b, s), lambda t, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, s), lambda t, j: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, b, s), Ui.dtype),
        scratch_shapes=[pltpu.VMEM((b, s), acc_dtype)],
        interpret=interpret,
    )(Ui, Vi, W2)
