"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax.numpy as jnp


def lr_sample_ref(Ui, Vi, W2):
    """Y[t] = sum_j U[t,j] @ (V[t,j]^T @ W2[j])."""
    T, k, b, _ = Ui.shape
    s = W2.shape[-1]
    if k == 0:
        return jnp.zeros((T, b, s), Ui.dtype)
    T3 = jnp.einsum("tjbr,jbs->tjrs", Vi, W2)
    return jnp.einsum("tjbr,tjrs->tbs", Ui, T3)


def batched_gemm_ref(A, B, ranks):
    """C[t] = A[t][:, :ranks[t]] @ B[t][:ranks[t], :] via masking."""
    k = A.shape[-1]
    mask = (jnp.arange(k)[None, :] < ranks[:, None]).astype(A.dtype)
    return jnp.einsum("tmk,tk,tkn->tmn", A, mask, B)


def tile_chain_ref(U, V, X):
    """out[t] = U[t] @ (V[t]^T @ X[t])."""
    return jnp.einsum("tbr,trs->tbs", U, jnp.einsum("tbr,tbs->trs", V, X))


def batched_qr_ref(Y):
    """Batched economy QR, (T, b, r) -> Q (T, b, r), R (T, r, r), r <= b.

    Householder (XLA's geqrf): for rank-deficient panels the dead Q columns
    are arbitrary orthonormal directions with ~zero R rows, while the MGS
    kernel zeroes them -- both satisfy the only contract the rounding pass
    needs (Y ~= Q R with orthonormal live columns).
    """
    return jnp.linalg.qr(Y, mode="reduced")


def small_svd_ref(M):
    """Batched SVD of small cores: (T, m, n) -> (U, s, V), M ~= U s V^T.

    Note V, not V^H, to match the rotation-accumulated V of the Jacobi
    kernel; singular values descending.
    """
    U, s, Vh = jnp.linalg.svd(M, full_matrices=False)
    return U, s, jnp.swapaxes(Vh, -1, -2)
