"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax.numpy as jnp


def lr_sample_ref(Ui, Vi, W2):
    """Y[t] = sum_j U[t,j] @ (V[t,j]^T @ W2[j])."""
    T, k, b, _ = Ui.shape
    s = W2.shape[-1]
    if k == 0:
        return jnp.zeros((T, b, s), Ui.dtype)
    T3 = jnp.einsum("tjbr,jbs->tjrs", Vi, W2)
    return jnp.einsum("tjbr,tjrs->tbs", Ui, T3)


def batched_gemm_ref(A, B, ranks):
    """C[t] = A[t][:, :ranks[t]] @ B[t][:ranks[t], :] via masking."""
    k = A.shape[-1]
    mask = (jnp.arange(k)[None, :] < ranks[:, None]).astype(A.dtype)
    return jnp.einsum("tmk,tk,tkn->tmn", A, mask, B)


def tile_chain_ref(U, V, X):
    """out[t] = U[t] @ (V[t]^T @ X[t])."""
    return jnp.einsum("tbr,trs->tbs", U, jnp.einsum("tbr,tbs->trs", V, X))
