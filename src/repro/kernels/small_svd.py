"""Pallas TPU kernel: batched small SVD by one-sided Jacobi rotations.

The rounding pass (``core/algebra.py``) needs the SVD of the small core
matrix ``R_u R_v^T`` (r x r, r <= b) for every tile in a batch. XLA's SVD
does not exist inside Pallas; one-sided Jacobi does: it only ever *rotates
pairs of columns* (VPU work on two b-vectors plus three dot products), so
the whole factorization is a ``fori_loop`` over column pairs with
``dynamic_slice`` updates -- no linalg primitives, no scatter.

Each flat step ``t`` visits pair ``(p, q) = (t // n mod n, t mod n)`` and
rotates columns p < q of the working matrix (and of the accumulated V) by
the angle that zeroes their inner product; ``sweeps`` cyclic passes
converge quadratically (the classical result; ~4-8 sweeps reach working
precision for the r <= 256 cores the rounding pass produces). At the end
the column norms are the singular values and the normalized columns are U:

    M = U diag(s) V^T        (V, not V^H -- the op contract of ops.small_svd)

Values come out unsorted; the dispatch wrapper in ``ops.py`` sorts
descending, which the truncation logic of the rounding pass relies on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_svd_kernel(a_ref, u_ref, s_ref, v_ref, *, sweeps: int):
    A = a_ref[0]                                   # (m, n), n <= m
    m, n = A.shape
    V = jnp.eye(n, dtype=A.dtype)
    tiny = jnp.finfo(A.dtype).tiny

    def body(t, carry):
        A, V = carry
        p = (t // n) % n
        q = t % n
        ap = jax.lax.dynamic_slice(A, (0, p), (m, 1))
        aq = jax.lax.dynamic_slice(A, (0, q), (m, 1))
        alpha = jnp.sum(ap * ap)
        beta = jnp.sum(aq * aq)
        gamma = jnp.sum(ap * aq)
        theta = 0.5 * jnp.arctan2(2.0 * gamma, alpha - beta)
        # rotate only ordered pairs with a numerically live inner product
        do = (p < q) & (jnp.abs(gamma) > tiny)
        c = jnp.where(do, jnp.cos(theta), 1.0).astype(A.dtype)
        s = jnp.where(do, jnp.sin(theta), 0.0).astype(A.dtype)
        ap2, aq2 = c * ap + s * aq, -s * ap + c * aq
        A = jax.lax.dynamic_update_slice(A, ap2, (0, p))
        A = jax.lax.dynamic_update_slice(A, aq2, (0, q))
        vp = jax.lax.dynamic_slice(V, (0, p), (n, 1))
        vq = jax.lax.dynamic_slice(V, (0, q), (n, 1))
        V = jax.lax.dynamic_update_slice(V, c * vp + s * vq, (0, p))
        V = jax.lax.dynamic_update_slice(V, -s * vp + c * vq, (0, q))
        return A, V

    A, V = jax.lax.fori_loop(0, sweeps * n * n, body, (A, V))
    s = jnp.sqrt(jnp.sum(A * A, axis=0))           # (n,) column norms
    U = A / jnp.maximum(s, tiny)[None, :]
    u_ref[0] = jnp.where(s[None, :] > tiny, U, jnp.zeros_like(U))
    s_ref[0] = s
    v_ref[0] = V


@functools.partial(jax.jit, static_argnames=("sweeps", "interpret"))
def small_svd_pallas(M, *, sweeps: int = 8, interpret: bool = True):
    """Batched SVD of small cores: M (T, m, n), n <= m.

    Returns (U (T, m, n), s (T, n), V (T, n, n)) with M[t] ~= U s V^T,
    *unsorted* -- ``ops.small_svd`` sorts descending.
    """
    T, m, n = M.shape
    if n > m:
        raise ValueError(f"small_svd needs n <= m, got m={m}, n={n}; "
                         "transpose the core first")
    return pl.pallas_call(
        functools.partial(_jacobi_svd_kernel, sweeps=sweeps),
        grid=(T,),
        in_specs=[pl.BlockSpec((1, m, n), lambda t: (t, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, m, n), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, n), lambda t: (t, 0)),
            pl.BlockSpec((1, n, n), lambda t: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, m, n), M.dtype),
            jax.ShapeDtypeStruct((T, n), M.dtype),
            jax.ShapeDtypeStruct((T, n, n), M.dtype),
        ],
        interpret=interpret,
    )(M)
