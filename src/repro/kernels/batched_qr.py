"""Pallas TPU kernel: batched thin QR via modified Gram-Schmidt.

The rounding pass of the tile algebra (``core/algebra.py``) reduces every
low-rank sum to one batched QR of the stacked factors followed by a small
SVD of the core. LAPACK-style Householder panels do not map onto the MXU;
the kernel instead runs right-looking modified Gram-Schmidt: when column
``k`` is finalized it is projected out of every later column with one
rank-1 update (an outer product -- MXU work), so the whole factorization is
``r`` sequential steps of matvec + outer-product, all expressible with
``jnp.dot`` / ``where`` / ``fori_loop`` (no scatter, no linalg primitives).

Rank deficiency: a column whose residual norm falls below a relative drop
tolerance (1e-8 f64 / 1e-4 f32, the same cut ``core/ara.py`` uses) carries
no information and is zeroed -- zero columns are inert in every downstream
product, and the small-SVD truncation removes the matching zero rows of R.
Two MGS sweeps restore orthogonality on ill-conditioned panels (MGS2); R is
recovered as ``Q^T Y`` at the end, so ``Y ~= Q R`` holds to the drop
tolerance even for rank-deficient input.

Requires ``r <= b`` (tall panels): the economy factorization is
``Q (b, r), R (r, r)``, matching ``jnp.linalg.qr(..., mode="reduced")``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mgs_body(b: int, r: int, tol, Q):
    """One MGS sweep over the r columns of Q (b, r); returns orthonormal Q."""

    def body(k, Q):
        qk = jax.lax.dynamic_slice(Q, (0, k), (b, 1))            # (b, 1)
        nrm = jnp.sqrt(jnp.sum(qk * qk))
        keep = nrm > tol
        qk = jnp.where(keep, qk / jnp.maximum(nrm, tol), jnp.zeros_like(qk))
        # project the finalized direction out of every *later* column
        proj = jnp.dot(qk.T, Q, preferred_element_type=Q.dtype)  # (1, r)
        later = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1) > k
        proj = jnp.where(later, proj, jnp.zeros_like(proj))
        Q = Q - jnp.dot(qk, proj, preferred_element_type=Q.dtype)
        return jax.lax.dynamic_update_slice(Q, qk, (0, k))

    return jax.lax.fori_loop(0, r, body, Q)


def _mgs_qr_kernel(y_ref, q_ref, r_ref, *, sweeps: int):
    Y = y_ref[0]                                                 # (b, r)
    b, r = Y.shape
    rel = 1e-8 if Y.dtype == jnp.float64 else 1e-4
    Q = Y
    for _ in range(sweeps):
        # Tolerance must track the *current* column scale: after sweep 1 the
        # surviving columns are unit vectors, so a tolerance derived from the
        # input norms (which can exceed 1/rel) would zero them all in sweep 2.
        col_norm = jnp.sqrt(jnp.sum(Q * Q, axis=0, keepdims=True))  # (1, r)
        tol = jnp.maximum(rel * jnp.max(col_norm), jnp.finfo(Y.dtype).tiny)
        Q = _mgs_body(b, r, tol, Q)
    q_ref[0] = Q
    r_ref[0] = jnp.dot(Q.T, Y, preferred_element_type=Q.dtype)


@functools.partial(jax.jit, static_argnames=("sweeps", "interpret"))
def batched_qr_pallas(Y, *, sweeps: int = 2, interpret: bool = True):
    """Batched economy QR: Y (T, b, r) -> Q (T, b, r), R (T, r, r), r <= b."""
    T, b, r = Y.shape
    if r > b:
        raise ValueError(
            f"batched_qr needs tall panels (r <= b), got b={b}, r={r}; "
            "densify the factor sum first (core/algebra.py does)")
    return pl.pallas_call(
        functools.partial(_mgs_qr_kernel, sweeps=sweeps),
        grid=(T,),
        in_specs=[pl.BlockSpec((1, b, r), lambda t: (t, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, b, r), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, r, r), lambda t: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, b, r), Y.dtype),
            jax.ShapeDtypeStruct((T, r, r), Y.dtype),
        ],
        interpret=interpret,
    )(Y)
