"""Pallas TPU kernel: rank-masked uniform batched GEMM.

The TPU replacement for MAGMA's *non-uniform* batched GEMM: every operand is
padded to (b, r_max) and carries a per-item effective rank. Padding columns
are zero by construction of the TLR store, so the extra FLOPs are numerically
inert; the kernel additionally applies an explicit iota-mask on the
contraction dimension so it also works with *unpadded* (garbage-tailed)
inputs, matching the semantics of a true variable-rank batch.

    C[t] = A[t][:, :k_t] @ B[t][:k_t, :]      k_t = ranks[t]

Large (m, n) tiles are handled by gridding the output into (bm, bn) blocks
with the full contraction dimension resident in VMEM (r_max <= 1024 keeps
operand panels under ~1 MB at bf16 for bm = 256).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bgemm_kernel(a_ref, b_ref, rank_ref, c_ref):
    k = a_ref.shape[-1]
    rank = rank_ref[0]
    mask = (jax.lax.iota(jnp.int32, k) < rank).astype(a_ref.dtype)
    a = a_ref[0] * mask[None, :]
    acc_dtype = (
        jnp.float32 if a_ref.dtype in (jnp.bfloat16, jnp.float16)
        else a_ref.dtype
    )
    c_ref[0] = jnp.dot(a, b_ref[0], preferred_element_type=acc_dtype).astype(
        c_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def batched_gemm_pallas(A, B, ranks, *, bm: int = 0, bn: int = 0,
                        interpret: bool = True):
    """C[t] = A[t] @ diag(mask(ranks[t])) @ B[t].

    A: (T, m, k), B: (T, k, n), ranks: (T,) int32 -> C: (T, m, n).
    """
    T, m, k = A.shape
    n = B.shape[-1]
    bm = bm or m
    bn = bn or n
    grid = (T, pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _bgemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, k), lambda t, i, j: (t, i, 0)),
            pl.BlockSpec((1, k, bn), lambda t, i, j: (t, 0, j)),
            pl.BlockSpec((1,), lambda t, i, j: (t,)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda t, i, j: (t, i, j)),
        out_shape=jax.ShapeDtypeStruct((T, m, n), A.dtype),
        interpret=interpret,
    )(A, B, ranks)
