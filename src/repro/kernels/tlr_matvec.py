"""Pallas TPU kernel: batched TLR tile matvec chain  p[t] = U_t (V_t^T x_t).

The per-tile two-product chain of the TLR matrix-vector product (Algorithm 7
and section 4.4). The (r,) intermediate never leaves VMEM. The segment
reduction scattering tile products into block rows stays outside the kernel
(XLA segment-sum handles it well); the kernel removes the HBM round trip of
the intermediate, which is what limits the GPU version.

``x`` blocks arrive pre-gathered per tile, (T, b, nrhs); nrhs >= 1 unifies
the vector and multi-vector cases (the lane dimension wants >= 128 on real
TPUs; nrhs pads up for the dry-run configuration).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile_chain_kernel(u_ref, v_ref, x_ref, out_ref):
    acc_dtype = (
        jnp.float32 if u_ref.dtype in (jnp.bfloat16, jnp.float16)
        else u_ref.dtype
    )
    t1 = jnp.dot(v_ref[0].T, x_ref[0], preferred_element_type=acc_dtype)
    out_ref[0] = jnp.dot(u_ref[0], t1, preferred_element_type=acc_dtype).astype(
        out_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret", "width"))
def tile_chain_pallas(U, V, X, *, interpret: bool = True,
                      width: int | None = None):
    """out[t] = U[t] @ (V[t]^T @ X[t]);  U,V: (T,b,r), X: (T,b,s).

    ``width``: optional TilePlan bucket width (DESIGN.md section 9). The
    factor operands are sliced to it *before* the ``pallas_call``, so the
    BlockSpecs -- and with them each grid cell's VMEM footprint and MXU
    work -- shrink to the bucket's ladder width instead of r_max. Exact,
    because factor columns past each tile's rank are zero.
    """
    if width is not None and width < U.shape[-1]:
        U = U[:, :, :width]
        V = V[:, :, :width]
    T, b, r = U.shape
    s = X.shape[-1]
    return pl.pallas_call(
        _tile_chain_kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, b, r), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, b, r), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, b, s), lambda t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, s), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, b, s), U.dtype),
        interpret=interpret,
    )(U, V, X)
