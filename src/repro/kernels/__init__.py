"""Pallas TPU kernels for the TLR hot spots (validated interpret=True on CPU).

Kernels (each: <name>.py kernel + ref.py oracle + ops.py dispatch):
  lr_sample    fused low-rank update-chain sampling (Eq. 2) -- the ARA
               sampling hot spot, ~the paper's 90% GEMM fraction
  batched_gemm rank-masked uniform batched GEMM (MAGMA non-uniform batch
               replacement)
  tlr_matvec   per-tile two-product chain of the TLR matvec (Alg. 7)
  batched_qr   MGS economy QR of stacked low-rank factors (the rounding
               pass of the tile algebra, core/algebra.py)
  small_svd    one-sided-Jacobi SVD of the r x r rounding cores
"""

from .ops import (  # noqa: F401
    batched_gemm, batched_qr, default_impl, lr_sample, small_svd, tile_chain,
)
from .lr_sample import lr_sample_pallas  # noqa: F401
from .batched_gemm import batched_gemm_pallas  # noqa: F401
from .batched_qr import batched_qr_pallas  # noqa: F401
from .small_svd import small_svd_pallas  # noqa: F401
from .tlr_matvec import tile_chain_pallas  # noqa: F401
from . import ref  # noqa: F401
