"""Pallas TPU kernels for the TLR hot spots (validated interpret=True on CPU).

Kernels (each: <name>.py kernel + ref.py oracle + ops.py dispatch):
  lr_sample    fused low-rank update-chain sampling (Eq. 2) -- the ARA
               sampling hot spot, ~the paper's 90% GEMM fraction
  batched_gemm rank-masked uniform batched GEMM (MAGMA non-uniform batch
               replacement)
  tlr_matvec   per-tile two-product chain of the TLR matvec (Alg. 7)
"""

from .ops import batched_gemm, default_impl, lr_sample, tile_chain  # noqa: F401
from .lr_sample import lr_sample_pallas  # noqa: F401
from .batched_gemm import batched_gemm_pallas  # noqa: F401
from .tlr_matvec import tile_chain_pallas  # noqa: F401
from . import ref  # noqa: F401
