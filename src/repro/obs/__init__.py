"""``repro.obs`` -- unified telemetry across factorize / plan / serve.

The observability layer of DESIGN.md section 11. One process-wide
recording context collects nested spans (wall time + FLOP attribution +
rank histograms) at every layer's natural boundaries and exports them
as Perfetto-loadable Chrome-trace JSON, a flat metrics snapshot, or
counter timelines of the compile-count registry.

Typical use::

    from repro import obs

    obs.enable()
    fact = op.cholesky(eps=1e-6)          # spans recorded as a side effect
    obs.export_chrome_trace("trace.json")  # -> load in ui.perfetto.dev
    print(fact.stats["telemetry"])         # per-phase FLOP/s snapshot
    obs.disable()

Everything is a no-op while disabled: ``obs.span(...)`` returns a shared
inert handle without touching the clock, and instrumentation sites gate
attribute computation behind ``obs.enabled()``, so production paths pay
one global check per site.
"""

from .telemetry import (NOOP_SPAN, Span, Telemetry, counter, current,
                        disable, enable, enabled, rank_hist,
                        record_retraces, span, traced)
from .chrome_trace import export_chrome_trace, to_chrome_trace
from .metrics import metrics_snapshot

__all__ = [
    "NOOP_SPAN", "Span", "Telemetry", "counter", "current", "disable",
    "enable", "enabled", "export_chrome_trace", "metrics_snapshot",
    "rank_hist", "record_retraces", "span", "to_chrome_trace", "traced",
]
