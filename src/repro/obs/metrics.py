"""Flat metrics snapshot of a telemetry recording.

``metrics_snapshot()`` collapses the span tree into the per-phase
numbers the paper's profiling tables report (arXiv:2108.11932 fig. 10:
wall time and achieved FLOP/s attributed to batched-GEMM vs.
compression phases): for every span *name*, the call count, total
seconds, useful and padded FLOPs, achieved FLOP/s, and padded-vs-useful
ratio. The snapshot is plain JSON-able data; the drivers merge it into
``fact.stats["telemetry"]``, the server into ``ServerStats``-backed
summaries, and every bench into its ``BENCH_<suite>.json`` -- which is
what ``benchmarks/compare.py`` diffs for regressions.

FLOP attribution convention (matching ``TilePlan.bucket_flops``):
instrumentation sites attach ``flops`` (useful work, true ranks) and
``flops_padded`` (dispatched work, bucket-padded shapes) to *leaf*
spans only. Aggregation here sums attrs per span name without walking
the tree, so interior spans must not repeat their children's FLOPs --
their own row then reports seconds but no FLOP/s, and the top-level
totals stay double-count free.
"""

from __future__ import annotations

from typing import Optional

from . import telemetry as _tel


def _phase_row() -> dict:
    return {"count": 0, "seconds": 0.0, "flops": 0.0, "flops_padded": 0.0}


def metrics_snapshot(tel: Optional["_tel.Telemetry"] = None,
                     root=None, cats=None) -> dict:
    """Aggregate a recording (default: the active one) into a flat dict:

    ``phases``
        per span-name rows ``{count, seconds, flops, flops_padded,
        flops_per_s, padded_flop_ratio}`` (the FLOP-derived fields only
        where FLOPs were attached);
    ``wall_s`` / ``flops`` / ``flops_padded`` / ``padded_flop_ratio`` /
    ``flops_per_s``
        totals -- ``wall_s`` is the summed duration of *top-level* spans
        in the selection (nested spans overlap their parents and must
        not be double counted);
    ``retraces``
        the compile-count registry snapshot at call time;
    ``spans``
        total span count in the selection.

    ``root`` restricts to one span's subtree (handle, Span, or id) --
    the drivers pass their run-root so concurrent recordings of other
    layers don't leak into ``fact.stats["telemetry"]``. ``cats``
    restricts to a set of span categories (e.g. ``("serve",)`` for the
    server's view of a shared recording); both filters compose.
    """
    tel = tel if tel is not None else _tel.current()
    if tel is None:
        return {}

    spans = tel.subtree(root)
    if cats is not None:
        want = {cats} if isinstance(cats, str) else set(cats)
        spans = [sp for sp in spans if sp.cat in want]
    ids = {sp.id for sp in spans}

    phases: dict[str, dict] = {}
    wall = 0.0
    for sp in spans:
        row = phases.setdefault(sp.name, _phase_row())
        row["count"] += 1
        row["seconds"] += sp.dur
        fl = sp.args.get("flops")
        if fl is not None:
            row["flops"] += float(fl)
            row["flops_padded"] += float(
                sp.args.get("flops_padded", fl))
        if sp.parent not in ids:
            wall += sp.dur

    tot_fl = tot_pad = 0.0
    for row in phases.values():
        if row["flops"] > 0.0:
            tot_fl += row["flops"]
            tot_pad += row["flops_padded"]
            if row["seconds"] > 0.0:
                row["flops_per_s"] = row["flops"] / row["seconds"]
            row["padded_flop_ratio"] = row["flops_padded"] / row["flops"]

    from ..core.buckets import trace_counts

    out = {
        "spans": len(spans),
        "wall_s": wall,
        "flops": tot_fl,
        "flops_padded": tot_pad,
        "phases": phases,
        "retraces": trace_counts(),
    }
    if tot_fl > 0.0:
        out["padded_flop_ratio"] = tot_pad / tot_fl
        if wall > 0.0:
            out["flops_per_s"] = tot_fl / wall
    # Last sample per counter series (counters are cumulative: the drivers
    # emit running totals, e.g. the "health" jitter/retry counts, so the
    # final sample IS the aggregate). Counters are recording-global --
    # root/cats filters don't apply.
    counters: dict[str, dict] = {}
    for name, _t, values in tel.counters:
        counters[name] = dict(values)
    if counters:
        out["counters"] = counters
    return out
