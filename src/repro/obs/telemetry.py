"""Span-based telemetry: the process-wide recording context (ISSUE 8).

One module-level :class:`Telemetry` instance (or ``None`` -- the disabled
state) collects *spans*: named, nested wall-time intervals opened at the
natural phase boundaries of every layer -- per-column phases in the
Cholesky drivers, per-bucket launches in the ``TilePlan`` dispatch paths,
per-tick stages of the ``TLRServer`` loop. Spans carry free-form numeric
attributes; the instrumentation sites attach ``flops`` (useful) /
``flops_padded`` (dispatched, padding included) estimates, bucket widths,
and rank-histogram snapshots, which ``obs.metrics_snapshot`` aggregates
into per-phase FLOP/s and padded-vs-useful ratios and
``obs.export_chrome_trace`` turns into a Perfetto-loadable trace.

Design constraints, in order:

* **Zero-cost when disabled.** ``span(...)`` checks one module global and
  returns a shared no-op handle; no allocation, no clock read, no device
  interaction. Instrumentation sites gate any attribute *computation*
  behind ``enabled()``, so the disabled path is the pre-telemetry path --
  the disabled-mode pin in ``tests/test_obs.py`` holds the compile-count
  registry and wall time to it.
* **Host-side only.** Spans never block on device values; a span's
  duration is the host time of its ``with`` body (which, at the driver
  boundaries, already brackets a ``block_until_ready``). Device-accurate
  timelines come from ``jax.profiler``: every enabled span also enters
  ``jax.profiler.TraceAnnotation`` and ``jax.named_scope``, so a device
  profile taken under telemetry aligns its device ops with these host
  spans by name.
* **No recompiles.** All instrumentation lives outside jitted bodies
  (``named_scope`` only renames HLO metadata while tracing; the jit cache
  key is unchanged), so enabling telemetry never changes the compiled
  executable set.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Span:
    """One finished span: a named wall-time interval with attributes.

    ``ts`` / ``dur`` are seconds relative to the owning telemetry's epoch;
    ``parent`` is the id of the enclosing span (-1 at the root), ``depth``
    its nesting depth, ``cat`` the layer ("factor" / "solve" / "algebra" /
    "serve") the Chrome-trace export maps to a Perfetto track.
    """

    id: int
    name: str
    cat: str
    ts: float
    dur: float
    parent: int
    depth: int
    args: Dict[str, Any]


class _SpanHandle:
    """Open-span context manager returned by :meth:`Telemetry.start_span`."""

    __slots__ = ("_tel", "id", "name", "cat", "parent", "depth", "t0",
                 "args", "_ctxs")

    def __init__(self, tel: "Telemetry", name: str, cat: str,
                 args: Dict[str, Any]):
        self._tel = tel
        self.name = name
        self.cat = cat
        self.args = args
        self._ctxs = ()

    def set(self, **attrs) -> "_SpanHandle":
        """Attach (or overwrite) attributes on the open span."""
        self.args.update(attrs)
        return self

    def __enter__(self) -> "_SpanHandle":
        self._tel._enter(self)
        return self

    def __exit__(self, *exc) -> None:
        self._tel._exit(self)


class _NoopSpan:
    """The shared disabled-mode handle: every operation is a no-op. A
    single instance serves every ``span()`` call while telemetry is off,
    so the disabled path allocates nothing."""

    __slots__ = ()
    id = -1

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NOOP_SPAN = _NoopSpan()


def _device_annotations(name: str):
    """Best-effort profiler alignment contexts for one span: a
    ``TraceAnnotation`` (host region in device profiles) and a
    ``named_scope`` (names any tracing that happens inside the span)."""
    import jax

    ctxs = []
    ta = getattr(getattr(jax, "profiler", None), "TraceAnnotation", None)
    if ta is not None:
        ctxs.append(ta(name))
    ctxs.append(jax.named_scope(name))
    return ctxs


class Telemetry:
    """One recording session: finished spans, counter events, an epoch.

    Thread-correct for the repo's actual concurrency (the drivers and the
    server are single-threaded hosts; a lock guards the shared lists so a
    background submitter thread cannot corrupt them), but span *nesting*
    is tracked per-thread: each thread sees its own open-span stack.
    """

    def __init__(self, *, device_annotations: bool = True):
        self._clock = time.perf_counter
        self.epoch = self._clock()
        self.spans: List[Span] = []
        self.counters: List[tuple] = []   # (name, ts, {series: value})
        self.device_annotations = device_annotations
        self._lock = threading.Lock()
        self._local = threading.local()
        self._next_id = 0

    # -- span lifecycle ----------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def start_span(self, name: str, cat: str,
                   args: Dict[str, Any]) -> _SpanHandle:
        return _SpanHandle(self, name, cat, args)

    def _enter(self, h: _SpanHandle) -> None:
        st = self._stack()
        with self._lock:
            h.id = self._next_id
            self._next_id += 1
        h.parent = st[-1].id if st else -1
        h.depth = len(st)
        st.append(h)
        if self.device_annotations:
            ctxs = _device_annotations(h.name)
            for c in ctxs:
                c.__enter__()
            h._ctxs = tuple(ctxs)
        h.t0 = self._clock()

    def _exit(self, h: _SpanHandle) -> None:
        t1 = self._clock()
        for c in reversed(h._ctxs):
            c.__exit__(None, None, None)
        st = self._stack()
        if st and st[-1] is h:
            st.pop()
        sp = Span(id=h.id, name=h.name, cat=h.cat, ts=h.t0 - self.epoch,
                  dur=t1 - h.t0, parent=h.parent, depth=h.depth,
                  args=h.args)
        with self._lock:
            self.spans.append(sp)

    # -- counters ----------------------------------------------------------

    def counter(self, name: str, values: Dict[str, float]) -> None:
        """Record one multi-series counter sample (Chrome-trace ``ph="C"``)."""
        with self._lock:
            self.counters.append((name, self._clock() - self.epoch,
                                  dict(values)))

    def record_retraces(self) -> None:
        """Fold the unified compile-count registry in as a counter sample
        (the retrace timeline of DESIGN.md section 9, on the trace)."""
        from ..core.buckets import trace_counts

        self.counter("retraces", trace_counts())

    # -- selection ---------------------------------------------------------

    def subtree(self, root) -> List[Span]:
        """Finished spans in the subtree of ``root`` (a handle, a span, or
        an id), root included; all spans for ``root=None``."""
        if root is None:
            return list(self.spans)
        rid = root if isinstance(root, int) else root.id
        keep = {rid}
        out = []
        for sp in self.spans:          # ids are assigned in open order, but
            if sp.id in keep or sp.parent in keep:   # children *close* first:
                keep.add(sp.id)                      # membership via parent
                out.append(sp)                       # links, two passes below
        # children may close before the root closes -> their parent wasn't
        # in ``keep`` yet on the first pass; iterate to a fixed point.
        changed = True
        while changed:
            changed = False
            for sp in self.spans:
                if sp.id not in keep and sp.parent in keep:
                    keep.add(sp.id)
                    out.append(sp)
                    changed = True
        out.sort(key=lambda s: (s.ts, s.id))
        return out


# -- module-level state (the process-wide context) -----------------------------

_STATE: Optional[Telemetry] = None


def enabled() -> bool:
    """Is telemetry recording? The one check every instrumentation site
    gates its attribute computation behind."""
    return _STATE is not None


def current() -> Optional[Telemetry]:
    """The active :class:`Telemetry`, or None when disabled."""
    return _STATE


def enable(*, device_annotations: bool = True) -> Telemetry:
    """Start (or restart) recording; returns the fresh context. Any
    previous context is dropped -- export it first if you need it."""
    global _STATE
    _STATE = Telemetry(device_annotations=device_annotations)
    return _STATE


def disable() -> Optional[Telemetry]:
    """Stop recording; returns the (now inert) context so callers can
    still export or snapshot it."""
    global _STATE
    tel, _STATE = _STATE, None
    return tel


def span(name: str, cat: str = "", **args):
    """Open a span (context manager). The disabled fast path returns the
    shared :data:`NOOP_SPAN` without touching the clock."""
    tel = _STATE
    if tel is None:
        return NOOP_SPAN
    return tel.start_span(name, cat, args)


def counter(name: str, values: Dict[str, float]) -> None:
    tel = _STATE
    if tel is not None:
        tel.counter(name, values)


def record_retraces() -> None:
    tel = _STATE
    if tel is not None:
        tel.record_retraces()


def traced(name: str, cat: str = ""):
    """Decorator form of :func:`span` for whole entry points (the algebra
    layer's ``tlr_gemm``/``tlr_syrk``/rounding passes): one span per call,
    the disabled path one global check + a direct tail call."""
    import functools

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if _STATE is None:
                return fn(*args, **kwargs)
            with _STATE.start_span(name, cat, {}):
                return fn(*args, **kwargs)
        return wrapper
    return deco


def rank_hist(ranks, cap: int) -> Dict[str, int]:
    """Compact rank-histogram snapshot on the power-of-two rank ladder:
    ``{"0": n_zero, "1": ..., "2": ..., ...}`` with each positive rank
    counted at the ladder width it buckets up to -- the span attribute the
    drivers attach at column boundaries (JSON-friendly string keys)."""
    from ..core.buckets import bucket_ladder

    rk = np.asarray(ranks).reshape(-1)
    out: Dict[str, int] = {}
    nz = int((rk <= 0).sum())
    if nz:
        out["0"] = nz
    ladder = np.asarray(bucket_ladder(int(cap)), np.int64)
    if ladder.size:
        pos = rk[rk > 0]
        ix = np.minimum(np.searchsorted(ladder, pos), ladder.size - 1)
        for i in sorted(set(ix.tolist())):
            out[str(int(ladder[i]))] = int((ix == i).sum())
    return out
