"""Chrome-trace / Perfetto export of a telemetry recording.

``export_chrome_trace(path)`` writes the active (or a given)
:class:`~repro.obs.telemetry.Telemetry` as Chrome Trace Event JSON --
the ``{"traceEvents": [...]}`` object format that ``ui.perfetto.dev``
and ``chrome://tracing`` load directly. Each telemetry category becomes
its own named track (thread) so the three layers read as parallel
swimlanes: factorization phase spans on one, plan-dispatch bucket spans
nested below them, server tick stages on another. Counter samples
(retrace registry, occupancy) become ``ph="C"`` counter tracks.

Format notes (the parts Perfetto actually validates):

* complete events: ``ph="X"`` with ``ts``/``dur`` in *microseconds*,
  plus ``pid``/``tid`` integers selecting the track;
* metadata events: ``ph="M"``, ``name="process_name"`` /
  ``"thread_name"`` with the label in ``args.name``;
* counters: ``ph="C"`` with the series in ``args``.
"""

from __future__ import annotations

import json
from typing import Optional

from . import telemetry as _tel

_PID = 1

# Stable track order: known categories first, anything novel appended.
_TRACKS = {"factor": 1, "solve": 2, "algebra": 3, "serve": 4, "": 9}

_TRACK_NAMES = {
    "factor": "factorize (chol drivers)",
    "solve": "solve/matvec (TilePlan dispatch)",
    "algebra": "tile algebra (round/gemm/syrk)",
    "serve": "TLRServer ticks",
    "": "misc",
}

_COUNTER_TID = 90


def _json_safe(v):
    """Span attrs may hold numpy scalars; coerce to plain JSON types."""
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, bool) or v is None or isinstance(v, (int, float, str)):
        return v
    try:
        item = v.item()  # numpy scalar
    except AttributeError:
        return str(v)
    return item if isinstance(item, (int, float, bool, str)) else str(item)


def to_chrome_trace(tel: Optional["_tel.Telemetry"] = None) -> dict:
    """Build the Chrome-trace object for ``tel`` (default: the active
    recording). Raises if telemetry was never enabled."""
    tel = tel if tel is not None else _tel.current()
    if tel is None:
        raise RuntimeError(
            "no telemetry recording: call obs.enable() before the run, "
            "or pass the Telemetry returned by obs.disable()")

    tracks = dict(_TRACKS)
    events = [{"ph": "M", "pid": _PID, "tid": 0, "name": "process_name",
               "args": {"name": "repro-tlr"}}]

    def track(cat: str) -> int:
        if cat not in tracks:
            tracks[cat] = 10 + len(tracks)
        return tracks[cat]

    for sp in sorted(tel.spans, key=lambda s: (s.ts, s.id)):
        ev = {"ph": "X", "pid": _PID, "tid": track(sp.cat),
              "name": sp.name, "cat": sp.cat or "span",
              "ts": sp.ts * 1e6, "dur": sp.dur * 1e6}
        if sp.args:
            ev["args"] = _json_safe(sp.args)
        events.append(ev)

    for name, ts, values in tel.counters:
        events.append({"ph": "C", "pid": _PID, "tid": _COUNTER_TID,
                       "name": name, "ts": ts * 1e6,
                       "args": _json_safe(values)})

    for cat, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
        events.append({"ph": "M", "pid": _PID, "tid": tid,
                       "name": "thread_name",
                       "args": {"name": _TRACK_NAMES.get(cat, cat)}})
    if tel.counters:
        events.append({"ph": "M", "pid": _PID, "tid": _COUNTER_TID,
                       "name": "thread_name", "args": {"name": "counters"}})

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome_trace(path: str,
                        tel: Optional["_tel.Telemetry"] = None) -> dict:
    """Write the Chrome-trace JSON for ``tel`` (default: active recording)
    to ``path``; returns the object written."""
    obj = to_chrome_trace(tel)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj
