"""Roofline analysis (deliverable g) over the dry-run artifacts.

Per (arch x shape x mesh) cell, derive the three roofline terms in seconds:

  compute    = FLOPs / (chips x 197e12 bf16 FLOP/s)
  memory     = HBM traffic / (chips x 819e9 B/s)
  collective = collective bytes per device / 50e9 B/s per link

FLOPs/traffic come from the scan-aware jaxpr cost model (whole module,
divided by chips); collective bytes from the while-trip-corrected HLO parse
(already per device). MODEL_FLOPS = 6*N*D for training (2*N*D inference),
N = active params, D = processed tokens; the ratio MODEL_FLOPS/HLO_FLOPs
exposes remat/causal-rectangle/dispatch waste.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--results DIR]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e-class)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token per sequence
    "long_500k": 1,
}
SHAPE_KIND = {
    "train_4k": "train", "prefill_32k": "prefill",
    "decode_32k": "decode", "long_500k": "decode",
}


def analyze(rec: dict) -> dict:
    from repro.configs import get_config
    from repro.launch.costmodel import analytic_traffic
    from repro.models.config import SHAPES
    from repro.launch.dryrun import default_microbatches

    chips = rec["devices"]
    flops_total = rec["cost"]["jaxpr_flops_total"]
    cfg = get_config(rec["arch"])
    spec = SHAPES[rec["shape"]]
    traffic_total = analytic_traffic(
        cfg, spec, default_microbatches(cfg) if spec.kind == "train" else 1)
    coll_dev = rec["collectives"]["total_bytes"]

    t_compute = flops_total / (chips * PEAK_FLOPS)
    t_memory = traffic_total / (chips * HBM_BW)
    t_coll = coll_dev / ICI_BW

    shape = rec["shape"]
    tokens = SHAPE_TOKENS[shape]
    n_active = rec["model"]["active_params"]
    factor = 6 if SHAPE_KIND[shape] == "train" else 2
    model_flops = factor * n_active * tokens

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": shape, "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops": flops_total,
        "useful_ratio": model_flops / flops_total if flops_total else 0.0,
        # fraction of peak the step would achieve if it runs at the
        # bound implied by the dominant term:
        "roofline_fraction": (model_flops / (chips * PEAK_FLOPS)) / t_bound
        if t_bound > 0 else 0.0,
        "peak_gib": rec["memory"]["peak_bytes_est"] / 2**30,
        "compile_s": rec.get("compile_s"),
        "coll_by_op": rec["collectives"]["bytes_by_op"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=str(RESULTS))
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = []
    for f in sorted(Path(args.results).glob("*.json")):
        rec = json.loads(f.read_text())
        if args.mesh != "all" and rec["mesh"] != args.mesh:
            continue
        rows.append(analyze(rec))
    if not rows:
        print("no dry-run artifacts found; run repro.launch.dryrun first")
        return
    hdr = (f"{'arch':<28} {'shape':<12} {'compute':>10} {'memory':>10} "
           f"{'coll':>10} {'dom':>7} {'useful':>7} {'roofline%':>9} "
           f"{'GiB/dev':>8}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:<28} {r['shape']:<12} "
              f"{r['t_compute_s']:>10.4f} {r['t_memory_s']:>10.4f} "
              f"{r['t_collective_s']:>10.4f} {r['dominant']:>7} "
              f"{r['useful_ratio']:>7.2f} {100*r['roofline_fraction']:>8.1f}% "
              f"{r['peak_gib']:>8.2f}")


if __name__ == "__main__":
    main()
