"""Sharding rules: DP/FSDP over (pod, data), Megatron TP + EP over model.

Parameter specs are derived from the pytree path:
  * attention wq/wk/wv: head (output) dim on "model"; wo: input dim on "model"
  * MLP wg/wu/wi: F on "model"; wd/wo: F on "model"
  * MoE experts (E, D, F): E on "model" when divisible (expert parallelism),
    else F on "model" (tensor parallelism inside experts) -- granite's 40
    experts do not divide 16-way, so it takes the TP path
  * embeddings: vocab on "model" (parallel CE loss)
  * SSD: in/out projections sharded on d_inner over "model"
  * FSDP: the largest remaining dim additionally sharded over (pod, data)
    when enabled and divisible (ZeRO-3; all-gather per scanned block)

Every rule degrades gracefully: a dim is sharded only when divisible by the
axis size, so reduced smoke configs fall back to replication.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import dp_axes


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path_s: str, shape: tuple[int, ...], mesh: Mesh,
               fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf."""
    model = "model" if "model" in mesh.axis_names else None
    dp = dp_axes(mesh)
    msize = _axis_size(mesh, model)
    dsize = _axis_size(mesh, dp)
    nd = len(shape)
    spec: list = [None] * nd

    def try_shard(dim: int, axes) -> bool:
        size = _axis_size(mesh, axes)
        if axes and spec[dim] is None and shape[dim] % size == 0 and size > 1:
            spec[dim] = axes
            return True
        return False

    # Block-stacked params carry a leading repeats axis -> never shard dim 0
    # for block params; detect via path containing "blocks".
    offset = 1 if ("blocks/" in path_s and nd >= 2) else 0

    leaf = path_s.rsplit("/", 1)[-1]
    parent = path_s.rsplit("/", 2)[-2] if path_s.count("/") >= 1 else ""

    if leaf == "tok":                       # (V, D) embedding
        try_shard(0, model)
        if fsdp:
            try_shard(1, dp)
    elif leaf == "head":                    # (D, V) unembedding
        try_shard(1, model)
        if fsdp:
            try_shard(0, dp)
    elif leaf in ("wq", "wk", "wv"):        # (D, H*hd): heads on model
        try_shard(offset + 1, model)
        if fsdp:
            try_shard(offset + 0, dp)
    elif leaf == "wo" and parent in ("mixer", "cross"):  # (H*hd, D)
        try_shard(offset + 0, model)
        if fsdp:
            try_shard(offset + 1, dp)
    elif leaf in ("wg", "wu", "wi") and nd - offset == 3:   # MoE (E, D, F)
        if not try_shard(offset + 0, model):     # EP preferred
            try_shard(offset + 2, model)         # else TP on F
        if fsdp:
            try_shard(offset + 1, dp)
    elif leaf in ("wd", "wo") and nd - offset == 3:         # MoE (E, F, D)
        if not try_shard(offset + 0, model):
            try_shard(offset + 1, model)
        if fsdp:
            try_shard(offset + 2, dp)
    elif leaf in ("wg", "wu", "wi"):        # dense MLP (D, F)
        try_shard(offset + 1, model)
        if fsdp:
            try_shard(offset + 0, dp)
    elif leaf in ("wd",):                   # dense MLP (F, D)
        try_shard(offset + 0, model)
        if fsdp:
            try_shard(offset + 1, dp)
    elif leaf == "wo":                      # gelu MLP out (F, D)
        try_shard(offset + 0, model)
        if fsdp:
            try_shard(offset + 1, dp)
    elif leaf == "router":                  # (D, E)
        if fsdp:
            try_shard(offset + 0, dp)
    elif leaf == "w_in":                    # SSD (D, 2*din+2N+nh)
        try_shard(offset + 1, model)
        if fsdp:
            try_shard(offset + 0, dp)
    elif leaf == "w_out":                   # SSD (din, D)
        try_shard(offset + 0, model)
        if fsdp:
            try_shard(offset + 1, dp)
    elif nd - offset >= 2 and fsdp:
        # generic matrices: fsdp the largest dim
        dims = sorted(range(offset, nd), key=lambda d: -shape[d])
        try_shard(dims[0], dp)
    # vectors (norm scales, biases, A_log, ...) stay replicated
    return P(*spec)


def params_shardings(params_abstract, mesh: Mesh, fsdp: bool = True):
    """NamedSharding pytree matching an abstract parameter tree."""

    def one(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh, fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_abstract)


# -- TLR tile-algebra batches (ROADMAP: sharded tile algebra) -------------------


def tile_batch_spec(n: int, ndim: int, mesh: Mesh) -> P:
    """PartitionSpec for a TLR tile-algebra batch: shard the leading
    (output-tile) axis over the DP axes when divisible, else replicate.

    The accumulation batches of ``tlr_gemm`` / ``tlr_syrk_column`` are
    embarrassingly parallel over output tiles -- one batched call per
    column with no cross-tile dependencies -- so the batch axis is the
    natural multi-device split (core/batching.py installs a mesh via
    ``set_tile_mesh``; without one the tile algebra stays single-device).
    """
    spec: list = [None] * ndim
    dp = dp_axes(mesh)
    if ndim and dp and n > 0 and n % _axis_size(mesh, dp) == 0:
        spec[0] = dp
    return P(*spec)


def tile_batch_sharding(mesh: Mesh, n: int, ndim: int) -> NamedSharding:
    """NamedSharding for one tile-batch array (see ``tile_batch_spec``)."""
    return NamedSharding(mesh, tile_batch_spec(n, ndim, mesh))


# -- inputs ---------------------------------------------------------------------


def batch_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    """Shard dim0 (global batch) over as many DP axes as divide it; for
    batch-1 decode, shard the sequence dim (dim with the largest extent)."""
    dp = dp_axes(mesh)
    spec: list = [None] * len(shape)
    if shape and shape[0] % _axis_size(mesh, dp) == 0 and len(dp) > 0:
        spec[0] = dp
    elif shape and len(dp) > 0 and shape[0] % mesh.shape[dp[-1]] == 0 \
            and mesh.shape[dp[-1]] > 1 and shape[0] > 1:
        spec[0] = dp[-1]
    else:
        # batch not shardable (e.g. long_500k batch=1): shard longest dim
        if len(shape) >= 2:
            d = int(np.argmax(shape[1:])) + 1
            if shape[d] % _axis_size(mesh, dp) == 0:
                spec[d] = dp
    return P(*spec)


def cache_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    """KV / SSM caches: stacked (R, B, S, KV, hd) or (R, B, ...). Shard batch
    over DP when divisible, else sequence; shard heads over model when
    divisible."""
    dp = dp_axes(mesh)
    spec: list = [None] * len(shape)
    if len(shape) < 2:
        return P(*spec)
    if shape[1] % _axis_size(mesh, dp) == 0 and shape[1] > 1:
        spec[1] = dp
    elif len(shape) >= 3 and shape[2] % _axis_size(mesh, dp) == 0:
        spec[2] = dp   # sequence-sharded cache (long-context decode)
    if len(shape) >= 4:
        msize = dict(mesh.shape).get("model", 1)
        if spec[3] is None and shape[3] % msize == 0 and shape[3] > 1:
            spec[3] = "model"       # KV heads over model
        elif len(shape) >= 5 and spec[2] is None and msize > 1 and \
                shape[2] % msize == 0:
            spec[2] = "model"       # else: cache sequence over model
    return P(*spec)


def inputs_shardings(specs: Any, mesh: Mesh):
    """NamedSharding pytree for input_specs structures (train/prefill/decode)."""

    def one(path, leaf):
        ps = _path_str(path)
        if "caches" in ps:
            return NamedSharding(mesh, cache_spec(leaf.shape, mesh))
        if leaf.shape == ():
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, batch_spec(leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(
        one, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def caches_shardings(caches: Any, mesh: Mesh):
    """NamedSharding pytree for decode-cache structures.

    Must be used whenever a cache subtree is passed on its own (the path no
    longer contains "caches", so ``inputs_shardings`` would misroute it to
    ``batch_spec`` -- which shards the leading layer-stack axis over data and
    forces a full cache all-gather inside the layer scan)."""

    def one(leaf):
        return NamedSharding(mesh, cache_spec(leaf.shape, mesh))

    return jax.tree.map(
        one, caches,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
