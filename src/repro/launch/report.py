"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts.

Usage: PYTHONPATH=src python -m repro.launch.report
Replaces the <!-- ROOFLINE_TABLE --> marker in EXPERIMENTS.md.
"""

from __future__ import annotations

import json
from pathlib import Path

from .roofline import RESULTS, analyze

ROOT = Path(__file__).resolve().parents[3]


def fmt_row(r: dict) -> str:
    return (f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} | "
            f"{r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{100*r['roofline_fraction']:.1f}% | {r['peak_gib']:.1f} |")


def build_tables() -> str:
    rows_single, rows_multi = [], []
    for f in sorted(RESULTS.glob("*.json")):
        rec = json.loads(f.read_text())
        (rows_single if rec["mesh"] == "single" else rows_multi).append(
            analyze(rec))
    hdr = ("| arch | shape | compute ms | memory ms | coll ms | dominant | "
           "useful | roofline | GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    out = ["### Roofline — single pod (16x16 = 256 chips), per step\n", hdr]
    out += [fmt_row(r) for r in rows_single]
    if rows_multi:
        out += ["", "### Multi-pod (2x16x16 = 512 chips) — dry-run "
                "pass/memory (collective figures include the pod axis)\n",
                hdr]
        out += [fmt_row(r) for r in rows_multi]
    skips = ("\nSkipped cells per assignment: long_500k for the eight pure "
             "full-attention archs (whisper, qwen, mistral-nemo, stablelm, "
             "phi3, llama4, granite, llama-vision) — see DESIGN.md section 5.")
    out.append(skips)
    return "\n".join(out)


def main() -> None:
    table = build_tables()
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        text = text.replace(marker, table)
    else:
        # refresh: replace between the section headers
        text += "\n" + table
    exp.write_text(text)
    print(table)


if __name__ == "__main__":
    main()
