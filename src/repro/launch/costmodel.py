"""Scan-aware cost accounting for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, not once per
trip -- so any scanned-layer model under-reports FLOPs/bytes/collectives by
~the layer count. Two fixes live here:

* ``jaxpr_cost(fn, *args)`` -- walks the (unpartitioned) jaxpr, counting
  dot/conv FLOPs exactly and multiplying through ``scan`` lengths; also
  accumulates an HBM-traffic proxy (operand+result bytes of materializing
  ops: dot/conv/gather/scatter/dynamic-*; elementwise chains are assumed
  fused). Totals are whole-module; divide by chip count for per-device.

* ``parse_collectives_trips(hlo)`` -- parses the post-SPMD HLO text into
  computations, finds each ``while``'s trip count from the constant in its
  condition computation, and multiplies collective traffic inside loop
  bodies accordingly. Ring-algorithm byte conventions per op class are
  documented on the function.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np


# -- jaxpr walker -----------------------------------------------------------------


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # tokens, abstract refs
        return 0


_MATERIALIZING = {
    "dot_general", "conv_general_dilated", "gather", "scatter", "scatter-add",
    "scatter_add", "dynamic_slice", "dynamic_update_slice", "sort", "top_k",
    "cumsum", "cumlogsumexp",
}


def _eqn_flops(eqn) -> float:
    prim = eqn.primitive.name
    if prim == "dot_general":
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
        batch = np.prod([lhs.shape[d] for d in lb], initial=1.0)
        contract = np.prod([lhs.shape[d] for d in lc], initial=1.0)
        lfree = np.prod([s for d, s in enumerate(lhs.shape)
                         if d not in lc and d not in lb], initial=1.0)
        rfree = np.prod([s for d, s in enumerate(rhs.shape)
                         if d not in rc and d not in rb], initial=1.0)
        return 2.0 * batch * contract * lfree * rfree
    if prim == "conv_general_dilated":
        out = eqn.outvars[0].aval
        rhs = eqn.invars[1].aval  # kernel
        fgc = eqn.params.get("feature_group_count", 1)
        k_elems = np.prod(rhs.shape, initial=1.0)
        out_spatial_batch = np.prod(out.shape, initial=1.0) / max(
            out.shape[-1] if out.shape else 1, 1)
        # 2 * output elems * kernel work per output channel
        return 2.0 * np.prod(out.shape, initial=1.0) * \
            k_elems / max(rhs.shape[-1], 1) / fgc
    return 0.0


# HBM-traffic convention: an operand/result contributes only if it is
# plausibly HBM-resident in a well-fused TPU program --
#   * "external" operands (weights, scan carries, jaxpr inputs) always count
#     (they live in HBM between steps);
#   * intermediate values count only when larger than VMEM_BYTES (a fused
#     flash-attention/SSD chunk keeps smaller panels on-chip).
VMEM_BYTES_GLOBAL = 512 * 2**20   # ~2 MiB/device at 256 chips


def _walk(jaxpr, mult: float, acc: dict) -> None:
    external = {id(v) for v in jaxpr.invars} | \
        {id(v) for v in jaxpr.constvars}

    def operand_bytes(eqn) -> float:
        tot = 0.0
        for v in eqn.invars:
            if not hasattr(v, "aval"):
                continue
            b = _aval_bytes(v.aval)
            if id(v) in external or b >= VMEM_BYTES_GLOBAL:
                tot += b
        return tot

    def output_bytes(eqn) -> float:
        return sum(b for v in eqn.outvars
                   if (b := _aval_bytes(v.aval)) >= VMEM_BYTES_GLOBAL)

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        sub = None
        submult = mult
        if prim == "scan":
            sub = [eqn.params["jaxpr"].jaxpr]
            L = eqn.params["length"]
            submult = mult * L
            # carries are read+written each step; stacked ys written once
            ncar = eqn.params.get("num_carry", 0)
            car_b = sum(_aval_bytes(v.aval) for v in eqn.outvars[:ncar])
            ys_b = sum(_aval_bytes(v.aval) for v in eqn.outvars[ncar:])
            acc["traffic"] += mult * (2 * L * car_b + ys_b)
        elif prim == "while":
            sub = [eqn.params["body_jaxpr"].jaxpr]
            submult = mult * acc.get("_while_trips", 1)
        elif prim == "cond":
            branches = eqn.params["branches"]
            flops = []
            for br in branches:
                a2 = {"flops": 0.0, "traffic": 0.0}
                _walk(br.jaxpr, 1.0, a2)
                flops.append((a2["flops"], a2["traffic"], br.jaxpr))
            fl, tr, _ = max(flops)
            acc["flops"] += mult * fl
            acc["traffic"] += mult * tr
            continue
        elif "jaxpr" in eqn.params:
            p = eqn.params["jaxpr"]
            sub = [p.jaxpr if hasattr(p, "jaxpr") else p]
        elif "call_jaxpr" in eqn.params:
            p = eqn.params["call_jaxpr"]
            sub = [p.jaxpr if hasattr(p, "jaxpr") else p]

        if sub is not None:
            for s in sub:
                _walk(s, submult, acc)
            continue

        acc["flops"] += mult * _eqn_flops(eqn)
        if prim in _MATERIALIZING:
            if prim == "dynamic_update_slice":
                # donated buffers update in place: traffic = the written
                # slice (operand 1), not the whole destination twice.
                nbytes = 2 * _aval_bytes(eqn.invars[1].aval)
            else:
                nbytes = operand_bytes(eqn) + output_bytes(eqn)
            acc["traffic"] += mult * nbytes


def jaxpr_cost(fn, *args) -> dict:
    """Whole-module FLOPs + HBM-traffic proxy from the unpartitioned jaxpr."""
    closed = jax.make_jaxpr(fn)(*args)
    acc = {"flops": 0.0, "traffic": 0.0}
    # top-level params/inputs are read at least once
    acc["traffic"] += sum(_aval_bytes(v.aval) for v in closed.jaxpr.invars)
    _walk(closed.jaxpr, 1.0, acc)
    return acc


# -- HLO collective parser (while-trip aware) ----------------------------------------


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}

_COMP_START = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{")
_COLL = re.compile(
    r"=\s*(?:\(\s*)?(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS2 = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CALLREF = re.compile(r"(?:body|condition|calls|branch_computations)=\{?%?([\w.\-]+)")
_WHILEREF = re.compile(r"\bwhile\(.*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur, buf = None, []
    for line in hlo.splitlines():
        m = _COMP_START.match(line)
        if m and cur is None:
            cur = m.group(1)
            buf = []
            continue
        if cur is not None:
            if line.strip() == "}":
                comps[cur] = buf
                cur = None
            else:
                buf.append(line)
    return comps


def _line_collective(line: str):
    m = _COLL.search(line)
    if not m:
        return None
    dtype, dims, op = m.group(1), m.group(2), m.group(3)
    if dtype not in _DTYPE_BYTES:
        return None
    size = _DTYPE_BYTES[dtype]
    if dims:
        size *= int(np.prod([int(d) for d in dims.split(",")]))
    g = _GROUPS.search(line)
    if g:
        n = int(g.group(2))
    else:
        g2 = _GROUPS2.search(line)
        n = len(g2.group(1).split(",")) if g2 else 2
    n = max(n, 2)
    if op == "all-gather":
        traffic = size * (n - 1) / n
    elif op == "all-reduce":
        traffic = 2 * size * (n - 1) / n
    elif op == "reduce-scatter":
        traffic = size * (n - 1)
    elif op == "all-to-all":
        traffic = size * (n - 1) / n
    else:  # collective-permute
        traffic = size
    return op, traffic, n


def parse_collectives_trips(hlo: str) -> dict:
    """Per-device collective traffic with while-loop trip multiplication."""
    comps = _split_computations(hlo)

    def trip_count(cond_name: str) -> int:
        consts = []
        for line in comps.get(cond_name, []):
            consts += [int(c) for c in _CONST.findall(line)]
        return max(consts) if consts else 1

    def comp_cost(name: str, seen: tuple) -> tuple[dict, dict]:
        if name in seen or name not in comps:
            return {}, {}
        totals: dict[str, float] = {}
        counts: dict[str, float] = {}
        for line in comps[name]:
            c = _line_collective(line)
            if c:
                op, traffic, _ = c
                totals[op] = totals.get(op, 0.0) + traffic
                counts[op] = counts.get(op, 0) + 1
            w = _WHILEREF.search(line)
            if w:
                cond, body = w.group(1), w.group(2)
                trips = trip_count(cond)
                bt, bc = comp_cost(body, seen + (name,))
                for k, v in bt.items():
                    totals[k] = totals.get(k, 0.0) + trips * v
                for k, v in bc.items():
                    counts[k] = counts.get(k, 0) + trips * v
                continue
            for ref in _CALLREF.findall(line):
                if "while" in line:
                    continue  # handled above
                bt, bc = comp_cost(ref, seen + (name,))
                for k, v in bt.items():
                    totals[k] = totals.get(k, 0.0) + v
                for k, v in bc.items():
                    counts[k] = counts.get(k, 0) + v
        return totals, counts

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_START.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: treat whole text as one computation, no trip correction
        totals, counts = {}, {}
        for line in hlo.splitlines():
            c = _line_collective(line)
            if c:
                op, traffic, _ = c
                totals[op] = totals.get(op, 0.0) + traffic
                counts[op] = counts.get(op, 0) + 1
        return {"bytes_by_op": totals, "counts": counts,
                "total_bytes": sum(totals.values())}

    totals, counts = comp_cost(entry, ())
    return {"bytes_by_op": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


# -- analytic HBM-traffic model -------------------------------------------------


def analytic_traffic(cfg, spec, microbatches: int = 1) -> float:
    """Whole-step global HBM bytes under the standard fused-kernel model.

    Conventions (documented for the roofline):
      * params: read once per forward + once per backward (x microbatches),
        written once by the optimizer; moments read+written; grads
        written+read;
      * block-boundary activations (the scan carries): write fwd, read bwd,
        plus one remat re-write;
      * flash attention: q,k,v read + out written per layer; k,v re-read
        once per q-chunk (VMEM can't hold 32k keys);
      * SSD: chunk inputs/outputs + states, ~4 passes over (B,S,d_inner);
      * MoE: every locally-resident expert weight is read per micro-step
        (EP shards experts; dispatch is batched, weights stream once);
      * CE loss: chunk logits written+read in fwd, recomputed in bwd (remat);
      * decode: full KV-cache read per token + slice write; params once.
    """
    B, S = spec.global_batch, spec.seq_len
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    pdt = 2  # bf16 params/activations
    N = cfg.param_count()
    Nact = cfg.active_param_count()
    kind = spec.kind
    M = max(microbatches, 1)

    if kind == "decode":
        # KV cache / SSM state traffic
        KV, hd = cfg.num_kv_heads, cfg.hd
        cache_dt = 1 if cfg.kv_cache_dtype == "int8" else 2
        n_attn = sum(1 for m_, _ in cfg.layer_pattern() if m_ == "attn") \
            * cfg.num_pattern_repeats
        cache = 2 * n_attn * B * S * KV * hd * cache_dt    # k+v read
        n_ssm = sum(1 for m_, _ in cfg.layer_pattern() if m_ == "ssm") \
            * cfg.num_pattern_repeats
        if cfg.ssm is not None:
            din = cfg.ssm.expand * D
            nh = din // cfg.ssm.head_dim
            cache += 2 * n_ssm * B * nh * cfg.ssm.head_dim * cfg.ssm.d_state * 4
        # active params read once per token-step
        frac_experts = 1.0
        if cfg.moe is not None:
            frac_experts = min(1.0, B * cfg.moe.top_k / cfg.moe.num_experts)
        params = (Nact + frac_experts * (N - Nact)) * pdt
        return cache + params + 2 * B * D * pdt * L

    tokens = B * S
    # parameter traffic
    params = (2 * M + 1) * N * pdt
    if kind == "train":
        mdt = 2 if N > 5e10 else 4
        params += 4 * N * mdt + 2 * N * pdt          # moments r/w + grads
    elif kind == "prefill":
        params = N * pdt
    # activations: block carries + remat rewrite
    act = 3 * L * tokens * D * pdt
    # attention: qkv+out + kv re-reads per q-chunk
    H, KVh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    n_attn = sum(1 for m_, _ in cfg.layer_pattern() if m_ in ("attn", "cross")) \
        * cfg.num_pattern_repeats
    nq = max(S // 512, 1)
    attn = n_attn * tokens * (2 * H * hd + 2 * KVh * hd) * pdt
    attn += n_attn * nq * 2 * B * min(S, 32768) * KVh * hd * pdt // max(M, 1)
    # SSD
    ssd = 0
    if cfg.ssm is not None:
        din = cfg.ssm.expand * D
        n_ssm = sum(1 for m_, _ in cfg.layer_pattern() if m_ == "ssm") \
            * cfg.num_pattern_repeats
        ssd = 4 * n_ssm * tokens * din * pdt
    # CE logits (train only; prefill takes last position)
    ce = 4 * tokens * V * pdt if kind == "train" else 0
    # act already counts its 3 passes (write fwd / read bwd / remat rewrite);
    # attention/SSD streams run fwd + remat-recompute + bwd for training.
    passes = 3 if kind == "train" else 1
    if kind != "train":
        act = act / 3
    return params + act + passes * (attn + ssd) + ce


# -- TLR tile-batch roofline (consumed by the core/batching.py auto policy) ----


def tile_batch_cost(bucket_shapes, *, n: int, b: int, cap: int,
                    itemsize: int = 8, nrhs: int = 1) -> dict:
    """Analytic byte/FLOP estimates for one batched two-product tile chain
    ``U (V^T x)`` -- the canonical TLR read-path kernel -- under the two
    dispatch shapes the ``batching`` knob selects:

    * flat:   one (n, b, cap) batch; every tile pays ``cap`` columns.
    * ranked: one (padded, b, width) batch per rank bucket
              (``bucket_shapes`` is ``[(padded, width), ...]``).

    Per dispatched tile of width w: 4*b*w*nrhs FLOPs (two GEMVs per rhs
    column) and 2*b*w*itemsize factor bytes (U and V streamed once; the x/y
    blocks are shared across tiles and negligible at TLR ranks). These are
    roofline *estimates* for the policy record -- the measured counterpart
    is ``TilePlan.bucket_flops`` (XLA cost_analysis at the true shapes).
    """
    flops_flat = 4.0 * n * b * cap * nrhs
    bytes_flat = 2.0 * n * b * cap * itemsize
    cols = sum(p * w for p, w in bucket_shapes)
    flops_ranked = 4.0 * b * cols * nrhs
    bytes_ranked = 2.0 * b * cols * itemsize
    return {
        "flops_flat": flops_flat, "flops_ranked": flops_ranked,
        "bytes_flat": bytes_flat, "bytes_ranked": bytes_ranked,
    }
