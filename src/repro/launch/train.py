"""Training launcher CLI.

Single-host: runs the Trainer directly. On a real cluster this binary is the
per-host entrypoint: jax.distributed.initialize() + the same Trainer, with
the data pipeline sharded by (host_index, host_count) and checkpoints on
shared storage (both already supported by the components).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 100 --batch 8 --seq 128 [--smoke] [--compress-rank 8]
"""

from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.optim import AdamWConfig, CompressConfig
from repro.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/launch_train")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--compress-rank", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tcfg = TrainConfig(
        steps=args.steps, batch=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt_dir, save_every=args.save_every,
        metrics_path=f"{args.ckpt_dir}/metrics.jsonl",
        optimizer=AdamWConfig(lr=args.lr),
        compress=CompressConfig(rank=args.compress_rank)
        if args.compress_rank else None,
    )
    out = Trainer(cfg, tcfg).run()
    print(f"status={out['status']} final_step={out['step']}")
    if out["losses"]:
        print(f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
