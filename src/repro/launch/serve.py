"""Serving launcher CLI: continuous-batching decode server.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 8 --slots 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import init_model
from repro.train import DecodeServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    srv = DecodeServer(cfg, params, slots=args.slots, max_len=args.max_len)
    reqs = [Request(prompt=[1 + i, 2, 3], max_new_tokens=args.max_new,
                    temperature=args.temperature, rid=i)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = srv.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(c.tokens) for c in done)
    print(f"{len(done)} completions, {tok} tokens, {tok/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
