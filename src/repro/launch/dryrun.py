import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) cell: build the production
mesh over 512 forced host devices, lower the train/prefill/serve step with
explicit in/out shardings, ``.compile()`` it, and record

  * memory_analysis()      -- per-device argument/output/temp bytes,
  * cost_analysis()        -- per-device HLO FLOPs and bytes accessed,
  * collective bytes       -- parsed from compiled.as_text() per op class,

into results/dryrun/<arch>__<shape>__<mesh>.json for the roofline pass.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, ARCHS, get_config, supported_shapes
from repro.models import (abstract_params, build_loss_fn, build_prefill_fn,
                          build_serve_step, input_specs)
from repro.models.config import SHAPES
from repro.optim.adamw import (AdamWConfig, AdamWState, adamw_init,
                               adamw_update)
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (caches_shardings, inputs_shardings,
                                   params_shardings)
from repro.launch.costmodel import jaxpr_cost, parse_collectives_trips

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_RE2 = re.compile(r"replica_groups=\{\{([^}]*)\}")


def parse_collectives(hlo: str) -> dict:
    """Per-op-class traffic bytes (per device) from post-SPMD HLO.

    Conventions (ring algorithms, N = collective group size):
      all-gather: result x (N-1)/N received;  all-reduce: 2 x buf x (N-1)/N;
      reduce-scatter: result x (N-1);  all-to-all: result x (N-1)/N;
      collective-permute: result size.
    """
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        size = _DTYPE_BYTES[dtype]
        if dims:
            size *= int(np.prod([int(d) for d in dims.split(",")]))
        g = _GROUP_RE.search(line)
        if g:
            n = int(g.group(2))
        else:
            g2 = _GROUP_RE2.search(line)
            n = len(g2.group(1).split(",")) if g2 else 2
        n = max(n, 2)
        if op == "all-gather":
            traffic = size * (n - 1) / n
        elif op == "all-reduce":
            traffic = 2 * size * (n - 1) / n
        elif op == "reduce-scatter":
            traffic = size * (n - 1)
        elif op == "all-to-all":
            traffic = size * (n - 1) / n
        else:
            traffic = size
        totals[op] = totals.get(op, 0.0) + traffic
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


def default_microbatches(cfg) -> int:
    """Gradient-accumulation depth for the train cells: big models trade
    extra FSDP all-gathers for a 4x activation-memory cut."""
    if cfg.param_count() > 3e10 or cfg.d_model >= 8192:
        return 4
    if cfg.moe is not None and cfg.moe.top_k >= 8:
        return 4
    return 1


def _build_step(cfg, shape_name: str, microbatches: int = 0):
    """Returns (fn, abstract_args, donate) for the cell's step function."""
    spec = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    params = abstract_params(cfg)
    if spec.kind == "train":
        loss_fn = build_loss_fn(cfg)
        ocfg = AdamWConfig(
            moment_dtype="bfloat16" if cfg.param_count() > 5e10 else "float32")
        ostate = jax.eval_shape(lambda p: adamw_init(p, ocfg), params)
        M = microbatches or default_microbatches(cfg)
        acc_dtype = jnp.bfloat16 if cfg.param_count() > 1e11 else jnp.float32

        def train_step(params, ostate, batch):
            if M == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                mbs = jax.tree.map(
                    lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]),
                    batch)

                def acc_step(carry, mb):
                    lacc, gacc = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    gacc = jax.tree.map(
                        lambda a, b: a + b.astype(a.dtype), gacc, g)
                    return (lacc + l, gacc), None

                init = (jnp.zeros((), jnp.float32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                                     params))
                (loss, grads), _ = jax.lax.scan(acc_step, init, mbs)
                loss = loss / M
                grads = jax.tree.map(lambda g: g / M, grads)
            new_params, new_state = adamw_update(grads, ostate, params, ocfg)
            return loss, new_params, new_state

        return train_step, (params, ostate, specs), (0, 1)
    if spec.kind == "prefill":
        fn = build_prefill_fn(cfg)
        return fn, (params, specs), ()
    serve = build_serve_step(cfg)

    def serve_fn(params, caches, token, cache_len):
        return serve(params, caches, token, cache_len)

    return serve_fn, (params, specs["caches"], specs["token"],
                      specs["cache_len"]), (1,)


def _is_cache_arg(i: int, kind: str) -> bool:
    return kind == "decode" and i == 1


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             fsdp: bool = True, save: bool = True,
             microbatches: int = 0, kv_cache_dtype: str = "") -> dict:
    import dataclasses

    from repro.launch.mesh import dp_axes
    from repro.models import pshard

    cfg = get_config(arch)
    if kv_cache_dtype:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_cache_dtype)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    pshard.set_hook(pshard.make_mesh_hook(mesh, dp_axes(mesh)))
    fn, args, donate = _build_step(cfg, shape_name, microbatches)

    from jax.sharding import NamedSharding, PartitionSpec as P

    kind = SHAPES[shape_name].kind
    pshards = params_shardings(args[0], mesh, fsdp=fsdp)
    in_shardings = [pshards]
    for i, extra in enumerate(args[1:], start=1):
        if isinstance(extra, AdamWState):
            # Optimizer moments mirror the parameter tree/sharding exactly.
            in_shardings.append(AdamWState(
                step=NamedSharding(mesh, P()),
                m=params_shardings(extra.m, mesh, fsdp=fsdp),
                v=params_shardings(extra.v, mesh, fsdp=fsdp),
            ))
        elif _is_cache_arg(i, kind):
            in_shardings.append(caches_shardings(extra, mesh))
        else:
            in_shardings.append(inputs_shardings(extra, mesh))

    t0 = time.time()
    jitted = jax.jit(fn, in_shardings=tuple(in_shardings),
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll_raw = parse_collectives(hlo)          # body-once (XLA convention)
    coll = parse_collectives_trips(hlo)        # while-trip corrected
    jc = jaxpr_cost(fn, *args)                 # scan-aware whole-module cost

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kv_cache_dtype": kv_cache_dtype or cfg.dtype,
        "devices": int(np.prod(list(dict(mesh.shape).values()))),
        "fsdp": fsdp,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_est": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "cost": {
            "xla_flops_per_device_body_once": ca.get("flops", 0.0),
            "xla_bytes_accessed_body_once": ca.get("bytes accessed", 0.0),
            "jaxpr_flops_total": jc["flops"],
            "jaxpr_traffic_bytes_total": jc["traffic"],
        },
        "collectives": coll,
        "collectives_body_once": coll_raw,
        "model": {
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "family": cfg.family,
        },
    }
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        out = RESULTS / f"{ALIASES.get(arch, arch)}__{shape_name}__{mesh_kind}.json"
        out.write_text(json.dumps(result, indent=2))
        result["path"] = str(out)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            cfg = get_config(arch)
            for shape in supported_shapes(cfg):
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            tag = f"{arch} x {shape} x {mk}"
            try:
                r = run_cell(arch, shape, mk, fsdp=not args.no_fsdp)
                print(f"OK   {tag}: compile {r['compile_s']}s, "
                      f"peak/device {r['memory']['peak_bytes_est']/2**30:.2f} GiB, "
                      f"flops/device {r['cost']['jaxpr_flops_total']/r['devices']:.3e}, "
                      f"coll/device {r['collectives']['total_bytes']/2**30:.3f} GiB",
                      flush=True)
            except Exception as e:  # noqa: BLE001 -- report, keep sweeping
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
