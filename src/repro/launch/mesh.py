"""Production mesh construction (deliverable e).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state. Single pod: (16, 16) = 256 chips as (data, model);
multi-pod: (2, 16, 16) = 512 chips as (pod, data, model). The dry-run builds
these over 512 forced host devices; on real hardware the same call maps onto
the TPU slice topology.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit-sharding API; absent in 0.4.x
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale dry-run tests (8 host devices)."""
    return _make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes used for data parallelism / FSDP."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
