"""Mamba-2 style state-space layer using the SSD (state-space duality)
chunked algorithm [arXiv:2405.21060], with O(1)-state decode.

Used by ``mamba2-130m`` (pure SSM) and the SSM layers of ``jamba-v0.1-52b``
(which we realize with SSD rather than Mamba-1's sequential selective scan:
SSD is the TPU-native formulation -- intra-chunk work is MXU matmuls, the
inter-chunk recurrence is a short scan over sequence chunks; a Mamba-1
selective scan would serialize over the full sequence. Recorded in DESIGN.md
as a hardware adaptation.)

Shapes: d_inner = expand * d_model; nh = d_inner / head_dim heads;
single B/C group (ngroups=1).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _dense_init
from .pshard import shard


def init_ssm(key, cfg, dtype):
    s = cfg.ssm
    D = cfg.d_model
    din = s.expand * D
    nh = din // s.head_dim
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [x (din), z gate (din), B (N), C (N), dt (nh)]
        "w_in": _dense_init(ks[0], (D, 2 * din + 2 * s.d_state + nh), dtype),
        "w_out": _dense_init(ks[1], (din, D), dtype),
        "conv_w": _dense_init(ks[2], (s.conv_width, din + 2 * s.d_state),
                              dtype, scale=np.sqrt(s.conv_width)),
        "conv_b": jnp.zeros((din + 2 * s.d_state,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, float(nh), nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((din,), dtype),
    }


def _split_proj(p, xproj, cfg):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    nh = din // s.head_dim
    xz, Bc, Cc, dt = jnp.split(
        xproj, [2 * din, 2 * din + s.d_state, 2 * din + 2 * s.d_state], axis=-1)
    x, z = jnp.split(xz, 2, axis=-1)
    return x, z, Bc, Cc, dt, din, nh


def _causal_conv(x, w, b):
    """Depthwise causal conv1d; x: (B, L, C), w: (W, C)."""
    W = w.shape[0]
    xpad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xpad[:, i : i + x.shape[1], :] * w[i]
    return out + b


def _segsum(dtA):
    """Stable segment-sum: out[..., i, j] = sum_{j < s <= i} dtA[..., s].

    dtA: (..., Q) -> (..., Q, Q) lower-triangular cumulative sums.
    """
    Q = dtA.shape[-1]
    x = jnp.cumsum(dtA, axis=-1)
    # out[i, j] = cumsum[i] - cumsum[j]  for i >= j
    out = x[..., :, None] - x[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


class SSMState(NamedTuple):
    conv: jax.Array   # (B, W-1, din + 2N) rolling conv inputs
    ssm: jax.Array    # (B, nh, hd, N) recurrent state


def ssd_forward(p, x_in, cfg):
    """Full-sequence SSD; x_in: (B, L, D) -> (B, L, D).

    Chunked: intra-chunk quasi-attention (MXU matmuls) + inter-chunk state
    recurrence (scan over L/chunk steps).
    """
    s = cfg.ssm
    B, L, D = x_in.shape
    Q = min(s.chunk, L)
    assert L % Q == 0, "sequence must be a multiple of the SSD chunk"
    nc = L // Q

    xproj = x_in @ p["w_in"]
    x, z, Bc, Cc, dt, din, nh = _split_proj(p, xproj, cfg)
    hd, N = s.head_dim, s.d_state

    conv_in = jnp.concatenate([x, Bc, Cc], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    x, Bc, Cc = jnp.split(conv_out, [din, din + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, L, nh)
    A = -jnp.exp(p["A_log"])                                       # (nh,)
    dtA = dt * A                                                   # (B, L, nh)

    xh = x.reshape(B, nc, Q, nh, hd).astype(jnp.float32)
    Br = Bc.reshape(B, nc, Q, N).astype(jnp.float32)
    Cr = Cc.reshape(B, nc, Q, N).astype(jnp.float32)
    dtr = dt.reshape(B, nc, Q, nh)
    dtAr = dtA.reshape(B, nc, Q, nh)

    # Shard the head dimension over the TP axis (layout hint; skipped when
    # nh does not divide the axis).
    xh = shard(xh, "dp", None, None, "model", None)
    dtr = shard(dtr, "dp", None, None, "model")
    dtAr = shard(dtAr, "dp", None, None, "model")

    # Scan over chunks: the working set is ONE chunk's decay matrix
    # (B, nh, Q, Q) instead of all nc of them -- essential for the 32k/500k
    # dry-run shapes (and how a fused SSD kernel walks HBM anyway).
    def chunk_step(state, inp):
        xc, Bq, Cq, dtc, dtAc = inp                       # (B, Q, ...)
        cum = jnp.cumsum(dtAc, axis=1)                    # (B, Q, nh)
        Lmat = jnp.exp(_segsum(dtAc.transpose(0, 2, 1)))  # (B, nh, Q, Q)
        scores = jnp.einsum("bqn,bkn->bqk", Cq, Bq)       # (B, Q, Q)
        M = scores[:, None] * Lmat                        # (B, nh, Q, Q)
        M = M * dtc.transpose(0, 2, 1)[:, :, None, :]     # weight by dt_k
        y_diag = jnp.einsum("bhqk,bkhd->bqhd", M, xc)
        decay_in = jnp.exp(cum)                           # (B, Q, nh)
        y_off = jnp.einsum("bqn,bhdn,bqh->bqhd", Cq, state, decay_in)
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)      # (B, Q, nh)
        snew = jnp.einsum("bqh,bqhd,bqn->bhdn",
                          decay_to_end * dtc, xc, Bq)
        state = state * jnp.exp(cum[:, -1])[..., None, None] + snew
        state = shard(state, "dp", "model", None, None)
        return state, y_diag + y_off

    state0 = jnp.zeros((B, nh, hd, N), jnp.float32)
    # Remat the chunk body: backward recomputes the (B, nh, Q, Q) decay
    # panels instead of stacking them across all chunks.
    _, ys = jax.lax.scan(
        jax.checkpoint(chunk_step), state0,
        (xh.swapaxes(0, 1), Br.swapaxes(0, 1), Cr.swapaxes(0, 1),
         dtr.swapaxes(0, 1), dtAr.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(B, L, nh, hd)
    y = y + xh.reshape(B, L, nh, hd) * p["D_skip"][None, None, :, None]
    y = y.reshape(B, L, din).astype(x_in.dtype)
    # gated RMS norm (mamba2's norm-before-out)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         ).astype(x_in.dtype) * p["norm_scale"]
    return y @ p["w_out"]


def ssm_init_state(cfg, batch: int, dtype) -> SSMState:
    s = cfg.ssm
    din = s.expand * cfg.d_model
    nh = din // s.head_dim
    return SSMState(
        conv=jnp.zeros((batch, s.conv_width - 1, din + 2 * s.d_state), dtype),
        ssm=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    )


def ssd_decode_step(p, x_in, cfg, state: SSMState):
    """One-token recurrent step; x_in: (B, 1, D) -> (out, new_state)."""
    s = cfg.ssm
    B = x_in.shape[0]
    xproj = x_in[:, 0] @ p["w_in"]
    x, z, Bc, Cc, dt, din, nh = _split_proj(p, xproj, cfg)
    hd, N = s.head_dim, s.d_state

    conv_in = jnp.concatenate([x, Bc, Cc], axis=-1)      # (B, C)
    hist = jnp.concatenate([state.conv, conv_in[:, None]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    x, Bc, Cc = jnp.split(conv_out, [din, din + N], axis=-1)
    new_conv = hist[:, 1:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A)                                         # (B, nh)
    xh = x.reshape(B, nh, hd).astype(jnp.float32)
    ssm = state.ssm * dec[..., None, None] + jnp.einsum(
        "bh,bhd,bn->bhdn", dt, xh, Bc.astype(jnp.float32))
    y = jnp.einsum("bn,bhdn->bhd", Cc.astype(jnp.float32), ssm)
    y = y + xh * p["D_skip"][None, :, None]
    y = y.reshape(B, din).astype(x_in.dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         ).astype(x_in.dtype) * p["norm_scale"]
    out = (y @ p["w_out"])[:, None]
    return out, SSMState(conv=new_conv, ssm=ssm)
