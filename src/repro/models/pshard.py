"""Activation-sharding hook.

Model code calls ``shard(x, "dp", None, "model")`` at strategic points
(post-embedding, block boundaries, logits). By default this is the identity;
the launcher installs a hook that maps the symbolic names onto the live mesh
("dp" -> the (pod, data) axes, "model" -> the TP axis) via
``with_sharding_constraint``. Keeping the hook symbolic keeps ``models/``
mesh-agnostic -- smoke tests run with no mesh at all.
"""

from __future__ import annotations

from typing import Callable, Optional

_HOOK: Optional[Callable] = None


def set_hook(fn: Optional[Callable]) -> None:
    global _HOOK
    _HOOK = fn


def shard(x, *names):
    if _HOOK is None:
        return x
    return _HOOK(x, names)


def make_mesh_hook(mesh, dp_axes: tuple[str, ...], model_axis: str = "model"):
    """Standard hook: resolve symbolic axis names against a mesh."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    mapping = {"dp": dp_axes if len(dp_axes) > 1 else dp_axes[0],
               "model": model_axis}
    sizes = dict(mesh.shape)

    def _axis_len(n):
        ax = mapping.get(n)
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            import numpy as _np
            return int(_np.prod([sizes[a] for a in ax]))
        return sizes[ax]

    def hook(x, names):
        if x.ndim != len(names):
            return x
        spec = []
        for dim, n in enumerate(names):
            if isinstance(n, str) and x.shape[dim] % _axis_len(n) == 0 and \
                    x.shape[dim] >= _axis_len(n):
                spec.append(mapping.get(n))
            else:
                spec.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    return hook
