"""Public model API: input specs per (arch x shape) cell + step builders.

``input_specs`` returns ShapeDtypeStructs (weak-type-correct, shardable, no
allocation) for every model input of a cell; the same structures drive the
multi-pod dry-run, the trainer, and the smoke tests (which materialize them
with random data).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, SHAPES, ShapeSpec
from . import transformer as T


def _enc_len(cfg: ModelConfig, seq_len: int) -> int:
    """Stubbed frontend token count: whisper frames = seq/4 (conv downsample
    stand-in), VLM patch tokens = cfg.frontend_tokens (fixed per image)."""
    if cfg.family == "audio":
        return max(64, seq_len // 4)
    return cfg.frontend_tokens


def input_specs(cfg: ModelConfig, shape: str | ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of (arch, shape)."""
    spec = SHAPES[shape] if isinstance(shape, str) else shape
    B, S = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    dt = cfg.jdtype

    if spec.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct((B, _enc_len(cfg, S),
                                                  cfg.d_model), dt)
        elif cfg.frontend_tokens:
            out["patches"] = jax.ShapeDtypeStruct((B, cfg.frontend_tokens,
                                                   cfg.d_model), dt)
        return out

    if spec.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct((B, _enc_len(cfg, S),
                                                  cfg.d_model), dt)
        elif cfg.frontend_tokens:
            out["patches"] = jax.ShapeDtypeStruct((B, cfg.frontend_tokens,
                                                   cfg.d_model), dt)
        return out

    # decode: one new token against a seq_len cache
    caches = jax.eval_shape(
        lambda: T.init_decode_caches(cfg, B, S, ctx_len=_enc_len(cfg, S)))
    return {
        "token": jax.ShapeDtypeStruct((B, 1), i32),
        "caches": caches,
        "cache_len": jax.ShapeDtypeStruct((), i32),
    }


# -- step builders -------------------------------------------------------------


def build_loss_fn(cfg: ModelConfig) -> Callable:
    def loss_fn(params, batch):
        return T.train_loss(params, batch, cfg)
    return loss_fn


def build_prefill_fn(cfg: ModelConfig) -> Callable:
    def prefill_fn(params, batch):
        return T.prefill(params, batch, cfg)
    return prefill_fn


def build_serve_step(cfg: ModelConfig) -> Callable:
    def serve_fn(params, caches, token, cache_len):
        return T.serve_step(params, caches, token, cache_len, cfg)
    return serve_fn


def abstract_params(cfg: ModelConfig, seed: int = 0):
    """Parameter ShapeDtypeStructs without allocating anything."""
    return jax.eval_shape(lambda k: T.init_model(k, cfg),
                          jax.random.PRNGKey(seed))


def materialize_inputs(cfg: ModelConfig, shape: str, seed: int = 0):
    """Random concrete inputs matching input_specs (smoke tests)."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)

    def make(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(
                rng.integers(0, max(2, cfg.vocab_size // 2), s.shape), s.dtype)
        return jnp.asarray(rng.standard_normal(s.shape) * 0.02, s.dtype)

    return jax.tree.map(make, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
