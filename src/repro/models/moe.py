"""Mixture-of-Experts with GShard-style grouped one-hot dispatch.

Expert-parallel friendly: the dispatch/combine tensors are
``(groups, group_size, experts, capacity)`` with groups sharded over the data
axes and experts over the model axis (EP). Capacity-based token dropping with
auxiliary load-balance loss. The dispatch tensor size is
``tokens * group_size * top_k * capacity_factor`` -- independent of the
expert count -- so 128-expert llama4 and 40-expert/top-8 granite both stay
cheap relative to expert FLOPs (see DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _dense_init
from .pshard import shard


def init_moe(key, cfg, dtype):
    m = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {"router": _dense_init(ks[0], (D, E), dtype)}
    if cfg.act == "swiglu":
        p["wg"] = _dense_init(ks[1], (E, D, F), dtype)
        p["wu"] = _dense_init(ks[2], (E, D, F), dtype)
        p["wd"] = _dense_init(ks[3], (E, F, D), dtype)
    else:
        p["wi"] = _dense_init(ks[1], (E, D, F), dtype)
        p["wo"] = _dense_init(ks[2], (E, F, D), dtype)
    if m.shared_expert:
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": _dense_init(kss[0], (D, F), dtype),
            "wu": _dense_init(kss[1], (D, F), dtype),
            "wd": _dense_init(kss[2], (F, D), dtype),
        }
    return p


def _capacity(group_size: int, top_k: int, num_experts: int, cf: float) -> int:
    c = int(np.ceil(group_size * top_k * cf / num_experts))
    return max(4, c)


def apply_moe(p, x, cfg):
    """x: (B, S, D) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    tokens = B * S
    gs = min(m.group_size, tokens)
    assert tokens % gs == 0, "token count must divide into dispatch groups"
    G = tokens // gs
    C = _capacity(gs, K, E, m.capacity_factor)

    xg = shard(x.reshape(G, gs, D), "dp", None, None)
    logits = (xg @ p["router"]).astype(jnp.float32)        # (G, gs, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # (G, gs, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch): E * sum_e f_e * P_e.
    me = probs.mean(axis=1)                                # (G, E)
    onehot_first = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32)
    ce = onehot_first.mean(axis=1)                         # (G, E)
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))

    # Position of each (token, slot) in its expert's capacity buffer:
    # flatten slots in (slot-major, token) order so top-1 picks win positions.
    sel = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)     # (G, gs, K, E)
    sel_flat = sel.transpose(0, 2, 1, 3).reshape(G, K * gs, E)
    pos_flat = jnp.cumsum(sel_flat, axis=1) - sel_flat     # (G, K*gs, E)
    pos = pos_flat.reshape(G, K, gs, E).transpose(0, 2, 1, 3)  # (G,gs,K,E)
    pos = jnp.sum(pos * sel, axis=-1)                      # (G, gs, K)
    keep = (pos < C).astype(gate_vals.dtype)
    gate_vals = gate_vals * keep

    pos_oh = jax.nn.one_hot(pos, C, dtype=x.dtype)         # (G, gs, K, C)
    sel_x = sel.astype(x.dtype)
    # combine[g, t, e, c] = sum_k gate * onehot(e) * onehot(c)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", sel_x, pos_oh,
                         gate_vals.astype(x.dtype))
    # Explicit EP layout: groups over the data axes, experts over the model
    # axis. Without these constraints the MoE backward picks inconsistent
    # shardings and SPMD falls back to full replication of the (G,E,C,D)
    # buffers (XLA "involuntary full rematerialization").
    combine = shard(combine, "dp", None, "model", None)
    dispatch = shard((combine > 0).astype(x.dtype), "dp", None, "model", None)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)        # (G, E, C, D)
    xe = shard(xe, "dp", "model", None, None)
    if "wg" in p:
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"]))
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["wu"])
        h = shard(h, "dp", "model", None, None)
        ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["wi"]))
        h = shard(h, "dp", "model", None, None)
        ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    ye = shard(ye, "dp", "model", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)

    if m.shared_expert:
        sh = p["shared"]
        y = y + (jax.nn.silu(xg @ sh["wg"]) * (xg @ sh["wu"])) @ sh["wd"]
    return y.reshape(B, S, D), aux
