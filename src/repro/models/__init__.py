"""LM substrate: configurable transformer families (dense/MoE/SSM/hybrid/
enc-dec/VLM) with scanned blocks, chunked attention, SSD state-space layers
and GShard MoE."""

from .config import ModelConfig, MoEConfig, SSMConfig, SHAPES, ShapeSpec  # noqa: F401
from .api import (  # noqa: F401
    abstract_params, build_loss_fn, build_prefill_fn, build_serve_step,
    input_specs, materialize_inputs,
)
from .transformer import init_model, train_loss, prefill, serve_step, \
    init_decode_caches  # noqa: F401
