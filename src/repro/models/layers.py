"""Transformer building blocks: norms, RoPE, GQA attention (chunked /
decode), MLPs, embeddings, chunked cross-entropy.

Everything is pure-functional: ``init_*`` builds parameter pytrees,
``apply``-style functions consume them. Attention over long sequences uses an
online-softmax scan over KV chunks (flash-attention structure) so the
(S x S) score matrix is never materialized -- mandatory for the 32k-prefill
dry-run shapes.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .pshard import shard

# -- initializers ---------------------------------------------------------------


def _dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0]
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# -- norms ----------------------------------------------------------------------


def init_norm(cfg, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = xf * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# -- rotary embeddings -----------------------------------------------------------


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- attention -------------------------------------------------------------------


def init_attention(key, cfg, dtype):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (D, H * hd), dtype),
        "wk": _dense_init(ks[1], (D, KV * hd), dtype),
        "wv": _dense_init(ks[2], (D, KV * hd), dtype),
        "wo": _dense_init(ks[3], (H * hd, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _qkv(p, x, cfg, positions, rope: bool = True):
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # Megatron attention layout: sequence gathered, heads over the TP axis
    # (the residual stream between blocks is sequence-parallel; without this
    # the kv-chunk scan would slice a model-sharded sequence dim and SPMD
    # falls back to replication).
    q = shard(q, "dp", None, "model", None)
    k = shard(k, "dp", None, "model", None)
    v = shard(v, "dp", None, "model", None)
    return q, k, v


def _pick_chunk(S: int, target: int) -> int:
    """Largest divisor of S that is <= target (context lengths like 1600
    image tokens are not multiples of the default chunk)."""
    c = min(target, S)
    while S % c:
        c -= 1
    return max(c, 1)


class SoftmaxState(NamedTuple):
    m: jax.Array    # running max        (B, KV, G, Sq)
    l: jax.Array    # running denom      (B, KV, G, Sq)
    acc: jax.Array  # running numerator  (B, KV, G, Sq, hd)


def _online_softmax_step(state: SoftmaxState, logits, vc):
    """logits: (B, KV, G, Sq, Sk); vc: (B, Sk, KV, hd)."""
    m_new = jnp.maximum(state.m, logits.max(axis=-1))
    scale = jnp.exp(state.m - m_new)
    probs = jnp.exp(logits - m_new[..., None])
    l_new = state.l * scale + probs.sum(axis=-1)
    acc = state.acc * scale[..., None] + jnp.einsum(
        "bkgqs,bskd->bkgqd", probs, vc.astype(probs.dtype))
    return SoftmaxState(m_new, l_new, acc)


def chunked_attention(q, k, v, *, causal: bool, k_chunk: int = 512,
                      q_chunk: int = 512, q_offset: int = 0):
    """Online-softmax attention; never materializes (S x S).

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). GQA via head grouping.
    ``q_offset`` is the absolute position of q[0] (prefill continuation).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = float(1.0 / np.sqrt(hd))
    q_chunk = _pick_chunk(Sq, q_chunk)
    k_chunk = _pick_chunk(Sk, k_chunk)
    nq = Sq // q_chunk
    nk = Sk // k_chunk

    qr = q.reshape(B, nq, q_chunk, KV, G, hd)
    kr = k.reshape(B, nk, k_chunk, KV, hd).swapaxes(0, 1)
    vr = v.reshape(B, nk, k_chunk, KV, hd).swapaxes(0, 1)
    q_pos = q_offset + jnp.arange(Sq).reshape(nq, q_chunk)
    k_pos = jnp.arange(Sk).reshape(nk, k_chunk)
    neg = jnp.asarray(-1e30, jnp.float32)

    def one_q_chunk(qc, qp):
        # qc: (B, q_chunk, KV, G, hd); qp: (q_chunk,) absolute positions
        def kv_step(state, inp):
            kc, vc, kp = inp
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qc.astype(jnp.float32),
                                kc.astype(jnp.float32)) * scale
            if causal:
                mask = qp[:, None] >= kp[None, :]
                logits = jnp.where(mask[None, None, None], logits, neg)
            return _online_softmax_step(state, logits, vc), None

        state0 = SoftmaxState(
            m=jnp.full((B, KV, G, q_chunk), -jnp.inf, jnp.float32),
            l=jnp.zeros((B, KV, G, q_chunk), jnp.float32),
            acc=jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32),
        )
        state = jax.lax.scan(kv_step, state0, (kr, vr, k_pos))[0]
        out = state.acc / jnp.maximum(state.l, 1e-30)[..., None]
        return out.astype(q.dtype)  # (B, KV, G, q_chunk, hd)

    # Triangular causal schedule: q-chunk i only visits kv-chunks 0..i,
    # halving attention FLOPs vs the masked rectangle (the dominant §Perf
    # win at 32k). Falls back to the rectangle scan when the self-attention
    # structure doesn't hold or the unroll would bloat the HLO.
    triangular = causal and Sq == Sk and q_chunk == k_chunk and \
        q_offset == 0 and nq <= 64

    if triangular:
        def tri_chunk(qc, qp, k_pref, v_pref, kp_pref):
            def kv_step(state, inp):
                kc, vc, kp = inp
                logits = jnp.einsum("bqkgd,bskd->bkgqs",
                                    qc.astype(jnp.float32),
                                    kc.astype(jnp.float32)) * scale
                mask = qp[:, None] >= kp[None, :]
                logits = jnp.where(mask[None, None, None], logits, neg)
                return _online_softmax_step(state, logits, vc), None

            state0 = SoftmaxState(
                m=jnp.full((B, KV, G, q_chunk), -jnp.inf, jnp.float32),
                l=jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                acc=jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32),
            )
            state = jax.lax.scan(kv_step, state0,
                                 (k_pref, v_pref, kp_pref))[0]
            out = state.acc / jnp.maximum(state.l, 1e-30)[..., None]
            return out.astype(q.dtype)

        outs = []
        for qi in range(nq):
            outs.append(jax.checkpoint(tri_chunk)(
                qr[:, qi], q_pos[qi], kr[: qi + 1], vr[: qi + 1],
                k_pos[: qi + 1]))
        out = jnp.stack(outs, axis=1)   # (B, nq, KV, G, q_chunk, hd)
        out = out.transpose(0, 1, 4, 2, 3, 5)
        return out.reshape(B, Sq, H * hd)

    # Rectangle scan (non-causal / cross-attention / offset prefill):
    # scan over q chunks with a remat'd chunk body -- flash-attention memory
    # behavior, essential for the 32k shapes.
    def q_step(_, inp):
        qc, qp = inp
        return None, jax.checkpoint(one_q_chunk)(qc, qp)

    _, outs = jax.lax.scan(q_step, None, (qr.swapaxes(0, 1), q_pos))
    # outs: (nq, B, KV, G, q_chunk, hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5)  # (B, nq, q_chunk, KV, G, hd)
    return out.reshape(B, Sq, H * hd)


def attention_block(p, x, cfg, positions, *, causal=True, kv_override=None,
                    rope=True):
    """Self-attention (or cross-attention when kv_override=(k, v) given)."""
    q, k, v = _qkv(p, x, cfg, positions, rope=rope)
    if kv_override is not None:
        k, v = kv_override
    out = chunked_attention(q, k, v, causal=causal)
    out = shard(out, "dp", None, "model")   # row-parallel wo input
    return out.astype(x.dtype) @ p["wo"]


def cross_kv(p, ctx, cfg):
    """K/V projections of a context sequence (encoder out / image tokens)."""
    B, T, D = ctx.shape
    KV, hd = cfg.num_kv_heads, cfg.hd
    k = (ctx @ p["wk"]).reshape(B, T, KV, hd)
    v = (ctx @ p["wv"]).reshape(B, T, KV, hd)
    if "bk" in p:
        k = k + p["bk"].reshape(KV, hd)
        v = v + p["bv"].reshape(KV, hd)
    return k, v


# -- decode-step attention -------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array     # (B, S_max, KV, hd); bf16 or int8 (quantized cache)
    v: jax.Array     # (B, S_max, KV, hd)


_KV_SCALE = 16.0   # static symmetric scale for int8 KV quantization


def _kv_quant(x, dtype):
    if dtype != jnp.int8:
        return x.astype(dtype)
    return jnp.clip(jnp.round(x.astype(jnp.float32) * _KV_SCALE),
                    -127, 127).astype(jnp.int8)


def _kv_dequant(x, dtype):
    if x.dtype != jnp.int8:
        return x.astype(dtype)
    return (x.astype(jnp.float32) / _KV_SCALE).astype(dtype)


def decode_attention(p, x, cfg, cache: KVCache, cache_len, *, rope=True):
    """One-token decode against a KV cache; returns (out, new_cache).

    x: (B, 1, D); cache_len: () int32 -- number of valid cache positions.
    """
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    G = H // KV
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    q, k, v = _qkv(p, x, cfg, pos, rope=rope)
    zero = jnp.zeros((), jnp.int32)
    cdt = cache.k.dtype
    newk = jax.lax.dynamic_update_slice(cache.k, _kv_quant(k, cdt),
                                        (zero, cache_len, zero, zero))
    newv = jax.lax.dynamic_update_slice(cache.v, _kv_quant(v, cdt),
                                        (zero, cache_len, zero, zero))
    S = cache.k.shape[1]
    qh = q.reshape(B, KV, G, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                        _kv_dequant(newk, jnp.float32)
                        ) * float(1.0 / np.sqrt(hd))
    valid = jnp.arange(S) <= cache_len
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, _kv_dequant(newv, jnp.float32))
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return out @ p["wo"], KVCache(newk, newv)


def decode_cross_attention(p, x, cfg, ckv: KVCache):
    """One-token cross-attention against a fixed (precomputed) context KV."""
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    G = H // KV
    q = (x @ p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    qh = q.reshape(B, KV, G, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                        ckv.k.astype(jnp.float32)) * float(1.0 / np.sqrt(hd))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, ckv.v.astype(jnp.float32))
    return out.reshape(B, 1, H * hd).astype(x.dtype) @ p["wo"]


# -- MLP -------------------------------------------------------------------------


def init_mlp(key, cfg, dtype, d_ff: int = 0):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "wg": _dense_init(ks[0], (D, F), dtype),
            "wu": _dense_init(ks[1], (D, F), dtype),
            "wd": _dense_init(ks[2], (F, D), dtype),
        }
    return {
        "wi": _dense_init(ks[0], (D, F), dtype),
        "wo": _dense_init(ks[1], (F, D), dtype),
    }


def apply_mlp(p, x, act: str):
    if act == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]


# -- embeddings & loss -----------------------------------------------------------


def init_embeddings(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    p = {"tok": _dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype,
                            scale=np.sqrt(cfg.d_model))}
    if not cfg.tied_embeddings:
        p["head"] = _dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed_logits(p, h):
    if "head" in p:
        return h @ p["head"]
    return h @ p["tok"].T


def chunked_ce_loss(p_emb, h, labels, *, chunk: int = 512):
    """Mean cross-entropy without materializing (B, S, V) logits.

    h: (B, S, D); labels: (B, S) int32 (-1 = ignore).
    Scans over S chunks; per-chunk logits are (B, chunk, V).
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    hs = h.reshape(B, n, chunk, D).swapaxes(0, 1)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_ce(hc, lc):
        logits = unembed_logits(p_emb, hc).astype(jnp.float32)
        logits = shard(logits, "dp", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((lse - picked) * mask), jnp.sum(mask)

    def step(carry, inp):
        tot, cnt = carry
        hc, lc = inp
        t, c = chunk_ce(hc, lc)
        return (tot + t, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)
