"""Model assembly: scanned block stacks for every architecture family.

Layers are grouped into the repeating (mixer, mlp) *pattern* of
``cfg.layer_pattern()``; the parameter stack holds one pytree per pattern
position with a leading ``repeats`` axis, and the depth loop is a
``lax.scan`` -- keeping the HLO compact enough to compile 100-layer models
with 512-way SPMD quickly. ``jax.checkpoint`` wraps each pattern block when
``cfg.remat``.

Decode state is a tuple of per-pattern-position caches (KVCache for attn,
fixed cross-KV for cross-attention, SSMState for SSD layers), each stacked
over repeats and scanned alongside the parameters.
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from .pshard import shard
from . import moe as MOE
from . import ssm as SSM
from .config import ModelConfig
from .layers import KVCache


# -- init -------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, mixer: str, mlp: str, dtype):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": L.init_norm(cfg, dtype)}
    if mixer in ("attn", "cross"):
        p["mixer"] = L.init_attention(ks[0], cfg, dtype)
    else:
        p["mixer"] = SSM.init_ssm(ks[0], cfg, dtype)
    if cfg.family == "audio":  # decoder layers carry self + cross attention
        p["norm_c"] = L.init_norm(cfg, dtype)
        p["cross"] = L.init_attention(ks[1], cfg, dtype)
    if mlp == "moe":
        p["norm2"] = L.init_norm(cfg, dtype)
        p["mlp"] = MOE.init_moe(ks[2], cfg, dtype)
    elif cfg.d_ff > 0:  # pure-SSM archs (mamba2) have no MLP sublayer
        p["norm2"] = L.init_norm(cfg, dtype)
        p["mlp"] = L.init_mlp(ks[2], cfg, dtype)
    return p


def init_model(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    pat = cfg.layer_pattern()
    R = cfg.num_pattern_repeats
    keys = jax.random.split(key, 4)
    params: dict[str, Any] = {"emb": L.init_embeddings(keys[0], cfg, dtype)}

    def stack_blocks(base_key, mixer, mlp):
        ks = jax.random.split(base_key, R)
        trees = [_init_block(k, cfg, mixer, mlp, dtype) for k in ks]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    bkeys = jax.random.split(keys[1], len(pat))
    params["blocks"] = [
        stack_blocks(bkeys[i], mixer, mlp) for i, (mixer, mlp) in enumerate(pat)
    ]
    params["final_norm"] = L.init_norm(cfg, dtype)

    if cfg.encoder_layers:
        ekeys = jax.random.split(keys[2], cfg.encoder_layers)
        etrees = []
        for ek in ekeys:
            ks2 = jax.random.split(ek, 2)
            etrees.append({
                "norm1": L.init_norm(cfg, dtype),
                "mixer": L.init_attention(ks2[0], cfg, dtype),
                "norm2": L.init_norm(cfg, dtype),
                "mlp": L.init_mlp(ks2[1], cfg, dtype),
            })
        params["encoder"] = {
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *etrees),
            "final_norm": L.init_norm(cfg, dtype),
        }
    return params


# -- forward (full-sequence) --------------------------------------------------------


def _apply_block(bp, x, cfg: ModelConfig, mixer: str, mlp: str, positions,
                 ctx_kv, causal: bool):
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(bp["norm1"], x, cfg.norm)
    if mixer == "attn":
        x = x + L.attention_block(bp["mixer"], h, cfg, positions, causal=causal)
    elif mixer == "cross":
        kv = L.cross_kv(bp["mixer"], ctx_kv, cfg)
        x = x + L.attention_block(bp["mixer"], h, cfg, positions,
                                  causal=False, kv_override=kv, rope=False)
    else:
        x = x + SSM.ssd_forward(bp["mixer"], h, cfg)
    if cfg.family == "audio" and ctx_kv is not None:
        hc = L.apply_norm(bp["norm_c"], x, cfg.norm)
        kv = L.cross_kv(bp["cross"], ctx_kv, cfg)
        x = x + L.attention_block(bp["cross"], hc, cfg, positions,
                                  causal=False, kv_override=kv, rope=False)
    if mlp == "moe":
        h2 = L.apply_norm(bp["norm2"], x, cfg.norm)
        y, a = MOE.apply_moe(bp["mlp"], h2, cfg)
        x = x + y
        aux = aux + a
    elif cfg.d_ff > 0:
        h2 = L.apply_norm(bp["norm2"], x, cfg.norm)
        x = x + L.apply_mlp(bp["mlp"], h2, cfg.act)
    return x, aux


def apply_blocks(params, x, cfg: ModelConfig, *, ctx=None, causal=True):
    """Scanned depth loop; returns (hidden, moe_aux)."""
    pat = cfg.layer_pattern()
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def block_step(x, bp, i):
        mixer, mlp = pat[i]
        return _apply_block(bp, x, cfg, mixer, mlp, positions, ctx, causal)

    if cfg.remat:
        policy = None
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        block_step = jax.checkpoint(block_step, static_argnums=(2,),
                                    policy=policy)

    def body(carry, xs):
        x, aux = carry
        for i in range(len(pat)):
            x, a = block_step(x, xs[i], i)
            x = shard(x, "dp", "model", None)   # sequence-parallel carry
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), tuple(params["blocks"]))
    return L.apply_norm(params["final_norm"], x, cfg.norm), aux


def apply_encoder(params, frames, cfg: ModelConfig):
    """Whisper-style encoder over (precomputed) frame embeddings."""
    enc = params["encoder"]

    def body(x, bp):
        B, S, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        h = L.apply_norm(bp["norm1"], x, cfg.norm)
        x = x + L.attention_block(bp["mixer"], h, cfg, pos, causal=False)
        h2 = L.apply_norm(bp["norm2"], x, cfg.norm)
        x = x + L.apply_mlp(bp["mlp"], h2, cfg.act)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, frames, enc["blocks"])
    return L.apply_norm(enc["final_norm"], x, cfg.norm)


# -- train loss ----------------------------------------------------------------------


def train_loss(params, batch, cfg: ModelConfig, *, aux_weight: float = 0.01):
    """Causal-LM CE loss (chunked over the vocab projection)."""
    x = shard(L.embed(params["emb"], batch["tokens"]), "dp", "model", None)
    ctx = None
    if cfg.encoder_layers:
        ctx = apply_encoder(params, batch["frames"], cfg)
    elif cfg.frontend_tokens:
        ctx = batch["patches"]
    h, aux = apply_blocks(params, x, cfg, ctx=ctx, causal=True)
    loss = L.chunked_ce_loss(params["emb"], h, batch["labels"])
    return loss + aux_weight * aux


# -- serving: prefill & decode ---------------------------------------------------------


class DecodeState(NamedTuple):
    caches: tuple          # per pattern position, stacked over repeats
    cache_len: jax.Array   # () int32
    ctx_kv: Optional[tuple]  # ((R_cross?, ...) not used; ctx KV inside caches)


def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int,
                       ctx_len: int = 0):
    """Abstract cache structure (zeros) for one-token serve steps."""
    import jax.numpy as _jnp
    dtype = _jnp.int8 if cfg.kv_cache_dtype == "int8" else cfg.jdtype
    pat = cfg.layer_pattern()
    R = cfg.num_pattern_repeats
    KV, hd = cfg.num_kv_heads, cfg.hd
    caches = []
    for (mixer, _) in pat:
        if mixer == "attn":
            c = KVCache(
                k=jnp.zeros((R, batch, max_len, KV, hd), dtype),
                v=jnp.zeros((R, batch, max_len, KV, hd), dtype),
            )
        elif mixer == "cross":
            c = KVCache(
                k=jnp.zeros((R, batch, ctx_len, KV, hd), dtype),
                v=jnp.zeros((R, batch, ctx_len, KV, hd), dtype),
            )
        else:
            s = SSM.ssm_init_state(cfg, batch, dtype)
            c = SSM.SSMState(
                conv=jnp.zeros((R,) + s.conv.shape, s.conv.dtype),
                ssm=jnp.zeros((R,) + s.ssm.shape, s.ssm.dtype),
            )
        caches.append(c)
    # Audio decoders additionally carry per-position cross-attention KV
    # (encoder outputs projected per layer), appended after the self caches.
    if cfg.family == "audio":
        for _ in pat:
            caches.append(KVCache(
                k=jnp.zeros((R, batch, ctx_len, KV, hd), dtype),
                v=jnp.zeros((R, batch, ctx_len, KV, hd), dtype),
            ))
    return tuple(caches)


def serve_step(params, caches, token, cache_len, cfg: ModelConfig):
    """One-token decode: token (B, 1) int32 -> (logits, new_caches)."""
    pat = cfg.layer_pattern()
    x = L.embed(params["emb"], token)

    def body(x, xs):
        bp_all, cache_all = xs
        new_caches = []
        for i, (mixer, mlp) in enumerate(pat):
            bp, cache = bp_all[i], cache_all[i]
            h = L.apply_norm(bp["norm1"], x, cfg.norm)
            if mixer == "attn":
                out, cache = L.decode_attention(bp["mixer"], h, cfg, cache,
                                                cache_len)
                x = x + out
            elif mixer == "cross":
                x = x + L.decode_cross_attention(bp["mixer"], h, cfg, cache)
            else:
                out, cache = SSM.ssd_decode_step(bp["mixer"], h, cfg, cache)
                x = x + out
            if cfg.family == "audio":
                hc = L.apply_norm(bp["norm_c"], x, cfg.norm)
                x = x + L.decode_cross_attention(bp["cross"], hc, cfg,
                                                 cache_all[len(pat) + i])
            if mlp == "moe":
                h2 = L.apply_norm(bp["norm2"], x, cfg.norm)
                y, _ = MOE.apply_moe(bp["mlp"], h2, cfg)
                x = x + y
            elif cfg.d_ff > 0:
                h2 = L.apply_norm(bp["norm2"], x, cfg.norm)
                x = x + L.apply_mlp(bp["mlp"], h2, cfg.act)
            new_caches.append(cache)
        if cfg.family == "audio":
            new_caches.extend(cache_all[len(pat):])
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        body, x, (tuple(params["blocks"]), caches))
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed_logits(params["emb"], h)
    return logits, new_caches


def prefill(params, batch, cfg: ModelConfig):
    """Full-sequence forward returning last-position logits (prefill shape).

    Cache construction during prefill reuses the forward pass; for the
    dry-run shapes the deliverable is the lowered/compiled prefill compute.
    """
    x = shard(L.embed(params["emb"], batch["tokens"]), "dp", "model", None)
    ctx = None
    if cfg.encoder_layers:
        ctx = apply_encoder(params, batch["frames"], cfg)
    elif cfg.frontend_tokens:
        ctx = batch["patches"]
    h, _ = apply_blocks(params, x, cfg, ctx=ctx, causal=True)
    logits = L.unembed_logits(params["emb"], h[:, -1:])
    return logits
