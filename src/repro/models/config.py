"""Model configuration for the assigned architecture pool.

One frozen dataclass drives every family: dense / MoE / SSM / hybrid /
enc-dec (audio) / VLM (cross-attention). Layer structure is expressed as a
repeating *pattern* of (mixer, mlp) kinds so the parameter stack can be
scanned (compile-time-compact HLO) while still expressing Jamba-style
interleaves.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    every_n_layers: int = 1       # MoE replaces the MLP every n layers
    shared_expert: bool = False   # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    group_size: int = 512         # dispatch group (tokens); see models/moe.py


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 128              # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // num_heads
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "swiglu"           # swiglu | gelu
    qkv_bias: bool = False
    tied_embeddings: bool = False
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every_n: int = 1         # hybrid: 1 attention layer per n (rest SSM)
    encoder_layers: int = 0       # enc-dec (whisper): encoder depth
    cross_attn_every_n: int = 0   # vlm: 1 cross-attn layer per n
    frontend_tokens: int = 0      # stubbed modality tokens (audio frames /
                                  # image patches), fed as embeddings
    max_seq_len: int = 131072
    kv_cache_dtype: str = ""   # "" => model dtype; "int8" => quantized cache
    dtype: str = "bfloat16"
    remat: bool = True            # activation checkpoint each block
    remat_policy: str = "full"    # "full" | "dots" (save matmul outputs)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    # -- layer pattern ---------------------------------------------------------

    def layer_pattern(self) -> Tuple[Tuple[str, str], ...]:
        """Repeating (mixer, mlp) pattern; len divides num_layers.

        mixer in {"attn", "ssm", "cross"}; mlp in {"dense", "moe"}.
        """
        n = self.num_layers
        plen = 1
        if self.attn_every_n > 1:
            plen = _lcm(plen, self.attn_every_n)
        if self.cross_attn_every_n > 0:
            plen = _lcm(plen, self.cross_attn_every_n)
        if self.moe is not None and self.moe.every_n_layers > 1:
            plen = _lcm(plen, self.moe.every_n_layers)
        while n % plen:
            plen += 1  # fall back to a pattern covering the full stack
            if plen >= n:
                plen = n
                break
        pat = []
        for i in range(plen):
            if self.attn_every_n > 1:
                # Jamba places its attention layer mid-block (index n//2).
                mixer = "attn" if i % self.attn_every_n == self.attn_every_n // 2 \
                    else "ssm"
            elif self.family == "ssm":
                mixer = "ssm"
            elif self.cross_attn_every_n > 0 and \
                    i % self.cross_attn_every_n == self.cross_attn_every_n - 1:
                mixer = "cross"
            else:
                mixer = "attn"
            if self.moe is not None and i % self.moe.every_n_layers == \
                    self.moe.every_n_layers - 1:
                mlp = "moe"
            else:
                mlp = "dense"
            pat.append((mixer, mlp))
        return tuple(pat)

    @property
    def num_pattern_repeats(self) -> int:
        return self.num_layers // len(self.layer_pattern())

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        hd, H, KV = self.hd, self.num_heads, self.num_kv_heads
        norm = D * (2 if self.norm == "layernorm" else 1)
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        if self.qkv_bias:
            attn += (H + 2 * KV) * hd
        nmats = 3 if self.act == "swiglu" else 2
        dense_mlp = nmats * D * F
        ssm_p = 0
        if self.ssm is not None:
            din = self.ssm.expand * D
            nh = din // self.ssm.head_dim
            ssm_p = (D * (2 * din + 2 * self.ssm.d_state + nh)
                     + din * D
                     + self.ssm.conv_width * (din + 2 * self.ssm.d_state)
                     + (din + 2 * self.ssm.d_state)        # conv bias
                     + 3 * nh + din)                       # A, dt_b, Dskip, norm
        moe_mlp = 0
        if self.moe is not None:
            e = self.moe.num_experts
            fe = self.moe.d_ff_expert
            moe_mlp = e * nmats * D * fe + D * e
            if self.moe.shared_expert:
                moe_mlp += nmats * D * fe
        total = 0
        for mixer, mlp in self.layer_pattern():
            total += attn if mixer in ("attn", "cross") else ssm_p
            total += norm
            if mlp == "moe":
                total += moe_mlp + norm
            elif F > 0:
                total += dense_mlp + norm
            if self.family == "audio":   # decoder cross-attention sublayer
                total += attn + norm
        total *= self.num_pattern_repeats
        total += V * D * (1 if self.tied_embeddings else 2)
        total += self.encoder_layers * (attn + dense_mlp + 2 * norm)
        total += norm * (2 if self.encoder_layers else 1)  # final norm(s)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        e = self.moe.num_experts
        fe = self.moe.d_ff_expert
        nmoe = sum(1 for _, m in self.layer_pattern() if m == "moe") \
            * self.num_pattern_repeats
        per_expert = (3 if self.act == "swiglu" else 2) * self.d_model * fe
        inactive = nmoe * (e - self.moe.top_k) * per_expert
        return full - inactive


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (arch x shape) cell of the assignment."""
    name: str              # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
