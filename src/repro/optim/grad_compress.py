"""Low-rank gradient compression with error feedback (PowerSGD-style),
built on the paper's randomized range finder.

Before the data-parallel all-reduce, each 2D gradient G (m x n) is
compressed to rank k via one randomized range-finding pass -- exactly the
sampling step of the paper's ARA (``Y = G Omega``, ``Q = orth(Y)``,
``B = G^T Q``) -- cutting the all-reduced payload from m*n to k*(m+n).
The compression residual is fed back into the next step's gradient
(error feedback), which keeps SGD-style convergence guarantees.

Collective savings are reported by ``payload_bytes`` so the dry-run /
roofline can quantify the collective-term reduction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    rank: int = 8
    min_size: int = 64 * 64   # only compress matrices at least this large
    error_feedback: bool = True


class CompressState(NamedTuple):
    error: Any   # residual pytree (zeros for uncompressed leaves)


def _is_compressible(leaf, cfg: CompressConfig) -> bool:
    return leaf.ndim == 2 and leaf.size >= cfg.min_size and \
        min(leaf.shape) > cfg.rank


def compress_init(grads_like, cfg: CompressConfig) -> CompressState:
    err = jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32)
        if _is_compressible(g, cfg) else jnp.zeros((), jnp.float32),
        grads_like)
    return CompressState(error=err)


def _lowrank_pass(G, rank: int, key):
    """One-pass randomized range finder (the ARA sampling step)."""
    m, n = G.shape
    Om = jax.random.normal(key, (n, rank), G.dtype)
    Y = G @ Om                       # sample
    Q, _ = jnp.linalg.qr(Y)          # orthogonalize (CholQR on TPU)
    B = G.T @ Q                      # project
    return Q, B                      # G ~= Q B^T


def compress_grads(grads, state: CompressState, cfg: CompressConfig, key):
    """Returns (decompressed_grads, new_state, stats).

    In a multi-host deployment the all-reduce runs on (Q, B) factors; here we
    return the decompressed gradient (single-process semantics) plus payload
    accounting for the roofline.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    err_leaves = jax.tree_util.tree_leaves(state.error)
    keys = jax.random.split(key, len(leaves))
    out, new_err = [], []
    raw_bytes = compressed_bytes = 0
    for i, (g, e) in enumerate(zip(leaves, err_leaves)):
        if _is_compressible(g, cfg):
            gf = g.astype(jnp.float32)
            if cfg.error_feedback:
                gf = gf + e
            Q, B = _lowrank_pass(gf, cfg.rank, keys[i])
            approx = Q @ B.T
            resid = gf - approx
            out.append(approx.astype(g.dtype))
            new_err.append(resid if cfg.error_feedback
                           else jnp.zeros_like(resid))
            raw_bytes += g.size * 4
            compressed_bytes += (Q.size + B.size) * 4
        else:
            out.append(g)
            new_err.append(jnp.zeros((), jnp.float32))
            raw_bytes += g.size * 4
            compressed_bytes += g.size * 4
    stats = {"payload_bytes": compressed_bytes, "raw_bytes": raw_bytes,
             "ratio": raw_bytes / max(compressed_bytes, 1)}
    return (jax.tree_util.tree_unflatten(treedef, out),
            CompressState(error=jax.tree_util.tree_unflatten(treedef,
                                                             new_err)),
            stats)
