"""TLR-KFAC: Kronecker-factored natural-gradient preconditioning where the
curvature factors are Cholesky-factored in TILE LOW RANK form.

This is the paper's factorization deployed as a first-class training feature
(the paper names "Hessians of optimization problems" among its target
workloads). For a weight W (m x n) with layer input a and output-gradient g,
K-FAC preconditions with the Kronecker factors

    A = E[a a^T] (n x n, activation covariance)
    S = E[g g^T] (m x m, output-gradient covariance)
    P = S^{-1} G A^{-1}

A and S are covariance matrices -- exactly the data-sparse SPD operators the
paper factors. Every ``refresh_every`` steps the damped factors are
compressed to TLR and factored with the left-looking ARA Cholesky
(GEMM-rich, O(n^1.5) memory vs O(n^2), O(n^2)-ish work vs O(n^3)); the
preconditioner application is two TLR triangular solves per side.

The trainer streams curvature observations via the ``curvature`` argument
({leaf-name: (a_batch, g_batch)} or precomputed (A, S) matrices); leaves
without curvature fall back to AdamW. Step size is grafted from AdamW
(direction from K-FAC, norm from Adam), the standard stabilization.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CholOptions, TLROperator
from .adamw import AdamWConfig, AdamWState, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TLRNewtonConfig:
    beta: float = 0.95
    damping: float = 1e-3
    min_dim: int = 64           # sides smaller than this solve densely
    tile: int = 32              # TLR tile size for the curvature factors
    eps_tlr: float = 1e-6       # ARA compression threshold
    refresh_every: int = 10     # factorization refresh cadence
    grafting: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class TLRNewtonState(NamedTuple):
    step: int
    stats: dict                  # leaf-name -> {"A": .., "S": ..} EMA factors
    facts: dict                  # leaf-name -> {"A": solve, "S": solve}
    adam: AdamWState


def _leaf_names(tree) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]


def tlr_newton_init(params, cfg: TLRNewtonConfig) -> TLRNewtonState:
    return TLRNewtonState(step=0, stats={}, facts={},
                          adam=adamw_init(params, cfg.grafting))


def _as_cov(obs, dim: int) -> np.ndarray:
    """Accept either a covariance matrix (dim x dim) or a batch of vectors
    (batch x dim) to be averaged into one."""
    obs = np.asarray(obs, np.float64)
    if obs.shape == (dim, dim):
        return obs
    if obs.ndim == 2 and obs.shape[1] == dim:
        return obs.T @ obs / obs.shape[0]
    raise ValueError(f"curvature obs shape {obs.shape} for dim {dim}")


def _make_solver(S: np.ndarray, cfg: TLRNewtonConfig):
    """Damped factorization of one curvature factor; returns solve(x)."""
    n = S.shape[0]
    lam = cfg.damping * (np.trace(S) / n + 1.0)
    damped = S + lam * np.eye(n)
    if n < max(cfg.min_dim, 2 * cfg.tile) or n % cfg.tile:
        chol = np.linalg.cholesky(damped)

        def solve_dense(x):
            y = jax.scipy.linalg.solve_triangular(
                jnp.asarray(chol), x, lower=True)
            return jax.scipy.linalg.solve_triangular(
                jnp.asarray(chol.T), y, lower=False)

        return solve_dense
    # r_max = tile size: rank-adaptive ARA keeps actual ranks low where the
    # factor is data-sparse, but generic K-FAC covariances may have
    # full-rank tiles and must not be force-truncated.
    op = TLROperator.compress(jnp.asarray(damped), cfg.tile,
                              eps=cfg.eps_tlr * 1e-2)
    fact = op.cholesky(CholOptions(eps=cfg.eps_tlr, bs=8, schur="diag"))
    return fact.solve


def tlr_newton_update(grads, state: TLRNewtonState, params,
                      cfg: TLRNewtonConfig,
                      curvature: Optional[dict] = None):
    """Returns (new_params, new_state).

    ``curvature``: {leaf-name: (A_obs, S_obs)}; each obs is a covariance
    matrix or a (batch, dim) array of observations. A_obs is the
    activation-side (n) factor, S_obs the output-gradient-side (m) factor;
    either may be None to precondition one side only.
    Host-driven (factorization refresh outside jit), mirroring the paper's
    host-orchestrated factorization.
    """
    names = _leaf_names(params)
    gleaves, treedef = jax.tree_util.tree_flatten(grads)
    pleaves = jax.tree_util.tree_leaves(params)
    curvature = curvature or {}

    # 1) EMA curvature statistics
    new_stats = dict(state.stats)
    for n, g in zip(names, gleaves):
        if n not in curvature or g.ndim != 2:
            continue
        m, k = g.shape
        A_obs, S_obs = curvature[n]
        ent = dict(new_stats.get(n, {}))
        if A_obs is not None:
            A = _as_cov(A_obs, k)
            ent["A"] = cfg.beta * ent.get("A", np.zeros((k, k))) + \
                (1 - cfg.beta) * A
        if S_obs is not None:
            S = _as_cov(S_obs, m)
            ent["S"] = cfg.beta * ent.get("S", np.zeros((m, m))) + \
                (1 - cfg.beta) * S
        new_stats[n] = ent

    # 2) refresh TLR factorizations on cadence
    facts = dict(state.facts)
    if state.step % cfg.refresh_every == 0:
        for n, ent in new_stats.items():
            facts[n] = {side: _make_solver(S, cfg)
                        for side, S in ent.items()}

    # 3) AdamW grafting pass (fallback direction + step norm)
    adam_params, adam_state = adamw_update(grads, state.adam, params,
                                           cfg.grafting)

    # 4) preconditioned update for leaves with curvature
    out = []
    adam_leaves = jax.tree_util.tree_leaves(adam_params)
    for n, g, p, ap in zip(names, gleaves, pleaves, adam_leaves):
        f = facts.get(n)
        if f:
            Pg = g.astype(jnp.float64)
            if "S" in f:                      # left: S^{-1} G
                Pg = f["S"](Pg)
            if "A" in f:                      # right: G A^{-1}
                Pg = f["A"](Pg.T).T
            a_step = (ap - p).astype(jnp.float64)
            denom = jnp.maximum(jnp.linalg.norm(Pg), 1e-30)
            upd = Pg * (jnp.linalg.norm(a_step) / denom)
            out.append((p.astype(jnp.float64) - upd).astype(p.dtype))
        else:
            out.append(ap)
    new_params = jax.tree_util.tree_unflatten(treedef, out)
    return new_params, TLRNewtonState(step=state.step + 1, stats=new_stats,
                                      facts=facts, adam=adam_state)
