"""Optimizers: AdamW, TLR-Newton (paper's factorization as a training
feature), ARA low-rank gradient compression."""

from .adamw import AdamWConfig, AdamWState, adamw_init, adamw_update, \
    global_norm  # noqa: F401
from .grad_compress import (CompressConfig, CompressState, compress_grads,
                            compress_init)  # noqa: F401
from .tlr_newton import (TLRNewtonConfig, TLRNewtonState, tlr_newton_init,
                         tlr_newton_update)  # noqa: F401
