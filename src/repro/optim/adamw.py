"""Minimal, sharding-friendly AdamW with optional low-precision moments.

Moments inherit the parameter sharding (jax.tree-mapped elementwise ops), so
the optimizer adds no collectives of its own. ``moment_dtype=bfloat16``
halves optimizer-state HBM -- used for the 400B-class dry-run configs
(recorded as a distributed-optimization feature in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        mf = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        vf = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        mhat = mf / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = vf / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - cfg.lr * delta
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
