"""phi3-mini-3.8b [dense]: 32L d=3072 32H (kv=32) ff=8192 V=32064,
RoPE SwiGLU GQA. [arXiv:2404.14219]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32064,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        max_seq_len=256, dtype="float32", remat=False,
    )
