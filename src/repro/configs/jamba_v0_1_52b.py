"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (kv=8) ff=14336 V=65536,
MoE 16e top-2 every other layer, Mamba+attn 1:7 interleave.
[arXiv:2403.19887; hf]"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=65536,
        attn_every_n=8,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                      every_n_layers=2),
        ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_width=4),
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        attn_every_n=8,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      every_n_layers=2, group_size=64),
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, conv_width=4,
                      chunk=16),
        max_seq_len=256, dtype="float32", remat=False,
    )
