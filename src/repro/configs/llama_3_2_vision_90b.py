"""llama-3.2-vision-90b [vlm]: 100L (80 self + 20 cross-attn image layers)
d=8192 64H (kv=8) ff=28672 V=128256; vision frontend stubbed (precomputed
patch embeddings). [hf:meta-llama/Llama-3.2-vision family]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256,
        cross_attn_every_n=5, frontend_tokens=1600,
        rope_theta=5e5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke", family="vlm",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        cross_attn_every_n=5, frontend_tokens=16,
        max_seq_len=256, dtype="float32", remat=False,
    )
