"""Architecture registry: the 10 assigned configs + reduced smoke variants +
the paper's own TLR problem configs.

``get_config(arch)`` returns the full published config; ``get_config(arch,
smoke=True)`` returns a structurally-identical reduced config for CPU tests.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, MoEConfig, SSMConfig, SHAPES  # noqa: F401

ARCHS = [
    "jamba_v0_1_52b",
    "whisper_large_v3",
    "qwen1_5_0_5b",
    "mistral_nemo_12b",
    "stablelm_1_6b",
    "phi3_mini_3_8b",
    "llama4_maverick_400b_a17b",
    "granite_moe_3b_a800m",
    "mamba2_130m",
    "llama_3_2_vision_90b",
]

# canonical ids as assigned (dash/dot form) -> module name
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "stablelm-1.6b": "stablelm_1_6b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mamba2-130m": "mamba2_130m",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
})
ALIASES.update({a: a for a in ARCHS})


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch)
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(set(ALIASES))}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config() if smoke else mod.config()


def supported_shapes(cfg: ModelConfig) -> list[str]:
    """Shapes runnable for this arch (long_500k: sub-quadratic archs only,
    per the assignment; skips documented in DESIGN.md section 5)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):
        shapes.append("long_500k")
    return shapes
