"""mistral-nemo-12b [dense]: 40L d=5120 32H (kv=8) ff=14336 V=131072,
head_dim=128, 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=131072,
        rope_theta=1e6, max_seq_len=131072,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemo-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        max_seq_len=256, dtype="float32", remat=False,
    )
