"""stablelm-1.6b [dense]: 24L d=2048 32H (kv=32) ff=5632 V=100352.
[hf:stabilityai/stablelm-2-1_6b]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=5632, vocab_size=100352,
        norm="layernorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, norm="layernorm",
        max_seq_len=256, dtype="float32", remat=False,
    )
