"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (kv=8) V=49155,
MoE 40e top-8 with per-expert ff=512. [hf:ibm-granite/granite-3.0 family]"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
        d_ff=512, vocab_size=49155,
        moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512,
                      every_n_layers=1, group_size=256),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        moe=MoEConfig(num_experts=5, top_k=3, d_ff_expert=64,
                      every_n_layers=1, group_size=64),
        max_seq_len=256, dtype="float32", remat=False,
    )
