"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (kv=8) ff=8192 V=202048,
MoE 128e top-1 + shared expert, MoE every other layer (400B total / ~17B
active). [hf:meta-llama/Llama-4 family]"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=202048,
        moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                      every_n_layers=2, shared_expert=True),
        rope_theta=5e5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-smoke", family="moe",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        moe=MoEConfig(num_experts=8, top_k=1, d_ff_expert=128,
                      every_n_layers=2, shared_expert=True, group_size=64),
        max_seq_len=256, dtype="float32", remat=False,
    )
