"""whisper-large-v3 [audio]: enc-dec, 32L(+32L enc) d=1280 20H (kv=20)
ff=5120 V=51866; conv frontend stubbed (precomputed frame embeddings).
[arXiv:2212.04356]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        norm="layernorm", act="gelu",
        encoder_layers=32, frontend_tokens=1500,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        norm="layernorm", act="gelu",
        encoder_layers=2, frontend_tokens=32,
        max_seq_len=256, dtype="float32", remat=False,
    )
