"""mamba2-130m [ssm]: 24L d=768 attn-free, ssm_state=128, SSD.
[arXiv:2405.21060]"""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        num_layers=24, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=0, vocab_size=50280,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, conv_width=4),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=256,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, conv_width=4,
                      chunk=16),
        max_seq_len=256, dtype="float32", remat=False,
    )
