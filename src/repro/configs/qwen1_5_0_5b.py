"""qwen1.5-0.5b [dense]: 24L d=1024 16H (kv=16) ff=2816 V=151936, QKV bias.
[hf:Qwen/Qwen1.5-0.5B]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b", family="dense",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=2816, vocab_size=151936,
        qkv_bias=True, tied_embeddings=True, rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        qkv_bias=True, tied_embeddings=True,
        max_seq_len=256, dtype="float32", remat=False,
    )
