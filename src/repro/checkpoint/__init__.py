from .store import (latest_checkpoint, restore_checkpoint,
                    save_checkpoint)  # noqa: F401
