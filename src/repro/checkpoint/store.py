"""Checkpointing: atomic, manifest-driven, keep-k, elastic restore.

Layout:  <dir>/step_<n>/
           manifest.json   tree structure, shapes, dtypes, step, meta
           <leaf-id>.npy   one array per pytree leaf

Writes go to ``step_<n>.tmp`` and are published with an atomic
``os.replace`` -- a crashed writer never corrupts the newest checkpoint.
Restore is *elastic*: arrays are stored mesh-independently (full logical
shapes) and re-device_put with whatever shardings the new mesh prescribes,
so a job can restart on a different topology (the reshard-on-restore path
that large-cluster elasticity needs). On multi-host clusters each host would
write its addressable shards; the manifest format already carries the
sharding metadata needed to reassemble.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes  # noqa: F401  -- registers bfloat16 et al. with numpy
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out.append((name or "leaf", leaf))
    return out


def save_checkpoint(directory: str | Path, step: int, tree: Any, *,
                    meta: Optional[dict] = None, keep: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves = _leaf_paths(tree)
    names = []
    for i, (name, leaf) in enumerate(leaves):
        lid = f"{i:05d}_{name[:120]}"
        arr = np.asarray(leaf)
        # raw-byte storage: survives dtypes numpy can't round-trip (bf16)
        np.save(tmp / f"{lid}.npy",
                np.frombuffer(arr.tobytes(), np.uint8),
                allow_pickle=False)
        names.append({"id": lid, "dtype": str(arr.dtype),
                      "shape": list(arr.shape)})
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "time": time.time(),
        "treedef": str(treedef),
        "leaves": names,
        "meta": meta or {},
        "format": 2,
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    # keep-k retention
    ckpts = sorted(directory.glob("step_*"))
    ckpts = [c for c in ckpts if c.is_dir() and not c.name.endswith(".tmp")]
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
    return final


def latest_checkpoint(directory: str | Path) -> Optional[Path]:
    directory = Path(directory)
    if not directory.exists():
        return None
    ckpts = sorted(d for d in directory.glob("step_*")
                   if d.is_dir() and (d / "manifest.json").exists())
    return ckpts[-1] if ckpts else None


def restore_checkpoint(path: str | Path, tree_like: Any, *,
                       shardings: Any = None) -> tuple[int, Any, dict]:
    """Restore into the structure of ``tree_like``; optionally device_put
    with new ``shardings`` (elastic restore onto a different mesh)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    leaves_meta = manifest["leaves"]
    arrays = []
    for lm in leaves_meta:
        raw = np.load(path / f"{lm['id']}.npy")
        arrays.append(raw.view(np.dtype(lm["dtype"])).reshape(lm["shape"]))
    treedef = jax.tree_util.tree_structure(tree_like)
    if treedef.num_leaves != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, structure wants "
            f"{treedef.num_leaves}")
    ref_leaves = jax.tree_util.tree_leaves(tree_like)
    cast = [a.astype(r.dtype) if hasattr(r, "dtype") and a.dtype != r.dtype
            else a for a, r in zip(arrays, ref_leaves)]
    tree = jax.tree_util.tree_unflatten(treedef, cast)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return manifest["step"], tree, manifest.get("meta", {})
