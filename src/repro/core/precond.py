"""Newton-Schulz TLR approximate inverse, used as a PCG preconditioner.

The classical iteration ``X_{k+1} = X_k (2I - A X_k)`` converges
quadratically to ``A^{-1}`` whenever ``||I - A X_0|| < 1``; for SPD ``A``
the scaling ``X_0 = I / tr(A)`` guarantees that (every eigenvalue of
``A/tr(A)`` lies in (0, 1)), and each iterate stays a polynomial in ``A``
-- hence symmetric positive definite, which is what lets ``X_k`` serve as
a PCG preconditioner at *any* iteration count: after ``m`` steps the
preconditioned spectrum is ``1 - (1 - lambda/tr)^(2^m)``, compressing the
condition number by ~``2^m`` even far from convergence.

In TLR arithmetic (core/algebra.py) each iteration is exactly two
``tlr_gemm`` (``M = A X``, ``S = X M``), one ``tlr_axpy``
(``2 X - sym(S)``), and the rounding those ops carry at ``eps`` -- ranks
stay bounded by ``r_max_out`` throughout, so the cost per iteration is
O(nb^2) batched small GEMMs, never a dense n x n product. The
symmetrization projects out the (eps-sized) asymmetry the two sequential
rounded products introduce, keeping PCG's SPD requirement honest.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .algebra import symmetrize, tlr_axpy, tlr_gemm, tlr_scale
from .dense_ref import spectral_norm_est_op
from .operator import TLROperator
from .solve import tlr_matvec
from .tlr import TLRMatrix, num_tiles


@dataclasses.dataclass(frozen=True)
class NewtonSchulzInfo:
    """Host-side instrumentation of a ``tlr_newton_schulz`` run."""

    alpha: float                  # initial scaling X_0 = alpha I
    iters: int
    residual_history: list       # ||I - A X_k||_2 estimates (if tracked)
    avg_rank: float               # mean off-diagonal rank of the final X
    max_rank: int


def _identity_tlr(nb: int, b: int, r_max: int, dtype, alpha) -> TLRMatrix:
    nt = num_tiles(nb)
    eye = jnp.asarray(alpha, dtype) * jnp.eye(b, dtype=dtype)
    return TLRMatrix(
        D=jnp.broadcast_to(eye, (nb, b, b)),
        U=jnp.zeros((nt, b, r_max), dtype),
        V=jnp.zeros((nt, b, r_max), dtype),
        ranks=jnp.zeros((nt,), jnp.int32),
    )


def tlr_newton_schulz(
    A,
    iters: int = 8,
    eps: float = 1e-6,
    r_max_out: Optional[int] = None,
    *,
    scale: str = "trace",
    impl: Optional[str] = None,
    track_residual: bool = False,
) -> tuple[TLROperator, NewtonSchulzInfo]:
    """Approximate ``A^{-1}`` in TLR form by Newton-Schulz iteration.

    ``A`` is a ``TLROperator`` or ``TLRMatrix`` (SPD). ``scale`` picks the
    initial ``X_0 = alpha I``: ``"trace"`` (alpha = 1/tr(A), always safe)
    or ``"norm"`` (alpha = 1/||A||_2 estimate, faster start). Returns the
    approximate inverse as a ``TLROperator`` -- its ``.matvec`` is the
    preconditioner action, so it plugs straight into ``pcg(precond=...)``
    -- plus a :class:`NewtonSchulzInfo`.

    ``track_residual`` estimates ``||I - A X_k||_2`` each iteration by
    power iteration (30 extra matvecs per step; diagnostics only).
    """
    op = A if isinstance(A, TLROperator) else TLROperator(A)
    nb, b = op.nb, op.b
    r_out = r_max_out or op.r_max
    if scale == "trace":
        alpha = 1.0 / float(op.trace())
    elif scale == "norm":
        alpha = 1.0 / spectral_norm_est_op(op.matvec, op.n)
    else:
        raise ValueError(f"scale must be 'trace' or 'norm', got {scale!r}")

    X = _identity_tlr(nb, b, r_out, op.dtype, alpha)
    history = []

    def residual(Xc):
        return spectral_norm_est_op(
            lambda v: v - op.matvec(tlr_matvec(Xc, v)), op.n)

    for _ in range(iters):
        M = tlr_gemm(op.A, X, eps, r_max_out=r_out, impl=impl)    # A X
        S = tlr_gemm(X, M, eps, r_max_out=r_out, impl=impl)       # X A X
        Ssym = symmetrize(S, eps=eps, r_max_out=r_out, impl=impl)
        X = tlr_axpy(-1.0, Ssym, tlr_scale(2.0, X), eps=eps,
                     r_max_out=r_out, impl=impl)                  # 2X - XAX
        if track_residual:
            history.append(residual(X))

    ranks = np.asarray(X.ranks)
    info = NewtonSchulzInfo(
        alpha=alpha,
        iters=iters,
        residual_history=history,
        avg_rank=float(ranks.mean()) if ranks.size else 0.0,
        max_rank=int(ranks.max()) if ranks.size else 0,
    )
    return TLROperator(X), info
