"""Newton-Schulz TLR approximate inverse, used as a PCG preconditioner.

The classical iteration ``X_{k+1} = X_k (2I - A X_k)`` converges
quadratically to ``A^{-1}`` whenever ``||I - A X_0|| < 1``; for SPD ``A``
the scaling ``X_0 = I / tr(A)`` guarantees that (every eigenvalue of
``A/tr(A)`` lies in (0, 1)), and each iterate stays a polynomial in ``A``
-- hence symmetric positive definite, which is what lets ``X_k`` serve as
a PCG preconditioner at *any* iteration count: after ``m`` steps the
preconditioned spectrum is ``1 - (1 - lambda/tr)^(2^m)``, compressing the
condition number by ~``2^m`` even far from convergence.

In TLR arithmetic (core/algebra.py) each iteration is exactly two
``tlr_gemm`` (``M = A X``, ``S = X M``), one ``tlr_axpy``
(``2 X - sym(S)``), and the rounding those ops carry at ``eps`` -- ranks
stay bounded by ``r_max_out`` throughout, so the cost per iteration is
O(nb^2) batched small GEMMs, never a dense n x n product. The
symmetrization projects out the (eps-sized) asymmetry the two sequential
rounded products introduce, keeping PCG's SPD requirement honest.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .algebra import symmetrize, tlr_axpy, tlr_gemm, tlr_scale
from .dense_ref import spectral_norm_est_op
from .operator import TLROperator
from .solve import tlr_matvec
from .tlr import TLRMatrix, num_tiles


@dataclasses.dataclass(frozen=True)
class NewtonSchulzInfo:
    """Host-side instrumentation of a ``tlr_newton_schulz`` run."""

    alpha: float                  # initial scaling X_0 = alpha I
    iters: int                    # iterations actually run
    residual_history: list       # ||I - A X_k||_2 estimates (if tracked)
    avg_rank: float               # mean off-diagonal rank of the final X
    max_rank: int
    eps_history: list = dataclasses.field(default_factory=list)
                                  # per-iteration rounding eps (adaptive mode)
    converged: bool = False       # residual stopping rule fired (tol > 0)


def _identity_tlr(nb: int, b: int, r_max: int, dtype, alpha) -> TLRMatrix:
    nt = num_tiles(nb)
    eye = jnp.asarray(alpha, dtype) * jnp.eye(b, dtype=dtype)
    return TLRMatrix(
        D=jnp.broadcast_to(eye, (nb, b, b)),
        U=jnp.zeros((nt, b, r_max), dtype),
        V=jnp.zeros((nt, b, r_max), dtype),
        ranks=jnp.zeros((nt,), jnp.int32),
    )


def tlr_newton_schulz(
    A,
    iters: int = 8,
    eps: float = 1e-6,
    r_max_out: Optional[int] = None,
    *,
    scale: str = "trace",
    impl: Optional[str] = None,
    track_residual: bool = False,
    adaptive: bool = False,
    tol: float = 0.0,
    loose_eps: float = 1e-2,
    batching: str = "flat",
) -> tuple[TLROperator, NewtonSchulzInfo]:
    """Approximate ``A^{-1}`` in TLR form by Newton-Schulz iteration.

    ``A`` is a ``TLROperator`` or ``TLRMatrix`` (SPD). ``scale`` picks the
    initial ``X_0 = alpha I``: ``"trace"`` (alpha = 1/tr(A), always safe)
    or ``"norm"`` (alpha = 1/||A||_2 estimate, faster start). Returns the
    approximate inverse as a ``TLROperator`` -- its ``.matvec`` is the
    preconditioner action, so it plugs straight into ``pcg(precond=...)``
    -- plus a :class:`NewtonSchulzInfo`.

    ``track_residual`` estimates ``||I - A X_k||_2`` each iteration by
    power iteration (30 extra matvecs per step; diagnostics only).

    Scale knobs (ROADMAP "Newton-Schulz at scale"; the fixed-count,
    fixed-eps path above stays the default):

    * ``adaptive=True``: per-iteration rounding threshold, loose early and
      tight late -- ``eps_k = clip(loose_eps * r_{k-1}, eps, loose_eps)``
      with ``r_k`` the residual-norm estimate. While the iterate is far
      from ``A^{-1}`` there is nothing worth preserving below the current
      residual, so early rounding at ``eps`` only burns rank; quadratic
      convergence then drags ``eps_k`` down to ``eps`` exactly when the
      accuracy is needed.
    * ``tol > 0``: stopping rule on the residual estimate -- the loop ends
      as soon as ``||I - A X_k||_2 < tol`` (``iters`` becomes a cap, and
      ``info.converged`` reports whether the rule fired).

    ``batching="ranked"`` routes every product/rounding through the
    rank-bucketed dispatch layer (core/batching.py).
    """
    op = A if isinstance(A, TLROperator) else TLROperator(A)
    nb, b = op.nb, op.b
    r_out = r_max_out or op.r_max
    if scale == "trace":
        alpha = 1.0 / float(op.trace())
    elif scale == "norm":
        alpha = 1.0 / spectral_norm_est_op(op.matvec, op.n)
    else:
        raise ValueError(f"scale must be 'trace' or 'norm', got {scale!r}")

    X = _identity_tlr(nb, b, r_out, op.dtype, alpha)
    history = []
    eps_history = []
    converged = False
    it_done = 0

    def residual(Xc):
        return spectral_norm_est_op(
            lambda v: v - op.matvec(tlr_matvec(Xc, v)), op.n)

    need_residual = adaptive or tol > 0
    r_est = residual(X) if need_residual else None

    for _ in range(iters):
        eps_i = eps
        if adaptive:
            # clip bounds must be ordered even when the caller's eps is
            # already coarser than loose_eps (np.clip with a_min > a_max
            # silently returns a_max, ignoring the requested threshold)
            eps_i = float(np.clip(loose_eps * r_est, eps,
                                  max(eps, loose_eps)))
        M = tlr_gemm(op.A, X, eps_i, r_max_out=r_out, impl=impl,
                     batching=batching)                           # A X
        S = tlr_gemm(X, M, eps_i, r_max_out=r_out, impl=impl,
                     batching=batching)                           # X A X
        Ssym = symmetrize(S, eps=eps_i, r_max_out=r_out, impl=impl,
                          batching=batching)
        X = tlr_axpy(-1.0, Ssym, tlr_scale(2.0, X), eps=eps_i,
                     r_max_out=r_out, impl=impl,
                     batching=batching)                           # 2X - XAX
        it_done += 1
        eps_history.append(eps_i)
        if need_residual or track_residual:
            r_est = residual(X)
            if track_residual:
                history.append(r_est)
            if tol > 0 and r_est < tol:
                converged = True
                break

    ranks = np.asarray(X.ranks)
    info = NewtonSchulzInfo(
        alpha=alpha,
        iters=it_done,
        residual_history=history,
        avg_rank=float(ranks.mean()) if ranks.size else 0.0,
        max_rank=int(ranks.max()) if ranks.size else 0,
        eps_history=eps_history,
        converged=converged,
    )
    return TLROperator(X), info
