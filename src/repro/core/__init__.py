"""repro.core -- the paper's contribution: TLR symmetric factorizations.

Public API (operator-first since PR 2; DESIGN.md section 5):

  TLROperator                      construction + algebra facade
    .compress / .from_dense / .from_kernel   batched tile compression
    .matvec / @ / .to_dense / .memory_stats  operator algebra
    .cholesky(opts) / .ldlt(opts)            -> TLRFactorization
  TLRFactorization                 active factorization handle
    .solve(y) / .tri_solve / .tri_matvec     jitted bucketed TRSM solves
    .logdet() / .sample(key, num)            determinant / MVN sampling
    .matvec                                  preconditioner action (A^{-1})
  CholOptions, tlr_cholesky, tlr_ldlt        factorizations (CholOptions.algo
                                             picks left- vs right-looking)
  TLRMatrix                                  tile low rank representation
  TLRTiles                                   general (nonsymmetric) tile grid
  ARAParams, ara_compress_dense              adaptive randomized approx.
  tlr_matvec, tlr_trsv, pcg                  free-function operator algebra
                                             (pcg accepts (n, k) RHS with
                                             per-column masks since PR 7)
  BatchedPCG                                 incremental batched-RHS PCG
                                             engine (the serve-path core)
  tlr_round, tlr_axpy, tlr_scale, tlr_gemm, tlr_syrk   batched tile algebra
  TilePlan, tile_plan, plan_rank_buckets     rank-aware execution plans
                                             (memoized per ranks array;
                                             DESIGN.md section 9)
  choose_batching, resolve_policy            the batching="auto" policy
                                             (rank histogram + cost model)
  trace_count, trace_counts,                 unified compile-count registry
  trace_counts_diff
                                             ("trsm"/"algebra"/"batching"/
                                             "plan" keys)
  batching_trace_count, set_tile_mesh        rank-bucketed dynamic batching
                                             + tile-mesh sharding (DESIGN.md
                                             section 8; pad_tile_batch /
                                             tile_dp_size size buffers to
                                             the sharding quantum)
  Stage, SequentialSchedule,                 column-stage graph + schedules
  LookaheadSchedule, run_graph               both drivers execute (DESIGN.md
                                             section 12; CholOptions.lookahead
                                             picks the overlap schedule)
  RetryPolicy, HealthMonitor,                breakdown detection + bounded
  HealthEvent, BreakdownReport,              recovery (CholOptions.check /
  FactorizationBreakdown, column_flags       .retry; DESIGN.md section 13)
  tlr_newton_schulz                          Newton-Schulz TLR inverse / PCG
  covariance_problem, fractional_diffusion_problem   paper's test matrices

Deprecated shims (kept for one release; each warns and delegates):
  from_dense          -> TLROperator.compress
(the PR-2 ``tlr_factor_solve`` / ``tlr_logdet`` / ``mvn_sample`` shims were
removed in PR 6 -- use the TLRFactorization handle methods)
"""

from .tlr import (  # noqa: F401
    TLRMatrix, from_dense, tlr_to_dense, zeros_like_structure,
    tril_index, tril_pairs, num_tiles, rank_heatmap,
)
from .ara import ARAParams, ara_compress_dense, run_ara_fused  # noqa: F401
from .operator import TLROperator, TLRFactorization  # noqa: F401
from .cholesky import (  # noqa: F401
    CholOptions, tlr_cholesky, tlr_ldlt,
    robust_cholesky, dense_ldlt_tile,
)
from .buckets import (trace_count, trace_counts,  # noqa: F401
                      trace_counts_diff)
from .solve import (  # noqa: F401
    BatchedPCG, PCGHistory, tlr_matvec, tlr_tri_matvec, tlr_trsv,
    tlr_trsv_reference, trsm_trace_count, pcg, tile_perm_to_element_perm,
)
from .generators import (  # noqa: F401
    grid_points, ball_points, exp_covariance, matern32_covariance,
    fractional_diffusion, covariance_problem, fractional_diffusion_problem,
)
from .algebra import (  # noqa: F401
    TLRTiles, algebra_trace_count, generalize, offd_index, offd_pairs,
    symmetrize, tlr_add_diag, tlr_axpy, tlr_gemm, tlr_round,
    tlr_round_tiles, tlr_scale, tlr_syrk, tlr_syrk_column, tlr_transpose,
)
from .batching import (  # noqa: F401
    BatchPlan, RankBucket, TilePlan, batching_trace_count, bucket_width,
    bucketed_round_tiles, choose_batching, pad_tile_batch,
    plan_rank_buckets, rank_ladder, resolve_batching, resolve_policy,
    set_tile_mesh, shard_tile_batch, tile_dp_size, tile_mesh, tile_plan,
)
from .stages import (  # noqa: F401
    LookaheadSchedule, Schedule, SequentialSchedule, Stage, build_deps,
    run_graph,
)
from .health import (  # noqa: F401
    BreakdownReport, FactorizationBreakdown, HealthEvent, HealthMonitor,
    RetryPolicy, column_flags,
)
from .precond import NewtonSchulzInfo, tlr_newton_schulz  # noqa: F401
from .ordering import kd_tree_ordering, morton_ordering  # noqa: F401
from .dense_ref import (  # noqa: F401
    dense_cholesky, dense_ldlt, blocked_cholesky_left, spectral_norm_est,
    spectral_norm_est_op,
)
