"""repro.core -- the paper's contribution: TLR symmetric factorizations.

Public API:
  TLRMatrix, from_dense, tlr_to_dense           tile low rank representation
  ARAParams, ara_compress_dense                 adaptive randomized approx.
  CholOptions, tlr_cholesky, tlr_ldlt           left-looking factorizations
  tlr_matvec, tlr_trsv, tlr_factor_solve, pcg   operator algebra
  covariance_problem, fractional_diffusion_problem   paper's test matrices
"""

from .tlr import (  # noqa: F401
    TLRMatrix, from_dense, tlr_to_dense, zeros_like_structure,
    tril_index, tril_pairs, num_tiles, rank_heatmap,
)
from .ara import ARAParams, ara_compress_dense, run_ara_fused  # noqa: F401
from .cholesky import (  # noqa: F401
    CholOptions, TLRFactorization, tlr_cholesky, tlr_ldlt,
    robust_cholesky, dense_ldlt_tile,
)
from .solve import (  # noqa: F401
    tlr_matvec, tlr_tri_matvec, tlr_trsv, tlr_factor_solve, tlr_logdet,
    mvn_sample, pcg, tile_perm_to_element_perm,
)
from .generators import (  # noqa: F401
    grid_points, ball_points, exp_covariance, matern32_covariance,
    fractional_diffusion, covariance_problem, fractional_diffusion_problem,
)
from .ordering import kd_tree_ordering, morton_ordering  # noqa: F401
from .dense_ref import (  # noqa: F401
    dense_cholesky, dense_ldlt, blocked_cholesky_left, spectral_norm_est,
    spectral_norm_est_op,
)
