"""Geometric orderings that keep tile ranks low (paper section 6).

The paper orders points with a KD-tree whose plane splits aim to produce
clusters matching the tile size: points in a cluster are sorted along the
largest dimension of the cluster's bounding box and split so the left child
holds ``tile_size * 2^floor(log2(m / tile_size / 2 + ...))`` points -- i.e.
the nearest power-of-two multiple of the tile size to half the cluster. The
leaves then map 1:1 onto tiles. We also provide a Morton (Z-curve) ordering
as an alternative (referenced in the paper's related work).
"""

from __future__ import annotations

import numpy as np


def kd_tree_ordering(points: np.ndarray, tile_size: int) -> np.ndarray:
    """Permutation ordering points into KD-tree leaves of ~tile_size.

    Returns ``perm`` such that ``points[perm]`` is the reordered cloud.
    """
    points = np.asarray(points)
    n = points.shape[0]
    out: list[np.ndarray] = []

    def split(idx: np.ndarray) -> None:
        m = idx.shape[0]
        if m <= tile_size:
            out.append(idx)
            return
        cloud = points[idx]
        widths = cloud.max(axis=0) - cloud.min(axis=0)
        dim = int(np.argmax(widths))
        order = np.argsort(cloud[:, dim], kind="stable")
        # left cluster: tile_size * (power of two closest to m/(2*tile_size))
        half_tiles = max(1, m / (2 * tile_size))
        p2 = 2 ** int(round(np.log2(half_tiles)))
        left = min(m - 1, max(1, p2 * tile_size))
        split(idx[order[:left]])
        split(idx[order[left:]])

    split(np.arange(n))
    return np.concatenate(out)


def morton_ordering(points: np.ndarray, bits: int = 16) -> np.ndarray:
    """Z-order (Morton) curve permutation for d<=3 point clouds."""
    points = np.asarray(points)
    n, d = points.shape
    lo, hi = points.min(axis=0), points.max(axis=0)
    scale = np.where(hi > lo, hi - lo, 1.0)
    q = ((points - lo) / scale * (2**bits - 1)).astype(np.uint64)
    codes = np.zeros(n, np.uint64)
    for bit in range(bits):
        for dim in range(d):
            codes |= ((q[:, dim] >> np.uint64(bit)) & np.uint64(1)) << np.uint64(
                bit * d + dim
            )
    return np.argsort(codes, kind="stable")
