"""TLR matrix-vector products and triangular solves (section 4.4, Alg. 7),
preconditioned CG (section 6.2), log-determinant and MVN sampling.

Every read path here dispatches through the :class:`~.batching.TilePlan`
execution-plan layer (DESIGN.md section 9). The matvec marshals off-diagonal
tiles into batched two-product chains ``U (V^T x)`` plus a segment reduction
-- the paper's "independent sets of products stored in output buffers
followed by a reduction" -- either as one flat r_max-wide batch
(``batching="flat"``) or per rank bucket at each bucket's ladder width
(``batching="ranked"``); ``batching="auto"`` (the default) lets the plan's
rank histogram decide.

The triangular solve is a jitted, bucket-laddered blocked TRSM: each column
step (diagonal solve + batched low-rank update of the remaining blocks) runs
inside one jitted executable whose row-batch operands are zero-padded up to
the power-of-two bucket ladder of DESIGN.md section 2, so ~log2(nb) compiled
variants serve all nb columns -- the same shape-stable treatment the
factorization's column pipeline got in PR 1, now applied to the solve phase
(the HODLR GPU solvers of arXiv 2208.06290 batch their solves the same way).
Under ranked batching the column step additionally slices its U/V gathers to
the column's plan width: one ladder width per row-bucket interval, so the
jit cache still grows *additively* (ladder length per direction, exactly the
flat path's contract -- the same additive-cache discipline as the ranked
left-looking driver's running ``wL``). Right-hand sides may be single
vectors ``(n,)`` or batched ``(n, m)``.
"""

from __future__ import annotations

import warnings
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from .batching import resolve_batching, tile_plan
from .buckets import _bucket_ladder, _bucket_up, trace_count, trace_event
from .. import obs
from .tlr import TLRMatrix, tril_pairs, tril_index


# -- symmetric TLR matvec ------------------------------------------------------


@partial(jax.jit, static_argnums=(5,))
def _sym_matvec(D, U, V, ranks, xb, nb: int):
    pairs = tril_pairs(nb)
    rows = jnp.asarray(pairs[:, 0], jnp.int32)
    cols = jnp.asarray(pairs[:, 1], jnp.int32)
    yb = jnp.einsum("kbc,kc...->kb...", D, xb)
    xj = jnp.take(xb, cols, axis=0)
    xi = jnp.take(xb, rows, axis=0)
    # lower tiles: y_i += U (V^T x_j);   mirrored upper: y_j += V (U^T x_i)
    ylo = jnp.einsum("tbr,tr...->tb...", U, jnp.einsum("tbr,tb...->tr...", V, xj))
    yup = jnp.einsum("tbr,tr...->tb...", V, jnp.einsum("tbr,tb...->tr...", U, xi))
    yb = yb.at[rows].add(ylo)
    yb = yb.at[cols].add(yup)
    return yb


# -- rank-bucketed read-path cores (TilePlan consumers; DESIGN.md section 9) ---

# The per-bucket two-product chains compile one variant per (bucket-padded
# count, bucket width, rhs shape) -- both padded up their ladders, so the
# count stays O(log nt * log r_max) per shape family. Registered under the
# "plan" key of the unified trace registry (tests/test_plans.py pins it).


@partial(jax.jit, static_argnames=("w",))
def _plan_chain(U, V, xb, yb, idx, src, dst, valid, *, w: int):
    """One rank bucket of a one-sided product: ``y[dst] += U (V^T x[src])``
    at the bucket's ladder width ``w`` (exact: factor columns past each
    tile's rank are zero). Padded slots gather tile 0 / block 0 and are
    masked to an exact zero before the segment reduction."""
    trace_event("plan")
    Ut = jnp.take(U, idx, axis=0)[:, :, :w]
    Vt = jnp.take(V, idx, axis=0)[:, :, :w]
    xs = jnp.take(xb, src, axis=0)
    y = jnp.einsum("tbr,tr...->tb...", Ut,
                   jnp.einsum("tbr,tb...->tr...", Vt, xs))
    m = valid.reshape((-1,) + (1,) * (y.ndim - 1))
    return yb.at[dst].add(jnp.where(m, y, jnp.zeros_like(y)))


@partial(jax.jit, static_argnames=("w",))
def _plan_chain_sym(U, V, xb, yb, idx, rows, cols, valid, *, w: int):
    """One rank bucket of the symmetric product: both the lower chain
    ``y_i += U (V^T x_j)`` and its mirrored upper ``y_j += V (U^T x_i)``
    share a single gather of the bucket's factors."""
    trace_event("plan")
    Ut = jnp.take(U, idx, axis=0)[:, :, :w]
    Vt = jnp.take(V, idx, axis=0)[:, :, :w]
    xj = jnp.take(xb, cols, axis=0)
    xi = jnp.take(xb, rows, axis=0)
    ylo = jnp.einsum("tbr,tr...->tb...", Ut,
                     jnp.einsum("tbr,tb...->tr...", Vt, xj))
    yup = jnp.einsum("tbr,tr...->tb...", Vt,
                     jnp.einsum("tbr,tb...->tr...", Ut, xi))
    m = valid.reshape((-1,) + (1,) * (ylo.ndim - 1))
    yb = yb.at[rows].add(jnp.where(m, ylo, jnp.zeros_like(ylo)))
    return yb.at[cols].add(jnp.where(m, yup, jnp.zeros_like(yup)))


def _bucket_index_arrays(bk, *gathers):
    """Pad a bucket's gather/scatter index vectors to its count-ladder slot
    count, plus the valid mask (padded slots point at index 0, masked)."""
    out = []
    for g in gathers:
        full = np.zeros(bk.padded, np.int32)
        full[:bk.count] = g
        out.append(jnp.asarray(full))
    valid = np.zeros(bk.padded, bool)
    valid[:bk.count] = True
    out.append(jnp.asarray(valid))
    return out


def _plan_gathers(plan, nb: int):
    """Per-bucket padded ``(idx, rows, cols, valid)`` device arrays.

    Memoized on the plan object itself: plans are memoized on the ranks
    array (one per factor generation), so the index uploads and padding
    happen once, not once per matvec/tri_matvec call. Stable array
    identities also keep the jitted chain cores hitting the same donated
    buffers across calls."""
    cache = plan.__dict__.get("_gather_cache")
    if cache is None:
        pairs = tril_pairs(nb)
        cache = [tuple(_bucket_index_arrays(
                     bk, bk.idx, pairs[bk.idx, 0], pairs[bk.idx, 1]))
                 for bk in plan.buckets]
        object.__setattr__(plan, "_gather_cache", cache)
    return cache


def tlr_matvec(A: TLRMatrix, x: jax.Array, *,
               batching: str | None = "auto") -> jax.Array:
    """y = A @ x for symmetric TLR A; x is (n,) or (n, m).

    ``batching="ranked"`` runs the two-product chains per rank bucket of
    the memoized :func:`~.batching.tile_plan` (each bucket at its own
    ladder width, rank-0 tiles skipped); ``"flat"`` is the single
    r_max-wide batch; ``"auto"`` (default) applies the rank-histogram
    policy (DESIGN.md section 9).
    """
    nb, b = A.nb, A.b
    xb = x.reshape(nb, b, *x.shape[1:])
    mode = resolve_batching(batching, A.ranks, A.r_max)
    if mode == "ranked":
        plan = tile_plan(A.ranks, A.r_max)
        yb = jnp.einsum("kbc,kc...->kb...", A.D, xb)
        for bk, (idx, rows, cols, valid) in zip(plan.buckets,
                                                _plan_gathers(plan, nb)):
            attrs = {}
            if obs.enabled():
                # Symmetric chain: both orientations per tile, 2 GEMMs each.
                attrs = _chain_span_attrs(plan, bk, b, xb, sym=True)
            with obs.span("matvec.bucket", cat="solve", **attrs):
                yb = _plan_chain_sym(A.U, A.V, xb, yb, idx, rows, cols,
                                     valid, w=bk.width)
    else:
        yb = _sym_matvec(A.D, A.U, A.V, A.ranks, xb, nb)
    return yb.reshape(x.shape)


def _chain_span_attrs(plan, bk, b: int, xb, sym: bool) -> dict:
    """Telemetry attributes for one bucket of a two-product read chain
    (enabled mode only): ``2 * 2*b*w*m`` FLOPs per dispatched tile-product
    slot (V^T x then U y, ``m`` rhs columns; doubled again for the
    symmetric chain's mirrored product), useful scaled by true rank mass."""
    m = 1
    for d in xb.shape[2:]:
        m *= int(d)
    per_col = (8 if sym else 4) * b * m
    return {"width": bk.width, "count": bk.count, "padded": bk.padded,
            "flops": float(per_col) * float(plan.ranks_host[bk.idx].sum()),
            "flops_padded": float(per_col) * float(bk.padded * bk.width)}


# -- lower-triangular TLR products / solves -------------------------------------


def tlr_tri_matvec(L: TLRMatrix, x: jax.Array, *, trans: bool = False,
                   batching: str | None = "auto") -> jax.Array:
    """y = L @ x (or L^T @ x) for lower-triangular TLR L. Same ``batching``
    dispatch as :func:`tlr_matvec` (the transposed product swaps the U/V
    roles inside each bucket chain)."""
    nb, b = L.nb, L.b
    xb = x.reshape(nb, b, *x.shape[1:])
    pairs = tril_pairs(nb)
    mode = resolve_batching(batching, L.ranks, L.r_max)
    if mode == "ranked":
        plan = tile_plan(L.ranks, L.r_max)
        if not trans:
            yb = jnp.einsum("kbc,kc...->kb...", L.D, xb)
        else:
            yb = jnp.einsum("kcb,kc...->kb...", L.D, xb)
        for bk, (idx, rows, cols, valid) in zip(plan.buckets,
                                                _plan_gathers(plan, nb)):
            attrs = {}
            if obs.enabled():
                attrs = _chain_span_attrs(plan, bk, b, xb, sym=False)
            with obs.span("tri_matvec.bucket", cat="solve", **attrs):
                if not trans:
                    yb = _plan_chain(L.U, L.V, xb, yb, idx, cols, rows,
                                     valid, w=bk.width)
                else:
                    # (L^T)(j,i) = L(i,j)^T = V U^T: swap the factor roles.
                    yb = _plan_chain(L.V, L.U, xb, yb, idx, rows, cols,
                                     valid, w=bk.width)
        return yb.reshape(x.shape)
    rows = jnp.asarray(pairs[:, 0], jnp.int32)
    cols = jnp.asarray(pairs[:, 1], jnp.int32)
    if not trans:
        yb = jnp.einsum("kbc,kc...->kb...", L.D, xb)
        xj = jnp.take(xb, cols, axis=0)
        ylo = jnp.einsum("tbr,tr...->tb...", L.U,
                         jnp.einsum("tbr,tb...->tr...", L.V, xj))
        yb = yb.at[rows].add(ylo)
    else:
        yb = jnp.einsum("kcb,kc...->kb...", L.D, xb)
        xi = jnp.take(xb, rows, axis=0)
        yup = jnp.einsum("tbr,tr...->tb...", L.V,
                         jnp.einsum("tbr,tb...->tr...", L.U, xi))
        yb = yb.at[cols].add(yup)
    return yb.reshape(x.shape)


# -- jitted bucketed blocked TRSM ----------------------------------------------

# One entry per freshly compiled column-step variant, under the "trsm" key
# of the unified registry (core/buckets.py); the python body of the jitted
# step runs exactly once per compile, so this is a real compile count (the
# contract tests/test_trsm.py pins, mirroring ``stats["column_traces"]`` in
# the factorization).


def trsm_trace_count() -> int:
    """Compiled TRSM column-step variants so far (process-wide); a view of
    ``trace_count("trsm")`` in the unified registry."""
    return trace_count("trsm")


@partial(jax.jit, static_argnames=("trans", "w"))
def _trsm_step(D, U, V, xb, k, tidx, ridx, valid, *, trans: bool, w: int):
    """One blocked-TRSM column: solve the diagonal block, update the rest.

    Operands: the factor's full (static-shape) D/U/V buffers plus small
    per-column index vectors. ``tidx`` selects the Tb (bucket-padded) tiles
    of column k, ``ridx`` the block rows they update; padded slots carry
    ``valid=False`` and a zero update, so the scatter-add is inert there
    (padded ``ridx`` entries point at block 0 and add exact zeros).

    ``w`` is the column's plan width (a rank-ladder value covering every
    rank this step touches; ``r_max`` on the flat path): the U/V gathers
    slice to it, so XLA fuses a narrow gather and the update chain runs at
    the bucketed width -- exact, because factor columns past each tile's
    rank are zero. One width is shared per row-bucket interval, so the jit
    cache stays one variant per (Tb, direction): additive, never the
    T-ladder x width-ladder product.
    """
    trace_event("trsm")
    Dk = jax.lax.dynamic_index_in_dim(D, k, keepdims=False)
    yk = jax.lax.dynamic_index_in_dim(xb, k, keepdims=False)
    Ut = jnp.take(U, tidx, axis=0)[:, :, :w]
    Vt = jnp.take(V, tidx, axis=0)[:, :, :w]
    if trans:
        # (L^T)(j,k) = L(k,j)^T = V U^T: the U/V roles swap in the update.
        Dk = Dk.T
        Ut, Vt = Vt, Ut
    xk = jax.scipy.linalg.solve_triangular(Dk, yk, lower=not trans)
    upd = jnp.einsum("tbr,trm->tbm", Ut, jnp.einsum("tbr,bm->trm", Vt, xk))
    upd = jnp.where(valid[:, None, None], upd, jnp.zeros_like(upd))
    xb = jax.lax.dynamic_update_index_in_dim(xb, xk, k, axis=0)
    return xb.at[ridx].add(-upd)


def _trsv_column_tiles(nb: int, k: int, trans: bool):
    """Packed tile indices and target block rows of solve column ``k``."""
    if not trans:
        tgt = np.arange(k + 1, nb)
        tiles = tgt * (tgt - 1) // 2 + k              # tril_index(i, k)
    else:
        tgt = np.arange(k)
        tiles = k * (k - 1) // 2 + tgt                # tril_index(k, j)
    return tiles, tgt


def _trsv_bucket_widths(plan, nb: int, trans: bool, ladder) -> dict[int, int]:
    """One plan width per row-bucket interval: the ladder width covering
    every rank any column in that Tb bucket touches. Sharing one width per
    interval (instead of one per column) keeps the jit cache additive --
    at most one (Tb, w) executable per ladder entry and direction, the same
    contract as the flat path -- while narrow intervals (the trailing
    columns of the forward sweep, the leading ones of the backward) still
    run at their own narrow widths.

    Memoized on the plan object (like ``_plan_gathers``): the plan is one
    per factor generation, so a server solving against a resident
    factorization every tick pays the nb-column sweep once, not per call."""
    cache = plan.__dict__.get("_trsv_width_cache")
    if cache is None:
        cache = {}
        object.__setattr__(plan, "_trsv_width_cache", cache)
    hit = cache.get((nb, trans))
    if hit is not None:
        return hit
    widths: dict[int, int] = {}
    for k in range(nb):
        tiles, tgt = _trsv_column_tiles(nb, k, trans)
        Tb = _bucket_up(max(len(tgt), 1), ladder)
        cw = int(plan.widths[tiles].max(initial=0)) if len(tiles) else 0
        widths[Tb] = max(widths.get(Tb, 1), cw, 1)
    cap = max(int(plan.cap), 1)
    out = {Tb: min(w, cap) for Tb, w in widths.items()}
    cache[(nb, trans)] = out
    return out


@lru_cache(maxsize=64)
def _trsv_column_steps(nb: int, trans: bool):
    """Host marshaling of a whole TRSM sweep, memoized per (nb, direction):
    for each column k in sweep order, the bucket-padded index operands of
    its jitted step as *device* arrays -- ``(Tb, k_dev, tidx, ridx,
    valid)``. Uploading these once per (nb, trans) instead of per call
    removes the per-column host packing + transfer from the solve hot path
    (a serving tick runs four sweeps per batch), and the stable array
    identities keep the jitted steps hitting the same buffers."""
    ladder = _bucket_ladder(nb - 1)
    order = range(nb) if not trans else range(nb - 1, -1, -1)
    steps = []
    for k in order:
        tiles, tgt = _trsv_column_tiles(nb, k, trans)
        T = len(tgt)
        Tb = _bucket_up(max(T, 1), ladder)
        tidx = np.zeros(Tb, np.int32)
        ridx = np.zeros(Tb, np.int32)
        tidx[:T], ridx[:T] = tiles, tgt
        valid = np.zeros(Tb, bool)
        valid[:T] = True
        steps.append((Tb, jnp.asarray(k, jnp.int32), jnp.asarray(tidx),
                      jnp.asarray(ridx), jnp.asarray(valid)))
    return tuple(steps)


def tlr_trsv(L: TLRMatrix, y: jax.Array, *, trans: bool = False,
             batching: str | None = "auto") -> jax.Array:
    """Solve L x = y (trans=False) or L^T x = y (trans=True). Algorithm 7.

    Right-looking blocked TRSM: after each diagonal solve, the solution
    block updates all remaining blocks through the batched two-product
    chain, inside a jitted bucket-laddered column step (~log2(nb) compiled
    variants instead of a host loop over per-block lists). ``y`` is a single
    right-hand side ``(n,)`` or a batch ``(n, m)``.

    ``batching="ranked"`` slices each column step's U/V gathers to the
    column's plan width from the factor's memoized
    :func:`~.batching.tile_plan` (see :func:`_trsv_bucket_widths` for the
    additive jit-cache contract); ``"flat"`` runs every step r_max-wide;
    ``"auto"`` (default) applies the rank-histogram policy.
    """
    nb, b = L.nb, L.b
    xb = y.reshape(nb, b, -1)
    if nb == 1:
        Dk = L.D[0].T if trans else L.D[0]
        x = jax.scipy.linalg.solve_triangular(Dk, xb[0], lower=not trans)
        return x.reshape(y.shape)
    mode = resolve_batching(batching, L.ranks, L.r_max)
    ladder = _bucket_ladder(nb - 1)
    if mode == "ranked":
        plan = tile_plan(L.ranks, L.r_max)
        bucket_w = _trsv_bucket_widths(plan, nb, trans, ladder)
    else:
        bucket_w = None
    sweep_attrs = {"nb": nb, "trans": trans, "mode": mode} \
        if obs.enabled() else {}
    with obs.span("trsm.sweep", cat="solve", **sweep_attrs):
        for Tb, k_dev, tidx, ridx, valid in _trsv_column_steps(nb, trans):
            w = bucket_w[Tb] if bucket_w is not None else L.r_max
            # Column steps dispatch asynchronously, so each child span
            # times the launch, not the device work; the sweep span's
            # TraceAnnotation carries the device alignment.
            with obs.span("trsm.column", cat="solve", Tb=Tb, w=w):
                xb = _trsm_step(L.D, L.U, L.V, xb, k_dev, tidx, ridx,
                                valid, trans=trans, w=w)
    return xb.reshape(y.shape)


def tlr_trsv_reference(L: TLRMatrix, y: jax.Array, *,
                       trans: bool = False) -> jax.Array:
    """Pre-PR-2 host-loop TRSV, kept as the parity oracle for the jitted
    bucketed TRSM (tests/test_trsm.py; benchmarks/bench_tlr.py --suite
    solve). Same math, un-jitted python loop over per-block lists."""
    nb, b = L.nb, L.b
    xb = [y.reshape(nb, b, *y.shape[1:])[i] for i in range(nb)]
    order = range(nb) if not trans else range(nb - 1, -1, -1)
    for k in order:
        Dk = L.D[k] if not trans else L.D[k].T
        xk = jax.scipy.linalg.solve_triangular(Dk, xb[k], lower=not trans)
        xb[k] = xk
        if not trans:
            idx = [tril_index(i, k) for i in range(k + 1, nb)]
            if idx:
                ii = jnp.asarray(idx, jnp.int32)
                Ut, Vt = jnp.take(L.U, ii, axis=0), jnp.take(L.V, ii, axis=0)
                upd = jnp.einsum("tbr,tr...->tb...", Ut,
                                 jnp.einsum("tbr,b...->tr...", Vt, xk))
                for t, i in enumerate(range(k + 1, nb)):
                    xb[i] = xb[i] - upd[t]
        else:
            idx = [tril_index(k, j) for j in range(k)]
            if idx:
                ii = jnp.asarray(idx, jnp.int32)
                Ut, Vt = jnp.take(L.U, ii, axis=0), jnp.take(L.V, ii, axis=0)
                # (L^T)(j,k) = L(k,j)^T = V U^T
                upd = jnp.einsum("tbr,tr...->tb...", Vt,
                                 jnp.einsum("tbr,b...->tr...", Ut, xk))
                for t, j in enumerate(range(k)):
                    xb[j] = xb[j] - upd[t]
    return jnp.stack(xb).reshape(y.shape)


def tile_perm_to_element_perm(perm: np.ndarray, b: int) -> np.ndarray:
    return (np.asarray(perm)[:, None] * b + np.arange(b)[None, :]).reshape(-1)


# -- factorization application (implementations behind the handle methods) ----


def _permute_rows(x: jax.Array, eperm: np.ndarray) -> jax.Array:
    """Gather rows by the element permutation; one code path for single
    vectors (n,) and batched right-hand sides (n, m)."""
    return x[eperm]


def _unpermute_rows(x: jax.Array, eperm: np.ndarray) -> jax.Array:
    """Scatter rows back through the inverse permutation (the dual of
    :func:`_permute_rows`, same ndim-agnostic contract)."""
    return jnp.zeros_like(x).at[eperm].set(x)


def _factor_solve_impl(fact, y: jax.Array) -> jax.Array:
    """Solve A x = y given a TLRFactorization (handles perm and LDL)."""
    eperm = tile_perm_to_element_perm(fact.perm, fact.L.b)
    z = tlr_trsv(fact.L, _permute_rows(y, eperm), trans=False)
    if fact.d is not None:
        dflat = fact.d.reshape(-1)
        z = z / dflat.reshape((-1,) + (1,) * (z.ndim - 1))
    z = tlr_trsv(fact.L, z, trans=True)
    return _unpermute_rows(z, eperm)


def _logdet_impl(fact) -> jax.Array:
    """log |det A| from the factorization diagonals.

    One batched ``jnp.diagonal`` over the (nb, b, b) diagonal-tile stack --
    the per-tile ``jnp.diag`` host loop this replaces dispatched nb tiny
    ops per call.
    """
    if fact.d is not None:
        diag_ld = jnp.sum(jnp.log(jnp.abs(fact.d)))
        return diag_ld
    diags = jnp.diagonal(fact.L.D, axis1=1, axis2=2)
    return 2.0 * jnp.sum(jnp.log(jnp.abs(diags)))


def _mvn_sample_impl(fact, key, num: int = 1) -> jax.Array:
    """Sample x ~ N(0, A) via x = P^T L z (Cholesky factorizations only)."""
    if fact.d is not None:
        raise ValueError("MVN sampling requires a Cholesky factorization")
    n = fact.L.n
    z = jax.random.normal(key, (n, num), fact.L.dtype)
    x = tlr_tri_matvec(fact.L, z)
    eperm = tile_perm_to_element_perm(fact.perm, fact.L.b)
    out = _unpermute_rows(x, eperm)
    return out[:, 0] if num == 1 else out


def _deprecated(old: str, new: str) -> None:
    # FutureWarning, not DeprecationWarning: the default warning filters
    # silence DeprecationWarning outside __main__, and remaining shims
    # (``tlr.from_dense``) are the user-facing migration signal for the one
    # release they survive.
    warnings.warn(f"{old} is deprecated; use {new} (DESIGN.md section 5)",
                  FutureWarning, stacklevel=3)


# -- preconditioned conjugate gradients -----------------------------------------


def _as_matvec(op):
    """Coerce an operator argument to a matvec callable: a bare callable,
    or any object with a ``.matvec`` (TLROperator; TLRFactorization, whose
    operator action is A^{-1})."""
    if op is None:
        return None
    if callable(op) and not hasattr(op, "matvec"):
        return op
    mv = getattr(op, "matvec", None)
    if mv is not None:
        return mv
    raise TypeError(
        f"expected a callable or an object with .matvec, got {type(op)!r}")


class PCGHistory(list):
    """Relative-residual history: a plain ``list`` of floats (so existing
    ``hist[-1]`` / iteration callers keep working) carrying breakdown
    diagnostics. ``breakdown`` is None on a clean run, or the condition
    that stopped the iteration early:

    * ``"indefinite_curvature"``      -- p^T A p <= 0 (A not SPD),
    * ``"indefinite_preconditioner"`` -- r^T M^{-1} r <= 0 (M not SPD),
    * ``"nonfinite"``                 -- a NaN/Inf appeared in the recurrence.

    On breakdown PCG returns the last finite iterate instead of silently
    flooding x and the history with NaNs for the remaining iterations.
    """

    def __init__(self, *args):
        super().__init__(*args)
        self.breakdown: str | None = None


def pcg(A, b_rhs: jax.Array, *, precond=None, tol=1e-6,
        maxiter: int = 300, check_every: int = 1):
    """PCG with relative residual ||Ax-b||/||b|| stopping (paper section 6.2).

    ``A`` and ``precond`` are callables ``v -> Av`` (resp. ``r -> M^{-1}r``)
    or any object with a ``.matvec`` -- a ``TLROperator``, or a
    ``TLRFactorization`` used directly as the preconditioner. Host-driven
    loop; returns (x, iterations, history), where ``history`` is a
    :class:`PCGHistory` whose ``breakdown`` attribute records an
    indefinite-operator / indefinite-preconditioner / non-finite breakdown
    (the iteration stops at the last finite iterate instead of spinning to
    ``maxiter`` on NaNs). A zero right-hand side returns x = 0 immediately
    with an empty history.

    A batched right-hand side ``(n, k)`` runs *per-column* CG through
    :class:`BatchedPCG`: every column carries its own alpha/beta recurrence,
    its own tolerance (``tol`` may be an ``(k,)`` array), and a per-column
    convergence mask, so one slow column never stalls the block -- converged
    columns freeze in place while the rest keep iterating (the serving-side
    mirror of the paper's Algorithm 5 eviction). The batched form returns
    ``(X, iters, histories)`` with ``iters`` an ``(k,)`` int array and
    ``histories`` a list of per-column :class:`PCGHistory`.

    ``check_every`` batches the convergence/breakdown checks: the recurrence
    runs ``check_every`` iterations on device, then one host sync pulls that
    window's scalars (``p^T A p``, ``||r||``, ``r^T z``) together instead of
    three blocking ``float(...)`` round trips per iteration. The device-side
    op sequence per iteration is identical for every ``check_every``, so the
    iterate history is bit-for-bit the same as ``check_every=1`` (pinned by
    tests/test_plans.py); a window that trips a check mid-way is replayed
    from its start up to the event, reproducing the exact per-iteration
    stopping semantics (at most one extra partial window of recompute, only
    on the final window). The window is always clamped to the iterations
    remaining, so ``maxiter`` need not be a multiple of ``check_every``.
    """
    if jnp.ndim(b_rhs) >= 2:
        return _pcg_batched(A, jnp.asarray(b_rhs), precond=precond, tol=tol,
                            maxiter=maxiter, check_every=check_every)
    tol = float(tol)
    matvec = _as_matvec(A)
    precond = _as_matvec(precond)
    check_every = max(1, int(check_every))
    bnorm = float(jnp.linalg.norm(b_rhs))
    if bnorm == 0.0:
        return jnp.zeros_like(b_rhs), 0, PCGHistory()
    x = jnp.zeros_like(b_rhs)
    r = b_rhs - matvec(x)
    z = precond(r) if precond else r
    p_dir = z
    rz = jnp.vdot(r, z)
    history = PCGHistory([float(jnp.linalg.norm(r)) / bnorm])
    rz_f = float(rz)
    if not np.isfinite(rz_f) or rz_f <= 0.0:
        history.breakdown = ("nonfinite" if not np.isfinite(rz_f)
                             else "indefinite_preconditioner")
        return x, 0, history

    def step(x, r, p_dir, rz):
        """One CG iteration; returns the new state and the (lazy, device)
        check scalars. Same op order as the classic per-iteration loop, so
        every intermediate is bitwise independent of ``check_every``."""
        Ap = matvec(p_dir)
        pAp = jnp.vdot(p_dir, Ap)
        alpha = rz / pAp
        x_new = x + alpha * p_dir
        r_new = r - alpha * Ap
        rnorm = jnp.linalg.norm(r_new)
        z = precond(r_new) if precond else r_new
        rz_new = jnp.vdot(r_new, z)
        beta = rz_new / rz
        p_new = z + beta * p_dir
        return (x_new, r_new, p_new, rz_new), (pAp, rnorm, rz_new)

    it = 0
    state = (x, r, p_dir, rz)
    done = False
    while it < maxiter and not done:
        steps = min(check_every, maxiter - it)
        start = state
        scalars = []
        st = state
        for _ in range(steps):
            st, sc = step(*st)
            scalars.append(sc)
        # One host sync for the whole window.
        vals = np.asarray(jnp.stack([jnp.stack(sc) for sc in scalars]))
        accepted = 0
        for s in range(steps):
            pAp, rnorm_raw, rz_new = (float(v) for v in vals[s])
            if not np.isfinite(pAp) or pAp <= 0.0:
                history.breakdown = ("nonfinite" if not np.isfinite(pAp)
                                     else "indefinite_curvature")
                done = True
                break                       # iterate s discarded
            rnorm = rnorm_raw / bnorm
            if not np.isfinite(rnorm):
                history.breakdown = "nonfinite"
                done = True
                break                       # iterate s discarded
            accepted = s + 1
            it += 1
            history.append(rnorm)
            if rnorm < tol:
                done = True
                break
            if not np.isfinite(rz_new) or rz_new <= 0.0:
                history.breakdown = ("nonfinite" if not np.isfinite(rz_new)
                                     else "indefinite_preconditioner")
                done = True
                break                       # iterate s kept
        if accepted == steps:
            state = st
        else:
            # Replay the window up to the last accepted iterate: the same
            # jax ops from the same inputs reproduce it exactly.
            st = start
            for _ in range(accepted):
                st, _ = step(*st)
            state = st
    return state[0], it, history


# -- batched-RHS PCG with per-column convergence masks --------------------------


def _pcg_block_step(matvec, precond, X, R, P, RZ, act):
    """One batched CG iteration over an ``(n, k)`` block with per-column
    alpha/beta and a per-column active mask.

    Columns are fully independent: the matvec applies the operator to each
    column separately (matrix products mix rows, never columns), and every
    other op is columnwise, so masking a column freezes it *exactly* --
    active columns compute bit-for-bit the same values whether their
    neighbors are frozen or not. Frozen columns keep their old state through
    explicit ``where`` selects (their lanes may compute garbage, including
    NaN from a broken-down neighbor iterate; the select discards it)."""
    AP = matvec(P)
    pAp = jnp.sum(P * AP, axis=0)
    alpha = jnp.where(act, RZ / jnp.where(pAp != 0, pAp, 1.0), 0.0)
    Xn = jnp.where(act, X + alpha[None, :] * P, X)
    Rn = jnp.where(act, R - alpha[None, :] * AP, R)
    rnorm = jnp.linalg.norm(Rn, axis=0)
    Z = precond(Rn) if precond else Rn
    RZn = jnp.sum(Rn * Z, axis=0)
    beta = jnp.where(act, RZn / jnp.where(RZ != 0, RZ, 1.0), 0.0)
    Pn = jnp.where(act, Z + beta[None, :] * P, P)
    RZk = jnp.where(act, RZn, RZ)
    return (Xn, Rn, Pn, RZk), (pAp, rnorm, RZn)


class BatchedPCG:
    """Incremental batched-RHS PCG over a fixed-width column block.

    The engine holds ``width`` right-hand-side *slots* of length ``n``.
    Columns are loaded with :meth:`load` (each with its own tolerance and
    iteration budget), advanced together in windows of ``check_every``
    device iterations by :meth:`advance`, and leave the block the moment
    they converge, break down, or exhaust their budget -- a per-column
    convergence mask freezes finished columns in place while the rest keep
    iterating, so shapes never change and one slow column cannot stall the
    block. This is the iterative-solve mirror of the paper's Algorithm 5
    subset marshaling (and the engine the ``TLRServer`` ticks drive).

    Per-iteration stopping semantics are exact: after each window one host
    sync pulls the window's per-column scalars, each column's stopping
    iteration is located host-side, and if any column stopped mid-window
    the window is replayed with per-step masks -- columns that ran the full
    window reproduce their no-replay state bit-for-bit (column
    independence), stopped columns freeze at exactly their last accepted
    iterate, matching the scalar :func:`pcg` contract per column. The
    window length never depends on per-column budgets, so the compiled
    step-shape set is fixed after the first window (the serve-path
    no-recompile pin rides on this).

    Statuses: ``"idle"`` (slot empty), ``"active"`` (iterating), ``"done"``
    (finished, result waiting for :meth:`evict`).
    """

    def __init__(self, A, n: int, width: int, *, precond=None,
                 maxiter: int = 300, check_every: int = 8,
                 dtype=None):
        self.matvec = _as_matvec(A)
        self.precond = _as_matvec(precond)
        self.n, self.width = int(n), int(width)
        self.check_every = max(1, int(check_every))
        self.default_maxiter = int(maxiter)
        self.dtype = jnp.dtype(dtype) if dtype is not None else (
            jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
        self._reset_state()

    def _reset_state(self):
        n, w = self.n, self.width
        self.X = jnp.zeros((n, w), self.dtype)
        self.R = jnp.zeros((n, w), self.dtype)
        self.P = jnp.zeros((n, w), self.dtype)
        self.RZ = jnp.zeros((w,), self.dtype)
        self.act = np.zeros(w, bool)
        self.status = ["idle"] * w
        self.converged = np.zeros(w, bool)
        self.bnorm = np.zeros(w)
        self.tol = np.full(w, 1e-6)
        self.maxiter = np.full(w, self.default_maxiter, np.int64)
        self.iters = np.zeros(w, np.int64)
        self.hist: list[PCGHistory] = [PCGHistory() for _ in range(w)]
        self._pending: dict[int, np.ndarray] = {}

    def reset(self):
        """Clear every slot (used after a warmup pass -- the compiled
        executables survive, the state does not)."""
        self._reset_state()

    # -- slot lifecycle ----------------------------------------------------

    def load(self, j: int, b_col, *, tol: float = 1e-6,
             maxiter: int | None = None) -> None:
        """Stage right-hand side ``b_col`` into column ``j``. The device
        write happens at the next :meth:`advance` as one masked block
        update over all staged columns (no per-column-index executables)."""
        j = int(j)
        if self.status[j] == "active":
            raise ValueError(f"column {j} is still active; evict it first")
        col = np.asarray(b_col, np.dtype(self.dtype)).reshape(-1)
        if col.shape[0] != self.n:
            raise ValueError(
                f"rhs length {col.shape[0]} != operator size {self.n}")
        self.hist[j] = PCGHistory()
        self.iters[j] = 0
        self.converged[j] = False
        self.act[j] = False
        self.tol[j] = float(tol)
        self.maxiter[j] = int(maxiter if maxiter is not None
                              else self.default_maxiter)
        self.bnorm[j] = float(np.linalg.norm(col))
        if self.bnorm[j] == 0.0:
            # x = 0 exactly; empty history, converged (scalar-pcg contract)
            self._pending.pop(j, None)
            self.status[j] = "done"
            self.converged[j] = True
            return
        self.status[j] = "pending"
        self._pending[j] = col

    def evict(self, j: int) -> tuple[np.ndarray, int, PCGHistory, bool]:
        """Pull column ``j``'s result and free the slot. Returns
        ``(x, iterations, history, converged)``."""
        j = int(j)
        if self.status[j] != "done":
            raise ValueError(f"column {j} is {self.status[j]!r}, not done")
        x = np.asarray(self.X[:, j])
        out = (x, int(self.iters[j]), self.hist[j], bool(self.converged[j]))
        self.status[j] = "idle"
        self.act[j] = False
        return out

    def cancel(self, j: int) -> None:
        """Abandon column ``j`` in whatever state it is in and free the
        slot (timeout eviction: the serve loop drops a column whose
        deadline passed without waiting for convergence). The device
        iterate keeps running the stale column until the mask next
        rebuilds -- harmless, it is never read."""
        j = int(j)
        self.status[j] = "idle"
        self.act[j] = False
        self._pending.pop(j, None)

    def solution(self) -> jax.Array:
        """The current iterate block (device, ``(n, width)``)."""
        return self.X

    @property
    def active_columns(self) -> list[int]:
        return [j for j, s in enumerate(self.status)
                if s in ("active", "pending")]

    @property
    def done_columns(self) -> list[int]:
        return [j for j, s in enumerate(self.status) if s == "done"]

    # -- the window --------------------------------------------------------

    def _flush_pending(self) -> list[int]:
        """Materialize staged columns: one masked block write (x=0, r=b),
        one batched preconditioner application for p/rz, and the per-column
        initial-residual bookkeeping. Returns columns that finished at
        init (rz <= 0 / non-finite: immediate breakdown)."""
        if not self._pending:
            return []
        cols = sorted(self._pending)
        B = np.zeros((self.n, self.width), np.dtype(self.dtype))
        sel = np.zeros(self.width, bool)
        for j in cols:
            B[:, j] = self._pending[j]
            sel[j] = True
        Bj = jnp.asarray(B)
        mj = jnp.asarray(sel)
        zero = jnp.zeros((), self.dtype)
        self.R = jnp.where(mj[None, :], Bj, self.R)
        self.X = jnp.where(mj[None, :], zero, self.X)
        Z = self.precond(self.R) if self.precond else self.R
        RZ_all = jnp.sum(self.R * Z, axis=0)
        self.P = jnp.where(mj[None, :], Z, self.P)
        self.RZ = jnp.where(mj, RZ_all, self.RZ)
        rz_host = np.asarray(RZ_all)[cols]
        finished = []
        for j, rz in zip(cols, rz_host):
            rz = float(rz)
            self.hist[j].append(1.0)      # ||r||/||b|| = 1 at x = 0
            if not np.isfinite(rz) or rz <= 0.0:
                self.hist[j].breakdown = (
                    "nonfinite" if not np.isfinite(rz)
                    else "indefinite_preconditioner")
                self.status[j] = "done"
                finished.append(j)
            else:
                self.status[j] = "active"
                self.act[j] = True
        self._pending.clear()
        return finished

    def _scan_column(self, j: int, vals: np.ndarray, steps: int) -> int:
        """Walk column ``j`` through the window's pulled scalars, applying
        the scalar-pcg acceptance rules; returns the number of accepted
        iterates (== ``steps`` when the column ran the whole window)."""
        accepted = 0
        for s in range(steps):
            pAp, rnorm_raw, rz_new = (float(vals[s, i, j]) for i in range(3))
            if not np.isfinite(pAp) or pAp <= 0.0:
                self.hist[j].breakdown = (
                    "nonfinite" if not np.isfinite(pAp)
                    else "indefinite_curvature")
                return accepted               # iterate s discarded
            rel = rnorm_raw / self.bnorm[j]
            if not np.isfinite(rel):
                self.hist[j].breakdown = "nonfinite"
                return accepted               # iterate s discarded
            accepted = s + 1
            self.iters[j] += 1
            self.hist[j].append(rel)
            if rel < self.tol[j]:
                self.converged[j] = True
                return accepted               # iterate s kept
            if not np.isfinite(rz_new) or rz_new <= 0.0:
                self.hist[j].breakdown = (
                    "nonfinite" if not np.isfinite(rz_new)
                    else "indefinite_preconditioner")
                return accepted               # iterate s kept
            if self.iters[j] >= self.maxiter[j]:
                return accepted               # budget exhausted, no flag
        return accepted

    def advance(self, steps: int | None = None) -> list[int]:
        """Run one window of ``steps`` (default ``check_every``) batched
        iterations, then settle per-column outcomes; returns the columns
        that finished during this call (converged, broke down, or hit
        their iteration budget). Idle/done columns are inert."""
        finished = self._flush_pending()
        act_idx = np.nonzero(self.act)[0]
        if act_idx.size == 0:
            return finished
        steps = max(1, int(steps if steps is not None else self.check_every))
        start = (self.X, self.R, self.P, self.RZ)
        actj = jnp.asarray(self.act)
        st, scal = start, []
        for _ in range(steps):
            st, sc = _pcg_block_step(self.matvec, self.precond, *st, actj)
            scal.append(jnp.stack(sc))
        vals = np.asarray(jnp.stack(scal))    # (steps, 3, width): one sync
        stop_at = np.full(self.width, steps)
        for j in act_idx:
            stop_at[j] = self._scan_column(j, vals, steps)
            if (stop_at[j] < steps or self.converged[j]
                    or self.hist[j].breakdown is not None
                    or self.iters[j] >= self.maxiter[j]):
                self.act[j] = False
                self.status[j] = "done"
                finished.append(int(j))
        if np.all(stop_at[act_idx] == steps):
            # every column accepted the whole window (finishing exactly at
            # its last step is fine -- the state is the accepted iterate)
            self.X, self.R, self.P, self.RZ = st
            return finished
        # Replay with per-step masks: a column accepted ``stop_at[j]``
        # iterates, so it participates in steps 0..stop_at[j]-1 and is
        # frozen after -- the same jax ops from the same inputs reproduce
        # the accepted prefix exactly (column independence makes the
        # surviving columns bitwise identical to the first pass).
        base_act = np.zeros(self.width, bool)
        base_act[act_idx] = True
        st = start
        for s in range(steps):
            mask = jnp.asarray(base_act & (stop_at > s))
            st, _ = _pcg_block_step(self.matvec, self.precond, *st, mask)
        self.X, self.R, self.P, self.RZ = st
        return finished

    def run(self) -> None:
        """Advance until every loaded column is finished."""
        while self.active_columns:
            self.advance()


def _pcg_batched(A, B: jax.Array, *, precond=None, tol=1e-6,
                 maxiter: int = 300, check_every: int = 1):
    """Per-column PCG over an ``(n, k)`` block (the ``pcg`` 2-D path):
    loads every column into a :class:`BatchedPCG` of width k and drains it.
    ``tol`` may be scalar or ``(k,)``. Returns ``(X, iters, histories)``."""
    n, k = B.shape
    tols = np.broadcast_to(np.asarray(tol, np.float64), (k,))
    eng = BatchedPCG(A, n, k, precond=precond, maxiter=maxiter,
                     check_every=check_every, dtype=B.dtype)
    Bh = np.asarray(B)
    for j in range(k):
        eng.load(j, Bh[:, j], tol=float(tols[j]))
    eng.run()
    X = eng.solution()
    return X, eng.iters.copy(), list(eng.hist)
