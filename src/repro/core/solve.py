"""TLR matrix-vector products and triangular solves (section 4.4, Alg. 7),
preconditioned CG (section 6.2), log-determinant and MVN sampling.

The matvec marshals every off-diagonal tile into one batched two-product
chain ``U (V^T x)`` plus a segment reduction -- the paper's "independent sets
of products stored in output buffers followed by a reduction".
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .tlr import TLRMatrix, tril_pairs, tril_index


# -- symmetric TLR matvec ------------------------------------------------------


@partial(jax.jit, static_argnums=(5,))
def _sym_matvec(D, U, V, ranks, xb, nb: int):
    pairs = tril_pairs(nb)
    rows = jnp.asarray(pairs[:, 0], jnp.int32)
    cols = jnp.asarray(pairs[:, 1], jnp.int32)
    yb = jnp.einsum("kbc,kc...->kb...", D, xb)
    xj = jnp.take(xb, cols, axis=0)
    xi = jnp.take(xb, rows, axis=0)
    # lower tiles: y_i += U (V^T x_j);   mirrored upper: y_j += V (U^T x_i)
    ylo = jnp.einsum("tbr,tr...->tb...", U, jnp.einsum("tbr,tb...->tr...", V, xj))
    yup = jnp.einsum("tbr,tr...->tb...", V, jnp.einsum("tbr,tb...->tr...", U, xi))
    yb = yb.at[rows].add(ylo)
    yb = yb.at[cols].add(yup)
    return yb


def tlr_matvec(A: TLRMatrix, x: jax.Array) -> jax.Array:
    """y = A @ x for symmetric TLR A; x is (n,) or (n, m)."""
    nb, b = A.nb, A.b
    xb = x.reshape(nb, b, *x.shape[1:])
    yb = _sym_matvec(A.D, A.U, A.V, A.ranks, xb, nb)
    return yb.reshape(x.shape)


# -- lower-triangular TLR products / solves -------------------------------------


def tlr_tri_matvec(L: TLRMatrix, x: jax.Array, *, trans: bool = False) -> jax.Array:
    """y = L @ x (or L^T @ x) for lower-triangular TLR L."""
    nb, b = L.nb, L.b
    xb = x.reshape(nb, b, *x.shape[1:])
    pairs = tril_pairs(nb)
    rows = jnp.asarray(pairs[:, 0], jnp.int32)
    cols = jnp.asarray(pairs[:, 1], jnp.int32)
    if not trans:
        yb = jnp.einsum("kbc,kc...->kb...", L.D, xb)
        xj = jnp.take(xb, cols, axis=0)
        ylo = jnp.einsum("tbr,tr...->tb...", L.U,
                         jnp.einsum("tbr,tb...->tr...", L.V, xj))
        yb = yb.at[rows].add(ylo)
    else:
        yb = jnp.einsum("kcb,kc...->kb...", L.D, xb)
        xi = jnp.take(xb, rows, axis=0)
        yup = jnp.einsum("tbr,tr...->tb...", L.V,
                         jnp.einsum("tbr,tb...->tr...", L.U, xi))
        yb = yb.at[cols].add(yup)
    return yb.reshape(x.shape)


def tlr_trsv(L: TLRMatrix, y: jax.Array, *, trans: bool = False) -> jax.Array:
    """Solve L x = y (trans=False) or L^T x = y (trans=True). Algorithm 7.

    Right-looking: after each diagonal solve, the solution block updates all
    remaining blocks through the batched two-product chain.
    """
    nb, b = L.nb, L.b
    xb = [y.reshape(nb, b, *y.shape[1:])[i] for i in range(nb)]
    order = range(nb) if not trans else range(nb - 1, -1, -1)
    for k in order:
        Dk = L.D[k] if not trans else L.D[k].T
        xk = jax.scipy.linalg.solve_triangular(Dk, xb[k], lower=not trans)
        xb[k] = xk
        if not trans:
            idx = [tril_index(i, k) for i in range(k + 1, nb)]
            if idx:
                ii = jnp.asarray(idx, jnp.int32)
                Ut, Vt = jnp.take(L.U, ii, axis=0), jnp.take(L.V, ii, axis=0)
                upd = jnp.einsum("tbr,tr...->tb...", Ut,
                                 jnp.einsum("tbr,b...->tr...", Vt, xk))
                for t, i in enumerate(range(k + 1, nb)):
                    xb[i] = xb[i] - upd[t]
        else:
            idx = [tril_index(k, j) for j in range(k)]
            if idx:
                ii = jnp.asarray(idx, jnp.int32)
                Ut, Vt = jnp.take(L.U, ii, axis=0), jnp.take(L.V, ii, axis=0)
                # (L^T)(j,k) = L(k,j)^T = V U^T
                upd = jnp.einsum("tbr,tr...->tb...", Vt,
                                 jnp.einsum("tbr,b...->tr...", Ut, xk))
                for t, j in enumerate(range(k)):
                    xb[j] = xb[j] - upd[t]
    return jnp.stack(xb).reshape(y.shape)


def tile_perm_to_element_perm(perm: np.ndarray, b: int) -> np.ndarray:
    return (np.asarray(perm)[:, None] * b + np.arange(b)[None, :]).reshape(-1)


def tlr_factor_solve(fact, y: jax.Array) -> jax.Array:
    """Solve A x = y given a TLRFactorization (handles perm and LDL)."""
    eperm = tile_perm_to_element_perm(fact.perm, fact.L.b)
    yp = y[eperm] if y.ndim == 1 else y[eperm, :]
    z = tlr_trsv(fact.L, yp, trans=False)
    if fact.d is not None:
        dflat = fact.d.reshape(-1)
        z = z / (dflat if z.ndim == 1 else dflat[:, None])
    z = tlr_trsv(fact.L, z, trans=True)
    out = jnp.zeros_like(z)
    if z.ndim == 1:
        out = out.at[eperm].set(z)
    else:
        out = out.at[eperm, :].set(z)
    return out


def tlr_logdet(fact) -> jax.Array:
    """log |det A| from the factorization diagonals."""
    if fact.d is not None:
        diag_ld = jnp.sum(jnp.log(jnp.abs(fact.d)))
        return diag_ld
    diags = jnp.stack([jnp.diag(fact.L.D[k]) for k in range(fact.L.nb)])
    return 2.0 * jnp.sum(jnp.log(jnp.abs(diags)))


def mvn_sample(fact, key, num: int = 1) -> jax.Array:
    """Sample x ~ N(0, A) via x = P^T L z (Cholesky factorizations only)."""
    if fact.d is not None:
        raise ValueError("MVN sampling requires a Cholesky factorization")
    n = fact.L.n
    z = jax.random.normal(key, (n, num), fact.L.dtype)
    x = tlr_tri_matvec(fact.L, z)
    eperm = tile_perm_to_element_perm(fact.perm, fact.L.b)
    out = jnp.zeros_like(x)
    out = out.at[eperm, :].set(x)
    return out[:, 0] if num == 1 else out


# -- preconditioned conjugate gradients -----------------------------------------


def pcg(matvec, b_rhs: jax.Array, *, precond=None, tol: float = 1e-6,
        maxiter: int = 300):
    """PCG with relative residual ||Ax-b||/||b|| stopping (paper section 6.2).

    Host-driven loop (convergence checked each iteration); returns
    (x, iterations, history).
    """
    x = jnp.zeros_like(b_rhs)
    r = b_rhs - matvec(x)
    z = precond(r) if precond else r
    p_dir = z
    rz = jnp.vdot(r, z)
    bnorm = float(jnp.linalg.norm(b_rhs))
    history = [float(jnp.linalg.norm(r)) / bnorm]
    it = 0
    for it in range(1, maxiter + 1):
        Ap = matvec(p_dir)
        alpha = rz / jnp.vdot(p_dir, Ap)
        x = x + alpha * p_dir
        r = r - alpha * Ap
        rnorm = float(jnp.linalg.norm(r)) / bnorm
        history.append(rnorm)
        if rnorm < tol:
            break
        z = precond(r) if precond else r
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        rz = rz_new
        p_dir = z + beta * p_dir
    return x, it, history
