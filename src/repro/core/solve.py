"""TLR matrix-vector products and triangular solves (section 4.4, Alg. 7),
preconditioned CG (section 6.2), log-determinant and MVN sampling.

The matvec marshals every off-diagonal tile into one batched two-product
chain ``U (V^T x)`` plus a segment reduction -- the paper's "independent sets
of products stored in output buffers followed by a reduction".

The triangular solve is a jitted, bucket-laddered blocked TRSM: each column
step (diagonal solve + batched low-rank update of the remaining blocks) runs
inside one jitted executable whose row-batch operands are zero-padded up to
the power-of-two bucket ladder of DESIGN.md section 2, so ~log2(nb) compiled
variants serve all nb columns -- the same shape-stable treatment the
factorization's column pipeline got in PR 1, now applied to the solve phase
(the HODLR GPU solvers of arXiv 2208.06290 batch their solves the same way).
Right-hand sides may be single vectors ``(n,)`` or batched ``(n, m)``.

``tlr_factor_solve`` / ``tlr_logdet`` / ``mvn_sample`` remain as deprecated
shims over the ``TLRFactorization`` handle methods (DESIGN.md section 5).
"""

from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .buckets import _bucket_ladder, _bucket_up
from .tlr import TLRMatrix, tril_pairs, tril_index


# -- symmetric TLR matvec ------------------------------------------------------


@partial(jax.jit, static_argnums=(5,))
def _sym_matvec(D, U, V, ranks, xb, nb: int):
    pairs = tril_pairs(nb)
    rows = jnp.asarray(pairs[:, 0], jnp.int32)
    cols = jnp.asarray(pairs[:, 1], jnp.int32)
    yb = jnp.einsum("kbc,kc...->kb...", D, xb)
    xj = jnp.take(xb, cols, axis=0)
    xi = jnp.take(xb, rows, axis=0)
    # lower tiles: y_i += U (V^T x_j);   mirrored upper: y_j += V (U^T x_i)
    ylo = jnp.einsum("tbr,tr...->tb...", U, jnp.einsum("tbr,tb...->tr...", V, xj))
    yup = jnp.einsum("tbr,tr...->tb...", V, jnp.einsum("tbr,tb...->tr...", U, xi))
    yb = yb.at[rows].add(ylo)
    yb = yb.at[cols].add(yup)
    return yb


def tlr_matvec(A: TLRMatrix, x: jax.Array) -> jax.Array:
    """y = A @ x for symmetric TLR A; x is (n,) or (n, m)."""
    nb, b = A.nb, A.b
    xb = x.reshape(nb, b, *x.shape[1:])
    yb = _sym_matvec(A.D, A.U, A.V, A.ranks, xb, nb)
    return yb.reshape(x.shape)


# -- lower-triangular TLR products / solves -------------------------------------


def tlr_tri_matvec(L: TLRMatrix, x: jax.Array, *, trans: bool = False) -> jax.Array:
    """y = L @ x (or L^T @ x) for lower-triangular TLR L."""
    nb, b = L.nb, L.b
    xb = x.reshape(nb, b, *x.shape[1:])
    pairs = tril_pairs(nb)
    rows = jnp.asarray(pairs[:, 0], jnp.int32)
    cols = jnp.asarray(pairs[:, 1], jnp.int32)
    if not trans:
        yb = jnp.einsum("kbc,kc...->kb...", L.D, xb)
        xj = jnp.take(xb, cols, axis=0)
        ylo = jnp.einsum("tbr,tr...->tb...", L.U,
                         jnp.einsum("tbr,tb...->tr...", L.V, xj))
        yb = yb.at[rows].add(ylo)
    else:
        yb = jnp.einsum("kcb,kc...->kb...", L.D, xb)
        xi = jnp.take(xb, rows, axis=0)
        yup = jnp.einsum("tbr,tr...->tb...", L.V,
                         jnp.einsum("tbr,tb...->tr...", L.U, xi))
        yb = yb.at[cols].add(yup)
    return yb.reshape(x.shape)


# -- jitted bucketed blocked TRSM ----------------------------------------------

# One entry per freshly compiled column-step variant; the python body of the
# jitted step runs exactly once per compile, so this is a real compile count
# (the contract tests/test_trsm.py pins, mirroring ``stats["column_traces"]``
# in the factorization).
_TRSM_TRACES = {"count": 0}


def trsm_trace_count() -> int:
    """Number of compiled TRSM column-step variants so far (process-wide)."""
    return _TRSM_TRACES["count"]


@partial(jax.jit, static_argnames=("trans",))
def _trsm_step(D, U, V, xb, k, tidx, ridx, valid, *, trans: bool):
    """One blocked-TRSM column: solve the diagonal block, update the rest.

    Operands: the factor's full (static-shape) D/U/V buffers plus small
    per-column index vectors. ``tidx`` selects the Tb (bucket-padded) tiles
    of column k, ``ridx`` the block rows they update; padded slots carry
    ``valid=False`` and a zero update, so the scatter-add is inert there
    (padded ``ridx`` entries point at block 0 and add exact zeros).
    """
    _TRSM_TRACES["count"] += 1
    Dk = jax.lax.dynamic_index_in_dim(D, k, keepdims=False)
    yk = jax.lax.dynamic_index_in_dim(xb, k, keepdims=False)
    Ut = jnp.take(U, tidx, axis=0)
    Vt = jnp.take(V, tidx, axis=0)
    if trans:
        # (L^T)(j,k) = L(k,j)^T = V U^T: the U/V roles swap in the update.
        Dk = Dk.T
        Ut, Vt = Vt, Ut
    xk = jax.scipy.linalg.solve_triangular(Dk, yk, lower=not trans)
    upd = jnp.einsum("tbr,trm->tbm", Ut, jnp.einsum("tbr,bm->trm", Vt, xk))
    upd = jnp.where(valid[:, None, None], upd, jnp.zeros_like(upd))
    xb = jax.lax.dynamic_update_index_in_dim(xb, xk, k, axis=0)
    return xb.at[ridx].add(-upd)


def tlr_trsv(L: TLRMatrix, y: jax.Array, *, trans: bool = False) -> jax.Array:
    """Solve L x = y (trans=False) or L^T x = y (trans=True). Algorithm 7.

    Right-looking blocked TRSM: after each diagonal solve, the solution
    block updates all remaining blocks through the batched two-product
    chain, inside a jitted bucket-laddered column step (~log2(nb) compiled
    variants instead of a host loop over per-block lists). ``y`` is a single
    right-hand side ``(n,)`` or a batch ``(n, m)``.
    """
    nb, b = L.nb, L.b
    xb = y.reshape(nb, b, -1)
    if nb == 1:
        Dk = L.D[0].T if trans else L.D[0]
        x = jax.scipy.linalg.solve_triangular(Dk, xb[0], lower=not trans)
        return x.reshape(y.shape)
    ladder = _bucket_ladder(nb - 1)
    order = range(nb) if not trans else range(nb - 1, -1, -1)
    for k in order:
        if not trans:
            tgt = np.arange(k + 1, nb)
            tiles = tgt * (tgt - 1) // 2 + k          # tril_index(i, k)
        else:
            tgt = np.arange(k)
            tiles = k * (k - 1) // 2 + tgt            # tril_index(k, j)
        T = len(tgt)
        Tb = _bucket_up(max(T, 1), ladder)
        tidx = np.zeros(Tb, np.int32)
        ridx = np.zeros(Tb, np.int32)
        tidx[:T], ridx[:T] = tiles, tgt
        valid = np.zeros(Tb, bool)
        valid[:T] = True
        xb = _trsm_step(L.D, L.U, L.V, xb,
                        jnp.asarray(k, jnp.int32), jnp.asarray(tidx),
                        jnp.asarray(ridx), jnp.asarray(valid), trans=trans)
    return xb.reshape(y.shape)


def tlr_trsv_reference(L: TLRMatrix, y: jax.Array, *,
                       trans: bool = False) -> jax.Array:
    """Pre-PR-2 host-loop TRSV, kept as the parity oracle for the jitted
    bucketed TRSM (tests/test_trsm.py; benchmarks/bench_tlr.py --suite
    solve). Same math, un-jitted python loop over per-block lists."""
    nb, b = L.nb, L.b
    xb = [y.reshape(nb, b, *y.shape[1:])[i] for i in range(nb)]
    order = range(nb) if not trans else range(nb - 1, -1, -1)
    for k in order:
        Dk = L.D[k] if not trans else L.D[k].T
        xk = jax.scipy.linalg.solve_triangular(Dk, xb[k], lower=not trans)
        xb[k] = xk
        if not trans:
            idx = [tril_index(i, k) for i in range(k + 1, nb)]
            if idx:
                ii = jnp.asarray(idx, jnp.int32)
                Ut, Vt = jnp.take(L.U, ii, axis=0), jnp.take(L.V, ii, axis=0)
                upd = jnp.einsum("tbr,tr...->tb...", Ut,
                                 jnp.einsum("tbr,b...->tr...", Vt, xk))
                for t, i in enumerate(range(k + 1, nb)):
                    xb[i] = xb[i] - upd[t]
        else:
            idx = [tril_index(k, j) for j in range(k)]
            if idx:
                ii = jnp.asarray(idx, jnp.int32)
                Ut, Vt = jnp.take(L.U, ii, axis=0), jnp.take(L.V, ii, axis=0)
                # (L^T)(j,k) = L(k,j)^T = V U^T
                upd = jnp.einsum("tbr,tr...->tb...", Vt,
                                 jnp.einsum("tbr,b...->tr...", Ut, xk))
                for t, j in enumerate(range(k)):
                    xb[j] = xb[j] - upd[t]
    return jnp.stack(xb).reshape(y.shape)


def tile_perm_to_element_perm(perm: np.ndarray, b: int) -> np.ndarray:
    return (np.asarray(perm)[:, None] * b + np.arange(b)[None, :]).reshape(-1)


# -- factorization application (implementations behind the handle methods) ----


def _factor_solve_impl(fact, y: jax.Array) -> jax.Array:
    """Solve A x = y given a TLRFactorization (handles perm and LDL)."""
    eperm = tile_perm_to_element_perm(fact.perm, fact.L.b)
    yp = y[eperm] if y.ndim == 1 else y[eperm, :]
    z = tlr_trsv(fact.L, yp, trans=False)
    if fact.d is not None:
        dflat = fact.d.reshape(-1)
        z = z / (dflat if z.ndim == 1 else dflat[:, None])
    z = tlr_trsv(fact.L, z, trans=True)
    out = jnp.zeros_like(z)
    if z.ndim == 1:
        out = out.at[eperm].set(z)
    else:
        out = out.at[eperm, :].set(z)
    return out


def _logdet_impl(fact) -> jax.Array:
    """log |det A| from the factorization diagonals.

    One batched ``jnp.diagonal`` over the (nb, b, b) diagonal-tile stack --
    the per-tile ``jnp.diag`` host loop this replaces dispatched nb tiny
    ops per call.
    """
    if fact.d is not None:
        diag_ld = jnp.sum(jnp.log(jnp.abs(fact.d)))
        return diag_ld
    diags = jnp.diagonal(fact.L.D, axis1=1, axis2=2)
    return 2.0 * jnp.sum(jnp.log(jnp.abs(diags)))


def _mvn_sample_impl(fact, key, num: int = 1) -> jax.Array:
    """Sample x ~ N(0, A) via x = P^T L z (Cholesky factorizations only)."""
    if fact.d is not None:
        raise ValueError("MVN sampling requires a Cholesky factorization")
    n = fact.L.n
    z = jax.random.normal(key, (n, num), fact.L.dtype)
    x = tlr_tri_matvec(fact.L, z)
    eperm = tile_perm_to_element_perm(fact.perm, fact.L.b)
    out = jnp.zeros_like(x)
    out = out.at[eperm, :].set(x)
    return out[:, 0] if num == 1 else out


def _deprecated(old: str, new: str) -> None:
    # FutureWarning, not DeprecationWarning: the default warning filters
    # silence DeprecationWarning outside __main__, and these shims are the
    # user-facing migration signal for the one release they survive.
    warnings.warn(f"{old} is deprecated; use {new} (DESIGN.md section 5)",
                  FutureWarning, stacklevel=3)


def tlr_factor_solve(fact, y: jax.Array) -> jax.Array:
    """Deprecated shim: use ``TLRFactorization.solve(y)``."""
    _deprecated("tlr_factor_solve(fact, y)", "fact.solve(y)")
    return _factor_solve_impl(fact, y)


def tlr_logdet(fact) -> jax.Array:
    """Deprecated shim: use ``TLRFactorization.logdet()``."""
    _deprecated("tlr_logdet(fact)", "fact.logdet()")
    return _logdet_impl(fact)


def mvn_sample(fact, key, num: int = 1) -> jax.Array:
    """Deprecated shim: use ``TLRFactorization.sample(key, num)``."""
    _deprecated("mvn_sample(fact, key, num)", "fact.sample(key, num)")
    return _mvn_sample_impl(fact, key, num)


# -- preconditioned conjugate gradients -----------------------------------------


def _as_matvec(op):
    """Coerce an operator argument to a matvec callable: a bare callable,
    or any object with a ``.matvec`` (TLROperator; TLRFactorization, whose
    operator action is A^{-1})."""
    if op is None:
        return None
    if callable(op) and not hasattr(op, "matvec"):
        return op
    mv = getattr(op, "matvec", None)
    if mv is not None:
        return mv
    raise TypeError(
        f"expected a callable or an object with .matvec, got {type(op)!r}")


class PCGHistory(list):
    """Relative-residual history: a plain ``list`` of floats (so existing
    ``hist[-1]`` / iteration callers keep working) carrying breakdown
    diagnostics. ``breakdown`` is None on a clean run, or the condition
    that stopped the iteration early:

    * ``"indefinite_curvature"``      -- p^T A p <= 0 (A not SPD),
    * ``"indefinite_preconditioner"`` -- r^T M^{-1} r <= 0 (M not SPD),
    * ``"nonfinite"``                 -- a NaN/Inf appeared in the recurrence.

    On breakdown PCG returns the last finite iterate instead of silently
    flooding x and the history with NaNs for the remaining iterations.
    """

    def __init__(self, *args):
        super().__init__(*args)
        self.breakdown: str | None = None


def pcg(A, b_rhs: jax.Array, *, precond=None, tol: float = 1e-6,
        maxiter: int = 300):
    """PCG with relative residual ||Ax-b||/||b|| stopping (paper section 6.2).

    ``A`` and ``precond`` are callables ``v -> Av`` (resp. ``r -> M^{-1}r``)
    or any object with a ``.matvec`` -- a ``TLROperator``, or a
    ``TLRFactorization`` used directly as the preconditioner. Host-driven
    loop (convergence checked each iteration); returns (x, iterations,
    history), where ``history`` is a :class:`PCGHistory` whose
    ``breakdown`` attribute records an indefinite-operator /
    indefinite-preconditioner / non-finite breakdown (the iteration stops
    at the last finite iterate instead of spinning to ``maxiter`` on
    NaNs). A zero right-hand side returns x = 0 immediately with an empty
    history.
    """
    matvec = _as_matvec(A)
    precond = _as_matvec(precond)
    bnorm = float(jnp.linalg.norm(b_rhs))
    if bnorm == 0.0:
        return jnp.zeros_like(b_rhs), 0, PCGHistory()
    x = jnp.zeros_like(b_rhs)
    r = b_rhs - matvec(x)
    z = precond(r) if precond else r
    p_dir = z
    rz = jnp.vdot(r, z)
    history = PCGHistory([float(jnp.linalg.norm(r)) / bnorm])
    rz_f = float(rz)
    if not np.isfinite(rz_f) or rz_f <= 0.0:
        history.breakdown = ("nonfinite" if not np.isfinite(rz_f)
                             else "indefinite_preconditioner")
        return x, 0, history
    it = 0
    for it in range(1, maxiter + 1):
        Ap = matvec(p_dir)
        pAp = float(jnp.vdot(p_dir, Ap))
        if not np.isfinite(pAp) or pAp <= 0.0:
            history.breakdown = ("nonfinite" if not np.isfinite(pAp)
                                 else "indefinite_curvature")
            it -= 1
            break
        alpha = rz / pAp
        x_new = x + alpha * p_dir
        r_new = r - alpha * Ap
        rnorm = float(jnp.linalg.norm(r_new)) / bnorm
        if not np.isfinite(rnorm):
            history.breakdown = "nonfinite"
            it -= 1
            break
        x, r = x_new, r_new
        history.append(rnorm)
        if rnorm < tol:
            break
        z = precond(r) if precond else r
        rz_new = jnp.vdot(r, z)
        rz_f = float(rz_new)
        if not np.isfinite(rz_f) or rz_f <= 0.0:
            history.breakdown = ("nonfinite" if not np.isfinite(rz_f)
                                 else "indefinite_preconditioner")
            break
        beta = rz_new / rz
        rz = rz_new
        p_dir = z + beta * p_dir
    return x, it, history
