"""Breakdown detection + bounded recovery for the TLR drivers (DESIGN.md
section 13; the failure-model layer of ISSUE 10).

The adaptive factorization is numerically live -- ranks, tolerances, and
diagonal conditioning interact at runtime -- so an indefinite diagonal
tile, a NaN produced mid-panel, or a rank overflow must surface as a
*policy decision* (retry, degrade, or raise), never as silent NaN factors.
H2OPUS-TLR leans on the same breakdown handling to factor ill-conditioned
covariance matrices at loose eps; the diagonal-shift escalation mirrors
the HODLR-GPU recovery of Chen & Martinsson (arXiv:2208.06290).

Three pieces live here, shared by both drivers:

* **Fused device-side flag reductions** (:func:`column_flags`): one jitted
  reduction per checked stage collapses "any non-finite panel entry",
  "any non-finite / non-positive pivot", and "any tile at the rank cap
  with err > eps" into a tiny vector, pulled to the host in a single
  transfer that rides the per-column sync the drivers already make.
  Inputs are bucket-padded (padding is zero, hence finite and inert), so
  the compiled-variant count stays O(log nb) -- the same shape discipline
  as the pipelines themselves. Zero-cost when ``CholOptions.check`` is
  off: the drivers never construct a monitor, exactly the ``obs``
  contract.

* **A bounded escalation policy** (:class:`RetryPolicy`, carried on
  ``CholOptions.retry``): diagonal jitter ``shift0 * growth**attempt`` on
  SPD breakdown, eps-loosening ``eps * eps_growth**attempt`` on rank
  overflow, per-tile densify as the last resort. The policy only *sizes*
  remedies; the drivers apply them (they own the pipelines).

* **Structured outcomes**: every remedy lands as a :class:`HealthEvent`
  in ``fact.stats["health"]`` (and, when telemetry records, as a
  cumulative ``obs.counter("health", ...)`` sample); exhausted retries
  raise :class:`FactorizationBreakdown` carrying a
  :class:`BreakdownReport` (column, stage, pivot index, every remedy
  attempted) instead of returning non-finite factors.
"""

from __future__ import annotations

import dataclasses
from typing import List, NoReturn, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs

__all__ = [
    "RetryPolicy", "HealthEvent", "BreakdownReport",
    "FactorizationBreakdown", "HealthMonitor", "column_flags",
]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded remedy schedule (frozen so ``CholOptions`` stays hashable).

    ``max_retries`` bounds *each* remedy ladder independently: up to
    ``max_retries`` jitter re-factors of a failing diagonal tile and up to
    ``max_retries`` eps-loosened ARA re-passes of an overflowing panel
    (then the densify fallback, if enabled). A tile whose truncation
    error still exceeds ``eps * eps_growth**max_retries`` after every
    remedy is a breakdown, not a silent degradation.
    """

    max_retries: int = 2
    shift0: float = 1e-8       # first jitter shift, relative to diag scale
    growth: float = 16.0       # jitter escalation per attempt
    eps_growth: float = 4.0    # eps loosening per rank-overflow retry
    densify: bool = True       # exact-sample + SVD fallback at the cap

    def shift(self, attempt: int) -> float:
        return self.shift0 * self.growth ** attempt

    def eps_at(self, eps: float, attempt: int) -> float:
        return eps * self.eps_growth ** attempt

    def eps_floor(self, eps: float) -> float:
        """The loosest tolerance any remedy is allowed to accept."""
        return eps * self.eps_growth ** self.max_retries


@dataclasses.dataclass
class HealthEvent:
    """One detection or remedy, as recorded in ``stats["health"]``."""

    kind: str                  # "spd_breakdown" | "nonfinite_panel" |
                               # "nonfinite_update" | "rank_overflow" | ...
    column: int
    stage: str                 # "diag" | "panel" | "update" | "final"
    remedy: str                # "jitter" | "eps_loosen" | "densify" |
                               # "clamp" | "accept" | "raise"
    attempt: int = 0
    detail: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class BreakdownReport:
    """What :class:`FactorizationBreakdown` carries instead of NaNs."""

    column: int
    stage: str
    reason: str
    pivot_index: Optional[int] = None
    remedies: List[str] = dataclasses.field(default_factory=list)
    events: List[HealthEvent] = dataclasses.field(default_factory=list)
    detail: dict = dataclasses.field(default_factory=dict)


class FactorizationBreakdown(RuntimeError):
    """Raised when every remedy in the :class:`RetryPolicy` is exhausted
    (or the failure is unrecoverable, e.g. non-finite panel output with
    healthy pivots). The factorization never returns partial or
    non-finite factors -- the report says what failed and what was tried.
    """

    def __init__(self, report: BreakdownReport):
        self.report = report
        where = f"column {report.column}" if report.column >= 0 \
            else "final scan"
        tried = ", ".join(report.remedies) if report.remedies else "none"
        super().__init__(
            f"factorization breakdown at {where} ({report.stage}): "
            f"{report.reason}; remedies attempted: {tried}")


# -- fused device-side flag reductions ----------------------------------------

# Flag vector layout (pulled host-side as one tiny transfer):
#   [0] non-finite entries across the scanned arrays (panel bases/factors)
#   [1] non-finite pivots
#   [2] min finite pivot (+inf when all pivots are non-finite)
#   [3] argmin of [2]
#   [4] tiles at the rank cap with err > eps (device-side overflow count;
#       0 when the caller computes overflow host-side instead)
N_FLAGS = 5


def _flags_body(pivots, tree, ranks, err, r_cap, eps):
    f64 = pivots.dtype
    leaves = jax.tree.leaves(tree)
    n_nonfinite = sum((jnp.sum(~jnp.isfinite(x)) for x in leaves),
                      jnp.zeros((), jnp.int32))
    pf = jnp.isfinite(pivots)
    n_bad_piv = jnp.sum(~pf)
    piv = jnp.where(pf, pivots, jnp.inf)
    if ranks is None:
        n_over = jnp.zeros((), jnp.int32)
    else:
        n_over = jnp.sum((ranks >= r_cap) & ~(err <= eps))
    return jnp.stack([
        n_nonfinite.astype(f64), n_bad_piv.astype(f64), jnp.min(piv),
        jnp.argmin(piv).astype(f64), n_over.astype(f64),
    ])


_flags_jit = jax.jit(_flags_body, static_argnames=())


def column_flags(pivots, arrays=(), *, ranks=None, err=None,
                 r_cap: int = 0, eps: float = 0.0) -> np.ndarray:
    """One fused health reduction, pulled as a single (5,) host transfer.

    ``pivots`` is the diagonal of the column's dense factor (Cholesky) or
    its LDL d-vector; ``arrays`` is a pytree of panel outputs to scan for
    non-finite entries (pass them bucket-padded so the compiled-variant
    count stays on the ladder). ``ranks`` / ``err`` (optional, device)
    enable the device-side rank-overflow count against ``r_cap`` /
    ``eps``; a NaN ``err`` counts as overflow (``~(err <= eps)``).
    """
    if ranks is None:
        flags = _flags_jit(pivots, tuple(jax.tree.leaves(arrays)),
                           None, None, 0, 0.0)
    else:
        flags = _flags_jit(pivots, tuple(jax.tree.leaves(arrays)),
                           ranks, err, jnp.asarray(r_cap),
                           jnp.asarray(eps, pivots.dtype))
    return np.asarray(flags)


# -- the monitor ---------------------------------------------------------------


class HealthMonitor:
    """Per-factorization event log + report builder.

    The drivers own the decisions (they hold the pipelines); the monitor
    records what happened, keeps cumulative counters (mirrored into
    ``obs.counter("health", ...)`` when telemetry records), and builds the
    :class:`BreakdownReport` when a driver gives up.
    """

    def __init__(self, policy: RetryPolicy, algo: str, nb: int):
        self.policy = policy
        self.algo = algo
        self.nb = nb
        self.events: List[HealthEvent] = []
        self.counters: dict[str, int] = {}
        self.columns_checked = 0

    def record(self, kind: str, column: int, stage: str, *, remedy: str,
               attempt: int = 0, **detail) -> HealthEvent:
        ev = HealthEvent(kind=kind, column=column, stage=stage,
                         remedy=remedy, attempt=attempt, detail=detail)
        self.events.append(ev)
        self.counters[kind] = self.counters.get(kind, 0) + 1
        if obs.enabled():
            # Cumulative samples: the last sample of the series is the
            # factorization's total (metrics_snapshot "counters" contract).
            obs.counter("health",
                        {k: float(v) for k, v in self.counters.items()})
        return ev

    def fail(self, column: int, stage: str, reason: str, *,
             pivot_index: Optional[int] = None, **detail) -> NoReturn:
        self.record(reason, column, stage, remedy="raise", **detail)
        col_events = [e for e in self.events if e.column == column]
        report = BreakdownReport(
            column=column, stage=stage, reason=reason,
            pivot_index=pivot_index,
            remedies=[e.remedy for e in col_events
                      if e.remedy not in ("raise", "accept")],
            events=col_events, detail=detail)
        raise FactorizationBreakdown(report)

    def summary(self) -> dict:
        """The ``stats["health"]`` record (DESIGN.md section 13)."""
        return {
            "events": [dataclasses.asdict(e) for e in self.events],
            "counters": dict(self.counters),
            "columns_checked": self.columns_checked,
            "policy": dataclasses.asdict(self.policy),
        }
