"""Batched TLR tile algebra: rounding, structured ops, GEMM / SYRK.

The factorizations of PR 1-2 consume a TLR matrix; this module lets the
repo *compute with* TLR matrices -- the GEMM-centric operation set the
paper's performance story is built on (and what Boukaram et al.,
arXiv:1902.01829, implement as batched QR/SVD compression on GPUs):

* ``tlr_round``      -- recompress every off-diagonal tile's accumulated
  low-rank sum ``[U1|U2][V1|V2]^T`` in one batched rank-masked QR +
  small-SVD pass (``kernels/batched_qr.py`` + ``kernels/small_svd.py``,
  dispatched through ``kernels.ops`` so the ``ref/interpret/pallas``
  ladder applies).
* ``tlr_axpy`` / ``tlr_scale`` / ``tlr_transpose`` / ``tlr_add_diag`` --
  structured ops; addition is an exact low-rank concatenation (ranks add)
  with optional rounding.
* ``tlr_gemm``       -- TLR x TLR product on the general (nonsymmetric)
  tile grid ``TLRTiles``: the ``nb`` inner products per output tile are
  accumulated as batched ``(b, r) @ (r, b)`` chains concatenated into a
  single wide batched GEMM, then one rounding pass compresses all output
  tiles at once.
* ``tlr_syrk``       -- symmetric Schur update ``A - L L^T`` for
  lower-triangular TLR ``L``; the per-tile inner-product count ``j`` is
  padded up the power-of-two bucket ladder of ``core/buckets.py``, so
  ~log2(nb) compiled accumulation variants serve all nt output tiles --
  the update kernel a right-looking factorization needs.
* ``tlr_syrk_column`` / ``tlr_round_tiles`` -- the column-scoped SYRK
  and accumulated-tile rounding pass driving the right-looking
  factorization (``core/cholesky.py``, ``algo="right"``): per factored
  column, every trailing tile eagerly receives that column's single
  rank-r outer product as a concatenated factor-pair append, bucket-
  laddered over the trailing rows (DESIGN.md section 7).

No function here loops over tiles on the host in the hot path: all tile
math happens in jitted batched cores whose compile count is exposed via
``algebra_trace_count()`` (the contract ``tests/test_algebra.py`` pins,
mirroring ``trsm_trace_count``). Error model: a rounding pass at absolute
threshold ``eps`` perturbs each tile by at most ``sqrt(r) * eps`` in
Frobenius norm, so the whole matrix moves by <= ``sqrt(nt * r) * eps``
(DESIGN.md section 6).
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from .batching import (bucket_width, bucketed_round_tiles, resolve_batching,
                       shard_tile_batch)
from .buckets import (_bucket_ladder, _bucket_up, _pad_axis, trace_count,
                      trace_event)
from .tlr import TLRMatrix, tril_index, tril_pairs
from ..kernels import ops
from .. import obs


# -- general (nonsymmetric) tile grid -----------------------------------------


def offd_index(i: int, j: int, nb: int) -> int:
    """Flat index of off-diagonal tile (i, j), i != j, row-major skipping
    the diagonal: tile (i, j) lives at ``i*(nb-1) + (j - (j > i))``."""
    if i == j:
        raise ValueError(f"offd_index requires i != j, got ({i}, {j})")
    return i * (nb - 1) + (j if j < i else j - 1)


@lru_cache(maxsize=None)
def offd_pairs(nb: int) -> np.ndarray:
    """(no, 2) array of all off-diagonal (i, j) pairs in packed order."""
    out = np.zeros((nb * (nb - 1), 2), dtype=np.int64)
    for i in range(nb):
        for j in range(nb):
            if i != j:
                out[offd_index(i, j, nb)] = (i, j)
    return out


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TLRTiles:
    """General (nonsymmetric) TLR matrix (pytree): the result type of
    ``tlr_gemm`` and operand type of the operator arithmetic.

    Same storage discipline as ``TLRMatrix`` but with *all* ``nb*(nb-1)``
    off-diagonal tiles stored explicitly (packed per ``offd_index``):

      D:     (nb, b, b)      dense diagonal tiles.
      U, V:  (no, b, r_max)  low-rank factors, zero-padded past ``ranks``.
      ranks: (no,) int32     leading meaningful columns per tile.
    """

    D: jax.Array
    U: jax.Array
    V: jax.Array
    ranks: jax.Array

    @property
    def nb(self) -> int:
        return self.D.shape[0]

    @property
    def b(self) -> int:
        return self.D.shape[1]

    @property
    def n(self) -> int:
        return self.nb * self.b

    @property
    def r_max(self) -> int:
        return self.U.shape[2]

    @property
    def shape(self) -> tuple[int, int]:
        return (self.n, self.n)

    @property
    def dtype(self):
        return self.D.dtype

    def to_dense(self) -> jax.Array:
        return _tiles_to_dense(self.D, self.U, self.V, self.nb, self.b)

    def matvec(self, x: jax.Array) -> jax.Array:
        """y = A @ x; x is (n,) or batched (n, m)."""
        xb = x.reshape(self.nb, self.b, *x.shape[1:])
        yb = _gen_matvec(self.D, self.U, self.V, xb, self.nb)
        return yb.reshape(x.shape)

    def __matmul__(self, x):
        if isinstance(x, (jax.Array, np.ndarray)):
            return self.matvec(jnp.asarray(x))
        return NotImplemented

    def transpose(self) -> "TLRTiles":
        return tlr_transpose(self)

    def symmetrize(self, eps=None, r_max_out=None, *, impl=None) -> TLRMatrix:
        return symmetrize(self, eps, r_max_out, impl=impl)

    def round(self, eps, r_max_out=None, *, impl=None) -> "TLRTiles":
        return tlr_round(self, eps, r_max_out, impl=impl)


@partial(jax.jit, static_argnums=(3, 4))
def _tiles_to_dense(D, U, V, nb: int, b: int):
    out = jnp.zeros((nb * b, nb * b), D.dtype)
    for i in range(nb):
        out = out.at[i * b:(i + 1) * b, i * b:(i + 1) * b].set(D[i])
    for t, (i, j) in enumerate(offd_pairs(nb)):
        out = out.at[i * b:(i + 1) * b, j * b:(j + 1) * b].set(U[t] @ V[t].T)
    return out


@partial(jax.jit, static_argnums=(4,))
def _gen_matvec(D, U, V, xb, nb: int):
    pairs = offd_pairs(nb)
    rows = jnp.asarray(pairs[:, 0], jnp.int32)
    cols = jnp.asarray(pairs[:, 1], jnp.int32)
    yb = jnp.einsum("kbc,kc...->kb...", D, xb)
    xj = jnp.take(xb, cols, axis=0)
    y = jnp.einsum("tbr,tr...->tb...", U,
                   jnp.einsum("tbr,tb...->tr...", V, xj))
    return yb.at[rows].add(y)


# -- symmetric <-> general conversion -----------------------------------------


@lru_cache(maxsize=None)
def _generalize_indices(nb: int):
    """For each general pair (i, j): its packed-lower index and whether the
    stored tile is the transpose (i < j, so the U/V roles swap)."""
    pairs = offd_pairs(nb)
    idx = np.empty(len(pairs), np.int32)
    flip = np.empty(len(pairs), bool)
    for t, (i, j) in enumerate(pairs):
        if i > j:
            idx[t], flip[t] = tril_index(i, j), False
        else:
            idx[t], flip[t] = tril_index(j, i), True
    return idx, flip


def generalize(A: TLRMatrix) -> TLRTiles:
    """Mirror a symmetric TLR matrix onto the full general tile grid."""
    idx, flip = _generalize_indices(A.nb)
    U0 = jnp.take(A.U, jnp.asarray(idx), axis=0)
    V0 = jnp.take(A.V, jnp.asarray(idx), axis=0)
    f = jnp.asarray(flip)[:, None, None]
    return TLRTiles(
        D=A.D,
        U=jnp.where(f, V0, U0),
        V=jnp.where(f, U0, V0),
        ranks=jnp.take(A.ranks, jnp.asarray(idx)),
    )


@lru_cache(maxsize=None)
def _symmetrize_indices(nb: int):
    """(low, up) general-grid slots of each packed-lower pair (i, j)."""
    pairs = tril_pairs(nb)
    low = np.asarray([offd_index(int(i), int(j), nb) for i, j in pairs],
                     np.int32)
    up = np.asarray([offd_index(int(j), int(i), nb) for i, j in pairs],
                    np.int32)
    return low, up


def symmetrize(G: TLRTiles, eps=None, r_max_out=None, *,
               impl=None, batching: str = "flat") -> TLRMatrix:
    """Project onto the symmetric part, 0.5 (G + G^T), as a ``TLRMatrix``.

    Each lower tile is the exact rank-2r concatenation
    ``[G(i,j)/2 | G(j,i)^T/2]``; pass ``eps`` to recompress. The ``ranks``
    of the unrounded concat follow the axpy convention (see ``tlr_axpy``).
    """
    low_np, up_np = _symmetrize_indices(G.nb)
    low, up = jnp.asarray(low_np), jnp.asarray(up_np)
    Ul, Vl = jnp.take(G.U, low, axis=0), jnp.take(G.V, low, axis=0)
    Uu, Vu = jnp.take(G.U, up, axis=0), jnp.take(G.V, up, axis=0)
    half = jnp.asarray(0.5, G.dtype)
    out = TLRMatrix(
        D=half * (G.D + jnp.swapaxes(G.D, 1, 2)),
        U=jnp.concatenate([half * Ul, half * Vu], axis=-1),
        V=jnp.concatenate([Vl, Uu], axis=-1),
        ranks=(G.r_max + jnp.take(G.ranks, up)).astype(jnp.int32),
    )
    if eps is not None:
        out = tlr_round(out, eps, r_max_out, impl=impl, batching=batching)
    return out


# -- the batched rounding pass ------------------------------------------------

# One entry per freshly compiled algebra-core variant (rounding pass, GEMM
# assembly, SYRK bucket step), recorded under the "algebra" key of the
# unified registry in ``core/buckets.py``. The python body of a jitted core
# runs exactly once per compile, so this is a real compile count: it must
# stay O(log nb) per shape family and *never* scale with nt
# (tests/test_algebra.py pins it).


def algebra_trace_count() -> int:
    """Compiled algebra-core variants so far (process-wide); a view of
    ``trace_count("algebra")`` in the unified registry."""
    return trace_count("algebra")


def _truncate_svd(W, s, Z, Q_left, Q_right, eps, r_out: int, rel: bool,
                  impl: str):
    """Shared truncation tail: given core SVD ``W s Z^T`` and the two
    orthonormal bases it lives in, build zero-padded (U, V, ranks, err).
    ``err`` is the per-tile Frobenius norm of the discarded part -- the
    bases are orthonormal, so it is exactly the 2-norm of the dropped
    singular values (no reconstruction needed)."""
    N, _, kin = W.shape
    b = Q_left.shape[1]
    cut = eps * (s[:, :1] if rel else jnp.ones_like(s[:, :1]))
    ranks = jnp.clip(jnp.sum(s > cut, axis=1), 0, r_out).astype(jnp.int32)
    k = min(r_out, kin)
    mask = (jnp.arange(k)[None, :] < ranks[:, None]).astype(W.dtype)
    full = jnp.full((N,), Q_left.shape[2], jnp.int32)
    dropped = jnp.where(jnp.arange(kin)[None, :] < ranks[:, None],
                        jnp.zeros_like(s), s)
    err = jnp.sqrt(jnp.sum(dropped * dropped, axis=1))
    U = ops.batched_gemm(
        Q_left, W[:, :, :k] * (s[:, None, :k] * mask[:, None, :]), full,
        impl=impl)
    if Q_right is None:
        V = Z[:, :, :k] * mask[:, None, :]
    else:
        V = ops.batched_gemm(Q_right, Z[:, :, :k] * mask[:, None, :], full,
                             impl=impl)
    if r_out > k:
        pad = ((0, 0), (0, 0), (0, r_out - k))
        U, V = jnp.pad(U, pad), jnp.pad(V, pad)
    return U, V, ranks, err


def _round_factors_impl(U, V, eps, *, r_out: int, rel: bool, impl: str):
    """Recompress (U, V) factor stacks, r_in <= b: batched QR of both
    sides, SVD of the r_in x r_in core R_u R_v^T, truncate at eps. The
    unjitted body, shared with the rank-bucketed cores in
    ``core/batching.py`` (which jit it per bucket width)."""
    N, b, r_in = U.shape
    Qu, Ru = ops.batched_qr(U, impl=impl)
    Qv, Rv = ops.batched_qr(V, impl=impl)
    full = jnp.full((N,), r_in, jnp.int32)
    core = ops.batched_gemm(Ru, jnp.swapaxes(Rv, 1, 2), full, impl=impl)
    W, s, Z = ops.small_svd(core, impl=impl)
    return _truncate_svd(W, s, Z, Qu, Qv, eps, r_out, rel, impl)


def _compress_dense_impl(T, eps, *, r_out: int, rel: bool, impl: str):
    """Compress dense (N, b, b) tiles: QR then SVD of the b x b R factor
    (unjitted body, shared with ``core/batching.py``)."""
    Q, R = ops.batched_qr(T, impl=impl)
    W, s, Z = ops.small_svd(R, impl=impl)
    return _truncate_svd(W, s, Z, Q, None, eps, r_out, rel, impl)


@partial(jax.jit, static_argnames=("r_out", "rel", "impl"))
def _round_factors(U, V, eps, *, r_out: int, rel: bool, impl: str):
    trace_event("algebra")
    return _round_factors_impl(U, V, eps, r_out=r_out, rel=rel, impl=impl)


@partial(jax.jit, static_argnames=("r_out", "rel", "impl"))
def _compress_dense_tiles(T, eps, *, r_out: int, rel: bool, impl: str):
    trace_event("algebra")
    return _compress_dense_impl(T, eps, r_out=r_out, rel=rel, impl=impl)


@obs.traced("algebra.round", cat="algebra")
def tlr_round(A, eps, r_max_out=None, *, rel: bool = False, impl=None,
              batching: str = "flat"):
    """Recompress every off-diagonal tile of ``A`` at threshold ``eps``.

    ``A`` is a ``TLRMatrix`` or ``TLRTiles`` whose tiles may hold
    accumulated sums ``[U1|U2][V1|V2]^T`` (ranks up to ``A.r_max``, which
    may exceed ``b`` after repeated concatenation). One batched pass over
    all tiles -- no host loop: factored QR + core SVD when ``r_max <= b``,
    densify-then-compress when the accumulated width exceeds the tile size
    (cheaper *and* exact there, since the tile is only b x b). Truncation
    keeps singular values ``> eps`` (absolute; ``rel`` cuts against each
    tile's s_max), so ranks are monotone non-increasing in ``eps``.

    ``batching="ranked"`` dispatches through the rank-bucketed layer
    (``core/batching.py``, DESIGN.md section 8): tiles are marshaled into
    rank-homogeneous batches and each bucket recompresses at its own ladder
    width instead of ``r_max`` (rank-0 tiles skip the kernels entirely).
    Same truncation semantics; ``"flat"`` is the compatibility path.
    """
    impl = ops.resolve_impl(impl)
    batching = resolve_batching(batching, A.ranks, A.r_max)
    b, r_in = A.b, A.r_max
    r_out = r_max_out or min(r_in, b)
    N = A.U.shape[0]
    if N == 0:
        z = jnp.zeros((0, b, r_out), A.dtype)
        return dataclasses.replace(A, U=z, V=z,
                                   ranks=jnp.zeros((0,), jnp.int32))
    if batching == "ranked":
        U, V, ranks, _ = bucketed_round_tiles(A.U, A.V, A.ranks, eps,
                                              r_out=r_out, rel=rel, impl=impl)
        return dataclasses.replace(A, U=U, V=V, ranks=ranks)
    eps = jnp.asarray(eps, A.dtype)
    if r_in <= b:
        U, V, ranks, _ = _round_factors(A.U, A.V, eps, r_out=r_out, rel=rel,
                                        impl=impl)
    else:
        dense = ops.batched_gemm(A.U, jnp.swapaxes(A.V, 1, 2), A.ranks,
                                 impl=impl)
        U, V, ranks, _ = _compress_dense_tiles(dense, eps, r_out=r_out,
                                               rel=rel, impl=impl)
    return dataclasses.replace(A, U=U, V=V, ranks=ranks)


@obs.traced("algebra.round_tiles", cat="algebra")
def tlr_round_tiles(U, V, eps, r_out=None, *, rel: bool = False, impl=None,
                    ranks=None, batching: str = "flat"):
    """Round a raw stack of accumulated tile factors ``U V^T``.

    The batched core of :func:`tlr_round`, exposed for callers that manage
    their own tile subsets instead of a whole ``TLRMatrix`` grid -- the
    right-looking factorization's panel and flush rounding passes
    (``core/cholesky.py``). ``U`` / ``V`` are ``(N, b, W)`` concatenated
    factor stacks (zero columns are inert, so callers need not track a
    per-tile used-width); returns ``(U, V, ranks, err)`` at width ``r_out``
    with ranks allowed to truncate to 0 and ``err`` the per-tile Frobenius
    norm of the discarded singular values. Width ``W > b`` takes the
    densify-then-compress path (exact for b x b tiles), ``W <= b`` the
    factored QR + core-SVD path.

    With ``batching="ranked"`` and a per-tile ``ranks`` (content-width)
    bound, the pass runs through the rank buckets of ``core/batching.py``
    instead of one W-wide batch (``ranks[t]`` must upper-bound tile ``t``'s
    nonzero columns -- the storage invariant / axpy width convention).
    """
    impl = ops.resolve_impl(impl)
    batching = resolve_batching(batching, ranks, U.shape[2])
    N, b, w_in = U.shape
    r_out = r_out or min(w_in, b)
    if batching == "ranked":
        if ranks is None:
            raise ValueError(
                "tlr_round_tiles(batching='ranked') needs the per-tile "
                "``ranks`` content-width bounds to build the buckets")
        return bucketed_round_tiles(U, V, ranks, eps, r_out=r_out, rel=rel,
                                    impl=impl)
    eps = jnp.asarray(eps, U.dtype)
    if w_in <= b:
        return _round_factors(U, V, eps, r_out=r_out, rel=rel, impl=impl)
    dense = ops.batched_gemm(U, jnp.swapaxes(V, 1, 2),
                             jnp.full((N,), w_in, jnp.int32), impl=impl)
    return _compress_dense_tiles(dense, eps, r_out=r_out, rel=rel, impl=impl)


# -- structured ops -----------------------------------------------------------


def tlr_scale(alpha, A):
    """alpha * A (exact; scales diagonal tiles and left factors)."""
    alpha = jnp.asarray(alpha, A.dtype)
    return dataclasses.replace(A, D=alpha * A.D, U=alpha * A.U)


def tlr_axpy(alpha, A, B, eps=None, r_max_out=None, *, impl=None,
             batching: str = "flat"):
    """alpha * A + B by low-rank concatenation, optionally rounded.

    Exact when ``eps`` is None: each tile becomes ``[alpha*U_A | U_B]
    [V_A | V_B]^T`` (r_max adds). The combined ``ranks`` are
    ``A.r_max + B.ranks``: the A-part's zero tail between ``rank_A`` and
    ``A.r_max`` sits *inside* the counted prefix, which is sound (zero
    columns are inert in every product) and keeps the "columns past ranks
    are zero" layout invariant; the next rounding pass compacts it away.
    ``A`` and ``B`` must share structure type, nb, and b.
    """
    if type(A) is not type(B) or A.nb != B.nb or A.b != B.b:
        raise ValueError(
            f"tlr_axpy needs matching structures, got {type(A).__name__}"
            f"(nb={A.nb}, b={A.b}) and {type(B).__name__}"
            f"(nb={B.nb}, b={B.b})")
    alpha = jnp.asarray(alpha, A.dtype)
    out = dataclasses.replace(
        A,
        D=alpha * A.D + B.D,
        U=jnp.concatenate([alpha * A.U, B.U], axis=-1),
        V=jnp.concatenate([A.V, B.V], axis=-1),
        ranks=(A.r_max + B.ranks).astype(jnp.int32),
    )
    if eps is not None:
        out = tlr_round(out, eps, r_max_out, impl=impl, batching=batching)
    return out


@lru_cache(maxsize=None)
def _transpose_perm(nb: int) -> np.ndarray:
    pairs = offd_pairs(nb)
    return np.asarray([offd_index(int(j), int(i), nb) for i, j in pairs],
                      np.int32)


def tlr_transpose(A):
    """A^T (exact). Identity for the symmetric ``TLRMatrix``; for
    ``TLRTiles`` the U/V roles swap and tiles move to mirrored slots."""
    if isinstance(A, TLRMatrix):
        return A
    perm = jnp.asarray(_transpose_perm(A.nb))
    return TLRTiles(
        D=jnp.swapaxes(A.D, 1, 2),
        U=jnp.take(A.V, perm, axis=0),
        V=jnp.take(A.U, perm, axis=0),
        ranks=jnp.take(A.ranks, perm),
    )


def tlr_add_diag(A, diag):
    """Dense add onto the diagonal tiles: ``diag`` is a scalar (alpha * I)
    or a (nb, b, b) stack of dense tiles."""
    diag = jnp.asarray(diag, A.dtype)
    if diag.ndim == 0:
        add = diag * jnp.eye(A.b, dtype=A.dtype)[None]
    elif diag.shape == A.D.shape:
        add = diag
    else:
        raise ValueError(
            f"diag must be scalar or shape {A.D.shape}, got {diag.shape}")
    return dataclasses.replace(A, D=A.D + add)


# -- TLR x TLR GEMM -----------------------------------------------------------


@lru_cache(maxsize=None)
def _gemm_indices(nb: int):
    """Host-built gather grids for the GEMM accumulation (setup only --
    the hot path consumes them as device constants).

    For off-diagonal output (i, j): its own slot in A and B, plus the
    ``nb - 2`` middle slots ``A(i, m), B(m, j)`` for m not in {i, j}. For
    diagonal output i: the ``nb - 1`` middle slots ``A(i, m), B(m, i)``.
    """
    pairs = offd_pairs(nb)
    no, K = len(pairs), max(nb - 2, 0)
    oi = pairs[:, 0].astype(np.int32)
    oj = pairs[:, 1].astype(np.int32)
    own = np.asarray([offd_index(int(i), int(j), nb) for i, j in pairs],
                     np.int32)
    mid_a = np.zeros((no, K), np.int32)
    mid_b = np.zeros((no, K), np.int32)
    for t, (i, j) in enumerate(pairs):
        mids = [m for m in range(nb) if m != i and m != j]
        mid_a[t] = [offd_index(int(i), m, nb) for m in mids]
        mid_b[t] = [offd_index(m, int(j), nb) for m in mids]
    dmid_a = np.zeros((nb, nb - 1), np.int32)
    dmid_b = np.zeros((nb, nb - 1), np.int32)
    for i in range(nb):
        mids = [m for m in range(nb) if m != i]
        dmid_a[i] = [offd_index(i, m, nb) for m in mids]
        dmid_b[i] = [offd_index(m, i, nb) for m in mids]
    return oi, oj, own, mid_a, mid_b, dmid_a, dmid_b


def _lrlr_dense_sum(Ua, Va, Ub, Vb, ranks_a, impl: str):
    """sum_k Ua_k (Va_k^T Ub_k) Vb_k^T as dense (N, b, b), fully batched.

    Inputs are (N, K, b, r*) term stacks. The per-term chains are flat
    batched GEMMs; the K-reduction is one wide GEMM over the concatenated
    width K*rb (the "concat the factors, multiply once" form).
    """
    N, K, b, ra = Ua.shape
    rb = Ub.shape[-1]
    if K == 0 or N == 0:
        return jnp.zeros((N, b, b), Ua.dtype)
    flat = lambda x: x.reshape(N * K, *x.shape[2:])  # noqa: E731
    fullb = jnp.full((N * K,), b, jnp.int32)
    W = ops.batched_gemm(jnp.swapaxes(flat(Va), 1, 2), flat(Ub), fullb,
                         impl=impl)                       # (NK, ra, rb)
    P = ops.batched_gemm(flat(Ua), W,
                         ranks_a.reshape(N * K).astype(jnp.int32),
                         impl=impl)                       # (NK, b, rb)
    Pc = P.reshape(N, K, b, rb).transpose(0, 2, 1, 3).reshape(N, b, K * rb)
    Vc = Vb.transpose(0, 2, 1, 3).reshape(N, b, K * rb)
    fullw = jnp.full((N,), K * rb, jnp.int32)
    return ops.batched_gemm(Pc, jnp.swapaxes(Vc, 1, 2), fullw, impl=impl)


@partial(jax.jit, static_argnames=("nb", "r_out", "rel", "impl"))
def _gemm_core(Da, Ua, Va, ranks_a, Db, Ub, Vb, eps, *, nb: int, r_out: int,
               rel: bool, impl: str):
    """The whole TLR x TLR product as one jitted batched computation."""
    trace_event("algebra")
    b = Da.shape[1]
    oi, oj, own, mid_a, mid_b, dmid_a, dmid_b = (
        jnp.asarray(x) for x in _gemm_indices(nb))
    no = own.shape[0]
    fullb = jnp.full((no,), b, jnp.int32)

    # dense diagonal of C: D_A(i) D_B(i) + sum_{m != i} lr x lr
    Dc = ops.batched_gemm(Da, Db, jnp.full((nb,), b, jnp.int32), impl=impl)
    if dmid_a.shape[1]:  # nb == 1: jnp.take squeezes empty index arrays
        Dc = Dc + _lrlr_dense_sum(
            jnp.take(Ua, dmid_a, axis=0), jnp.take(Va, dmid_a, axis=0),
            jnp.take(Ub, dmid_b, axis=0), jnp.take(Vb, dmid_b, axis=0),
            jnp.take(ranks_a, dmid_a), impl)
    if no == 0:
        z = jnp.zeros((0, b, r_out), Da.dtype)
        return Dc, z, z, jnp.zeros((0,), jnp.int32)

    # off-diagonal C(i, j), dense-accumulated from its nb inner products:
    #   k == i : D_A(i) B(i,j)           k == j : A(i,j) D_B(j)
    #   else   : A(i,k) B(k,j) low-rank chains, concatenated K-reduction
    Udl = ops.batched_gemm(jnp.take(Da, oi, axis=0),
                           jnp.take(Ub, own, axis=0), fullb, impl=impl)
    Vld = ops.batched_gemm(
        jnp.swapaxes(jnp.take(Db, oj, axis=0), 1, 2),
        jnp.take(Va, own, axis=0), fullb, impl=impl)
    C = ops.batched_gemm(
        jnp.concatenate([Udl, jnp.take(Ua, own, axis=0)], axis=-1),
        jnp.swapaxes(
            jnp.concatenate([jnp.take(Vb, own, axis=0), Vld], axis=-1), 1, 2),
        jnp.full((no,), Udl.shape[-1] + Ua.shape[-1], jnp.int32), impl=impl)
    if mid_a.shape[1]:  # nb == 2: no middle terms
        C = C + _lrlr_dense_sum(
            jnp.take(Ua, mid_a, axis=0), jnp.take(Va, mid_a, axis=0),
            jnp.take(Ub, mid_b, axis=0), jnp.take(Vb, mid_b, axis=0),
            jnp.take(ranks_a, mid_a), impl)
    U, V, ranks, _ = _compress_dense_tiles(C, eps, r_out=r_out, rel=rel,
                                           impl=impl)
    return Dc, U, V, ranks


def _as_tiles(X) -> TLRTiles:
    if isinstance(X, TLRTiles):
        return X
    if isinstance(X, TLRMatrix):
        return generalize(X)
    A = getattr(X, "A", None)  # TLROperator facade
    if isinstance(A, TLRMatrix):
        return generalize(A)
    raise TypeError(f"expected TLRMatrix / TLRTiles / TLROperator, "
                    f"got {type(X).__name__}")


@obs.traced("algebra.gemm", cat="algebra")
def tlr_gemm(A, B, eps, r_max_out=None, *, rel: bool = False,
             impl=None, batching: str = "flat") -> TLRTiles:
    """C = A @ B for TLR operands, compressed at ``eps``.

    ``A`` / ``B`` are ``TLRMatrix`` (mirrored onto the general grid),
    ``TLRTiles``, or ``TLROperator``. Every output tile accumulates its
    ``nb`` inner products as batched low-rank chains inside one jitted
    core, then a single rounding pass compresses all ``nb*(nb-1)`` output
    tiles -- no per-tile host loop; ``algebra_trace_count()`` counts the
    compiled variants (one per (nb, b, r) shape family).

    ``batching="ranked"``: each operand's factor stacks are sliced to the
    rank-ladder width covering its *actual* ranks before entering the core
    (exact -- columns past each rank are zero), so every accumulation chain
    and the concatenated K-reduction run at the bucketed width instead of
    ``r_max``. With an installed tile mesh the operand stacks shard their
    output-tile batch axis (``core/batching.py``).
    """
    Ga, Gb = _as_tiles(A), _as_tiles(B)
    if Ga.nb != Gb.nb or Ga.b != Gb.b:
        raise ValueError(f"tlr_gemm needs matching grids, got "
                         f"(nb={Ga.nb}, b={Ga.b}) and (nb={Gb.nb}, b={Gb.b})")
    impl = ops.resolve_impl(impl)
    batching = resolve_batching(
        batching, np.concatenate([np.asarray(Ga.ranks).reshape(-1),
                                  np.asarray(Gb.ranks).reshape(-1)]),
        max(Ga.r_max, Gb.r_max))
    r_out = r_max_out or min(max(Ga.r_max, Gb.r_max), Ga.b)
    Ua, Va, Ub, Vb = Ga.U, Ga.V, Gb.U, Gb.V
    if batching == "ranked" and Ua.shape[0]:
        wa = bucket_width(Ga.ranks, Ga.r_max)
        wb = bucket_width(Gb.ranks, Gb.r_max)
        Ua, Va = Ua[:, :, :wa], Va[:, :, :wa]
        Ub, Vb = Ub[:, :, :wb], Vb[:, :, :wb]
    if Ua.shape[0]:
        Ua, Va, Ub, Vb = shard_tile_batch(Ua, Va, Ub, Vb)
    Dc, U, V, ranks = _gemm_core(
        Ga.D, Ua, Va, Ga.ranks, Gb.D, Ub, Vb,
        jnp.asarray(eps, Ga.dtype), nb=Ga.nb, r_out=r_out, rel=rel,
        impl=impl)
    return TLRTiles(D=Dc, U=U, V=V, ranks=ranks)


# -- symmetric SYRK update  C = A - L L^T -------------------------------------


@lru_cache(maxsize=None)
def _syrk_buckets(nb: int):
    """Bucket the symmetric-update accumulation on the power-of-two ladder.

    Output tiles are all (i, j) with i >= j (packed lower first, then the
    nb diagonal slots appended at offset nt). Tile (i, j) sums ``j``
    low-rank inner products L(i,k) L(j,k)^T, k < j -- a term count that
    varies per tile, exactly the shape instability the bucket ladder
    exists for: tiles are grouped by ``bucket_up(j)`` so only ~log2(nb)
    accumulation variants compile. Returns a list of
    (out_slots, a_idx (N, Kb), b_idx (N, Kb), valid (N, Kb)) groups.
    """
    nt = nb * (nb - 1) // 2
    outs = [(int(i), int(j)) for i, j in tril_pairs(nb)]
    outs += [(i, i) for i in range(nb)]
    slots = list(range(nt)) + [nt + i for i in range(nb)]
    ladder = _bucket_ladder(nb - 1)
    groups = {}
    for slot, (i, j) in zip(slots, outs):
        if j == 0:
            continue  # no k < j terms; handled by the uniform parts
        Kb = _bucket_up(j, ladder)
        groups.setdefault(Kb, []).append((slot, i, j))
    out = []
    for Kb, members in sorted(groups.items()):
        N = len(members)
        sl = np.asarray([m[0] for m in members], np.int32)
        a_idx = np.zeros((N, Kb), np.int32)
        b_idx = np.zeros((N, Kb), np.int32)
        valid = np.zeros((N, Kb), bool)
        for t, (_, i, j) in enumerate(members):
            for k in range(j):
                a_idx[t, k] = tril_index(i, k)
                b_idx[t, k] = tril_index(j, k) if j > k else 0
            valid[t, :j] = True
        out.append((sl, a_idx, b_idx, valid))
    return out


@partial(jax.jit, static_argnames=("Kb", "impl"))
def _syrk_bucket(UL, VL, ranks_L, a_idx, b_idx, valid, *, Kb: int, impl: str):
    """Dense sum_{k<j} L(i,k) L(j,k)^T for one bucket's output tiles."""
    trace_event("algebra")
    Ua = jnp.take(UL, a_idx, axis=0) * valid[:, :, None, None]
    Va = jnp.take(VL, a_idx, axis=0)
    Ub = jnp.take(VL, b_idx, axis=0)   # term = U_ik (V_ik^T V_jk) U_jk^T
    Vb = jnp.take(UL, b_idx, axis=0)
    return _lrlr_dense_sum(Ua, Va, Ub, Vb, jnp.take(ranks_L, a_idx), impl)


@obs.traced("algebra.syrk", cat="algebra")
def tlr_syrk(A: TLRMatrix, L: TLRMatrix, eps, r_max_out=None, *,
             rel: bool = False, impl=None,
             batching: str = "flat") -> TLRMatrix:
    """Symmetric Schur update ``C = A - L L^T`` (lower-triangular TLR L).

    The right-looking counterpart of the factorization's left-looking
    column update: each output tile (i, j), i >= j, subtracts ``j``
    low-rank inner products plus the ``k == j`` diagonal-block term. Term
    counts ride the bucket ladder (~log2(nb) compiled accumulation
    variants); all nt off-diagonal results are compressed in one rounding
    pass. ``L.D`` holds the dense diagonal blocks L(k, k).

    ``batching="ranked"``: L's factor stacks are sliced to the rank-ladder
    width covering its actual ranks (exact), so every bucketed accumulation
    chain runs at the bucketed width instead of ``r_max``.
    """
    if A.nb != L.nb or A.b != L.b:
        raise ValueError(f"tlr_syrk needs matching grids, got "
                         f"(nb={A.nb}, b={A.b}) and (nb={L.nb}, b={L.b})")
    impl = ops.resolve_impl(impl)
    batching = resolve_batching(
        batching, np.concatenate([np.asarray(A.ranks).reshape(-1),
                                  np.asarray(L.ranks).reshape(-1)]),
        max(A.r_max, L.r_max))
    nb, b = A.nb, A.b
    nt = nb * (nb - 1) // 2
    r_out = r_max_out or min(max(A.r_max, L.r_max), b)
    dtype = A.dtype
    UL, VL = L.U, L.V
    if batching == "ranked" and nt:
        wl = bucket_width(L.ranks, L.r_max)
        UL, VL = UL[:, :, :wl], VL[:, :, :wl]

    # dense accumulation buffer: packed lower tiles, then the nb diagonals
    acc = jnp.zeros((nt + nb, b, b), dtype)
    if nt:
        acc = acc.at[:nt].set(
            ops.batched_gemm(A.U, jnp.swapaxes(A.V, 1, 2), A.ranks,
                             impl=impl))
    acc = acc.at[nt:].set(A.D)

    # k == j terms, uniform across outputs: off-diag L(i,j) D_j^T (one
    # batched chain over all nt lower tiles), diagonal D_i D_i^T
    if nt:
        pairs = tril_pairs(nb)
        jj = jnp.asarray(pairs[:, 1], jnp.int32)
        DV = ops.batched_gemm(jnp.take(L.D, jj, axis=0), VL,
                              jnp.full((nt,), b, jnp.int32), impl=impl)
        acc = acc.at[:nt].add(-ops.batched_gemm(
            UL, jnp.swapaxes(DV, 1, 2), L.ranks, impl=impl))
    acc = acc.at[nt:].add(-ops.batched_gemm(
        L.D, jnp.swapaxes(L.D, 1, 2), jnp.full((nb,), b, jnp.int32),
        impl=impl))

    # k < j terms: bucket-laddered batched accumulation (~log2(nb) shapes)
    for sl, a_idx, b_idx, valid in _syrk_buckets(nb):
        S = _syrk_bucket(UL, VL, L.ranks, jnp.asarray(a_idx),
                         jnp.asarray(b_idx), jnp.asarray(valid),
                         Kb=a_idx.shape[1], impl=impl)
        acc = acc.at[jnp.asarray(sl)].add(-S)

    if nt:
        U, V, ranks, _ = _compress_dense_tiles(
            acc[:nt], jnp.asarray(eps, dtype), r_out=r_out, rel=rel,
            impl=impl)
    else:
        U = V = jnp.zeros((0, b, r_out), dtype)
        ranks = jnp.zeros((0,), jnp.int32)
    return TLRMatrix(D=acc[nt:], U=U, V=V, ranks=ranks)


# -- column-scoped SYRK: the right-looking trailing update ---------------------


def _syrk_column_indices(nb: int, k: int, Tb: int):
    """Host gather grids for column ``k``'s trailing update, padded to the
    ``Tb``-row bucket. Slots map local trailing-row pairs ``(a, c)`` (rows
    ``k+1+a`` and ``k+1+c`` of the matrix) to packed-lower tile indices;
    padded slots carry ``valid=False`` and point at tile / block 0, where
    the core adds exact zeros. Vectorized on the (lru-cached) per-bucket
    pair grid -- no per-column Python loop, nothing retained per column.
    """
    T = nb - 1 - k
    pairs = tril_pairs(Tb)
    a = pairs[:, 0]
    c = pairs[:, 1]
    valid = a < T
    i, j = k + 1 + a, k + 1 + c
    oidx = np.where(valid, i * (i - 1) // 2 + j, 0).astype(np.int32)
    ar = np.arange(Tb)
    didx = np.where(ar < T, k + 1 + ar, 0).astype(np.int32)
    return (oidx, a.astype(np.int32), c.astype(np.int32), valid, didx,
            ar < T)


def _syrk_column_body(accU, accV, offsets, D, Up, Vn, ranks, dk,
                      oidx, aidx, cidx, valid, didx, dvalid, *,
                      ldl: bool, impl: str):
    """One column's eager trailing Schur update, fully batched.

    Per trailing tile (i, j), i > j > k, the single rank-``r_p`` term
    ``-L(i,k) D_k L(j,k)^T = -U_i (Vn_i^T D_k Vn_j) U_j^T`` is appended as
    a factor pair at that tile's write offset ``offsets[tile]`` of the
    accumulation buffers (the columns past the offset are zero, so a rolled
    scatter-add lands the block exactly; duplicate padded slots add zeros).
    ``offsets`` is a per-tile (nt,) vector -- uniform under flat batching,
    per-tile content widths under ranked batching, where each tile's
    concatenation stays compact instead of advancing in lockstep. Trailing
    diagonal tiles subtract their dense ``L(j,k) D_k L(j,k)^T`` product.
    """
    trace_event("algebra")
    r_p = Up.shape[-1]
    w_acc = accU.shape[-1]
    Ui = jnp.take(Up, aidx, axis=0)
    Vi = jnp.take(Vn, aidx, axis=0)
    Uj = jnp.take(Up, cidx, axis=0)
    Vj = jnp.take(Vn, cidx, axis=0)
    if ldl:
        G = jnp.einsum("tbr,b,tbq->trq", Vi, dk, Vj)
    else:
        G = jnp.einsum("tbr,tbq->trq", Vi, Vj)
    left = -ops.batched_gemm(Ui, G, jnp.take(ranks, aidx), impl=impl)
    m = valid[:, None, None]
    left = jnp.where(m, left, jnp.zeros_like(left))
    right = jnp.where(m, Uj, jnp.zeros_like(Uj))
    pad = ((0, 0), (0, 0), (0, w_acc - r_p))
    off = jnp.take(offsets, oidx)
    roll = jax.vmap(lambda x, s: jnp.roll(x, s, axis=-1))
    accU = accU.at[oidx].add(roll(jnp.pad(left, pad), off))
    accV = accV.at[oidx].add(roll(jnp.pad(right, pad), off))
    if ldl:
        Gd = jnp.einsum("tbr,b,tbq->trq", Vn, dk, Vn)
    else:
        Gd = jnp.einsum("tbr,tbq->trq", Vn, Vn)
    upd = jnp.einsum("tbr,trq,tcq->tbc", Up, Gd, Up)
    upd = jnp.where(dvalid[:, None, None], upd, jnp.zeros_like(upd))
    D = D.at[didx].add(-upd)
    return accU, accV, D


# Two compiled families of the same body: the drivers rebind their
# accumulation buffers after every call, so they use the donating variant
# (XLA aliases accU/accV/D input->output: no per-column copy of the widest
# arrays in the factorization); external callers that reuse their arrays
# (timing loops, tests) get the copying default via ``donate=False``.
_syrk_column_core = jax.jit(_syrk_column_body,
                            static_argnames=("ldl", "impl"))
_syrk_column_core_donated = jax.jit(_syrk_column_body,
                                    static_argnames=("ldl", "impl"),
                                    donate_argnums=(0, 1, 3))


def _syrk_head_body(accU, accV, offsets, D, Up, Vn, ranks, dk,
                    oidx, valid, didx, dvalid, *, ldl: bool, impl: str):
    """The *head* of a column's trailing update: tiles ``(i, k+1)`` for
    ``i > k+1`` plus the next diagonal ``D[k+1]`` -- everything column
    ``k+1`` needs before its own panel can factor.

    Slot ``s`` of the row-bucketed batch handles tile ``(k+1+s, k+1)``
    (``left = -U_i (V_i^T D_k V_{k+1})``, ``right = U_{k+1}``), a linear
    batch over the ``Tb`` row ladder instead of the full pair grid -- the
    lookahead schedule dispatches this narrow core eagerly and defers the
    wide pair-grid remainder (``_syrk_column_body`` masked to ``c >= 1``)
    until after the next panel is in flight.
    """
    trace_event("algebra")
    r_p = Up.shape[-1]
    w_acc = accU.shape[-1]
    V0 = Vn[0]
    if ldl:
        G = jnp.einsum("tbr,b,bq->trq", Vn, dk, V0)
    else:
        G = jnp.einsum("tbr,bq->trq", Vn, V0)
    left = -ops.batched_gemm(Up, G, ranks, impl=impl)
    m = valid[:, None, None]
    left = jnp.where(m, left, jnp.zeros_like(left))
    right = jnp.where(m, jnp.broadcast_to(Up[0][None], Up.shape),
                      jnp.zeros_like(Up))
    pad = ((0, 0), (0, 0), (0, w_acc - r_p))
    off = jnp.take(offsets, oidx)
    roll = jax.vmap(lambda x, s: jnp.roll(x, s, axis=-1))
    accU = accU.at[oidx].add(roll(jnp.pad(left, pad), off))
    accV = accV.at[oidx].add(roll(jnp.pad(right, pad), off))
    if ldl:
        Gd = jnp.einsum("br,b,bq->rq", V0, dk, V0)
    else:
        Gd = jnp.einsum("br,bq->rq", V0, V0)
    upd = Up[0] @ Gd @ Up[0].T
    upd = jnp.where(dvalid, upd, jnp.zeros_like(upd))
    D = D.at[didx].add(-upd)
    return accU, accV, D


_syrk_head_core = jax.jit(_syrk_head_body, static_argnames=("ldl", "impl"))
_syrk_head_core_donated = jax.jit(_syrk_head_body,
                                  static_argnames=("ldl", "impl"),
                                  donate_argnums=(0, 1, 3))


@obs.traced("algebra.syrk_column", cat="algebra")
def tlr_syrk_column(accU, accV, used, D, Up, Vn, ranks, dk, k: int, *,
                    impl=None, part: str = "all", donate: bool = False):
    """Column-scoped SYRK: eagerly apply factor column ``k``'s trailing
    Schur update ``A(i,j) -= L(i,k) D_k L(j,k)^T`` for all i >= j > k.

    The right-looking driver's per-column counterpart of :func:`tlr_syrk`:
    instead of summing ``j`` inner products per output tile after the fact,
    each trailing tile receives column ``k``'s *single* rank-``r_p`` outer
    product the moment the column panel is factored. Off-diagonal trailing
    tiles get the term appended as a concatenated factor pair at column
    ``used`` of the ``(nt, b, W)`` accumulation buffers (growing factors
    between rounding passes -- see ``tlr_round_tiles``); trailing diagonal
    tiles ``D(j)`` subtract the dense product. The trailing-row batch is
    padded up the power-of-two bucket ladder, so only ~log2(nb) compiled
    accumulation variants serve all columns (trace-counted via
    ``algebra_trace_count``, the same contract as the rest of the algebra).

    Args: ``accU`` / ``accV``: (nt, b, W) accumulation buffers; ``used``:
    the write offset -- either a scalar first-free column (flat batching:
    uniform across live trailing tiles, every tile (i, j) with j > k has
    received exactly one term per factored column) or a per-tile (nt,)
    content-width vector (ranked batching: each tile's concatenation stays
    compact, appends land at its own width); ``D``: (nb, b, b) trailing
    diagonal tiles; ``Up`` / ``Vn`` / ``ranks``: column k's factored panel,
    row i at slot ``i - k - 1``; ``dk``: (b,) LDL^T diagonal of column k,
    or None for Cholesky.

    ``part`` splits the update for the lookahead schedule (DESIGN.md
    section 12): ``"head"`` applies only the tiles of column ``k+1`` plus
    ``D[k+1]`` (the narrow row-batched core), ``"tail"`` the pair-grid
    remainder (``c >= 1`` / trailing diagonals past ``k+1``), and
    ``"head"`` then ``"tail"`` is exactly equivalent to one ``"all"``
    call -- each trailing tile receives its single term from exactly one
    of the two, at the same offset, computed by the same formula.

    ``donate=True`` dispatches the donating compiled variant: the
    ``accU`` / ``accV`` / ``D`` buffers are invalidated and aliased into
    the outputs (zero-copy append). Callers must rebind -- i.e. use the
    returned arrays and never touch the arguments again.

    Returns the updated ``(accU, accV, D)``.
    """
    if part not in ("all", "head", "tail"):
        raise ValueError(f"part must be 'all', 'head' or 'tail', got "
                         f"{part!r}")
    nb = D.shape[0]
    T = nb - 1 - k
    if T <= 0:
        return accU, accV, D
    r_p = Up.shape[-1]
    impl = ops.resolve_impl(impl)
    ladder = _bucket_ladder(nb - 1)
    Tb = _bucket_up(T, ladder)
    w_acc = accU.shape[-1]
    # Gather grids first, masked down to the requested ``part``, so the
    # overflow check below only sees the tiles this call actually appends
    # to (after a "head" call bumped its tiles' widths, the full-grid max
    # would spuriously overflow for the following "tail").
    if part == "head":
        ar = np.arange(Tb)
        validh = (ar >= 1) & (ar < T)
        i = k + 1 + ar
        oidxh = np.where(validh, i * (i - 1) // 2 + (k + 1), 0)
        live = oidxh[validh]
    else:
        oidx, aidx, cidx, valid, didx, dvalid = _syrk_column_indices(
            nb, k, Tb)
        if part == "tail":
            valid = valid & (cidx >= 1)
            dvalid = dvalid & (np.arange(Tb) >= 1)
        live = oidx[valid]
    if np.ndim(used) == 0:
        high = int(used)
        offsets = jnp.full((accU.shape[0],), int(used), jnp.int32)
    else:
        u = np.asarray(used)
        high = int(u[live].max()) if live.size else 0
        offsets = jnp.asarray(u, jnp.int32)
    if high + r_p > w_acc:
        raise ValueError(
            f"no room for a rank-{r_p} append at column {high} of the "
            f"width-{w_acc} accumulation buffers; round first "
            f"(tlr_round_tiles)")
    accU, accV = shard_tile_batch(accU, accV, preserve_shape=True)
    ldl = dk is not None
    Upp = _pad_axis(Up, Tb)
    Vnp = _pad_axis(Vn, Tb)
    rkp = _pad_axis(ranks, Tb)
    if part == "head":
        core = _syrk_head_core_donated if donate else _syrk_head_core
        return core(accU, accV, offsets, D, Upp, Vnp, rkp, dk,
                    jnp.asarray(oidxh.astype(np.int32)),
                    jnp.asarray(validh),
                    jnp.asarray(k + 1, jnp.int32), jnp.asarray(True),
                    ldl=ldl, impl=impl)
    core = _syrk_column_core_donated if donate else _syrk_column_core
    return core(
        accU, accV, offsets, D, Upp, Vnp, rkp, dk,
        *(jnp.asarray(x) for x in (oidx, aidx, cidx, valid, didx, dvalid)),
        ldl=ldl, impl=impl)
