"""Rank-bucketed dynamic batching for the TLR hot paths (DESIGN.md section 8).

Every batched compute path of the tile algebra stores its low-rank factors
zero-padded to a single global ``r_max``, so a matrix whose tile ranks range
4-64 pays QR/SVD/GEMM FLOPs and HBM traffic as if every tile were rank 64.
This module is the TPU-friendly analogue of the paper's *dynamic batching*
(and of MAGMA's pointer marshaling in Boukaram et al., arXiv:1902.01829):
tiles are gathered into rank-homogeneous batches on a power-of-two *rank
ladder*, each bucket runs the batched kernels at its own (much narrower)
bucket width, and the results scatter back into the padded storage layout.

Shape discipline (the same contract as ``core/buckets.py``): both the rank
axis and the batch-count axis of every bucket are padded up power-of-two
ladders, so at most ``~log2(r_max) * log2(nt)`` executables compile per
kernel family -- never one per rank distribution. The compile count is a
real, process-wide counter (``batching_trace_count()``) pinned by
``tests/test_batching.py``, mirroring ``algebra_trace_count`` /
``trsm_trace_count``.

Soundness rests on one storage invariant: factor columns past each tile's
``ranks`` entry are exactly zero (DESIGN.md section 1), so slicing a tile's
factors to any width >= its rank is *exact*, not an approximation -- the
error model of every rounding pass is unchanged. Tiles in the rank-0 bucket
are skipped entirely (no QR, no SVD, no phantom rank-1 regrowth; the PR 4
rank-floor semantics extend to the bucketed path).

The module also hosts the tile-batch sharding hook (ROADMAP "sharded tile
algebra"): ``set_tile_mesh(mesh)`` makes the embarrassingly-parallel
accumulation batches of ``tlr_gemm`` / ``tlr_syrk_column`` place their
leading (output-tile) axis across the mesh's data axes, with a no-mesh /
single-device fallback that is the identity.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .buckets import (_bucket_ladder, _bucket_up, _pad_axis, trace_count,
                      trace_event)
from ..kernels import ops
from .. import obs


BATCHINGS = ("flat", "ranked", "auto")


def resolve_batching(batching: str | None, ranks=None, cap: int = 0) -> str:
    """Validate and resolve a ``batching`` knob up front
    (``CholOptions.batching``, the algebra entry points).

    ``"flat"`` is the compatibility path: one r_max-wide batch, exactly the
    pre-bucketing behavior. ``"auto"`` asks the rank-histogram policy to
    decide (DESIGN.md section 9) and therefore needs the per-tile ``ranks``
    (and their ``cap``); entry points that carry no rank information reject
    it here rather than silently falling back.
    """
    batching = batching or "flat"
    if batching not in BATCHINGS:
        raise ValueError(
            f"batching must be one of {BATCHINGS}, got {batching!r}")
    if batching == "auto":
        if ranks is None:
            raise ValueError(
                "batching='auto' needs the per-tile ranks to inspect; this "
                "entry point has none -- pass 'flat' or 'ranked' explicitly")
        return choose_batching(tile_plan(ranks, cap))
    return batching


# -- trace accounting ----------------------------------------------------------

# One entry per freshly compiled bucket-core variant, recorded in the unified
# keyed registry of ``core/buckets.py`` under the "batching" key. The python
# body of a jitted core runs exactly once per compile, so this is a real
# compile count: it must stay O(log2(r_max) * log2(nt)) per shape family and
# *never* scale with the number of tiles or with the rank distribution (the
# contract tests/test_batching.py pins, mirroring ``algebra_trace_count``).


def batching_trace_count() -> int:
    """Compiled rank-bucket core variants so far (process-wide); a view of
    ``trace_count("batching")`` in the unified registry."""
    return trace_count("batching")


# -- bucket planning (host side) -----------------------------------------------


def rank_ladder(cap: int) -> list[int]:
    """The power-of-two rank ladder [1, 2, 4, ..., cap]."""
    return _bucket_ladder(int(cap))


def bucket_width(ranks, cap: int, floor: int = 1) -> int:
    """Smallest ladder width covering every rank in ``ranks`` (host side).

    The "slice the whole stack" form of rank bucketing: a batched chain whose
    operand stack holds ranks 3-23 inside width-64 storage can run at ladder
    width 32 exactly (columns past each rank are zero). ``floor`` keeps
    degenerate all-zero stacks at a 1-wide batch instead of a 0-width array.
    """
    if cap <= 0:
        return 0
    rk = np.asarray(ranks)
    m = int(rk.max()) if rk.size else 0
    m = min(max(m, floor), int(cap))
    return _bucket_up(m, rank_ladder(cap))


@dataclasses.dataclass(frozen=True)
class RankBucket:
    """One rank-homogeneous batch: ``idx`` (host gather indices) of the
    tiles whose rank buckets up to ``width``; the batch count is padded up
    the count ladder to ``padded`` slots (trailing slots are zero tiles)."""

    width: int
    idx: np.ndarray
    count: int
    padded: int


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Host-side dispatch plan: rank buckets plus the skipped rank-0 set."""

    n: int
    cap: int
    buckets: tuple[RankBucket, ...]
    zero_idx: np.ndarray

    @property
    def zero_count(self) -> int:
        return int(self.zero_idx.shape[0])


@dataclasses.dataclass(frozen=True)
class TilePlan(BatchPlan):
    """The reusable execution plan every batched path dispatches through
    (DESIGN.md section 9).

    Extends the rounding-only :class:`BatchPlan` with the per-tile data the
    *read* paths (TRSM, matvec, tri_matvec, sampling) need: a host snapshot
    of the ranks, the per-tile ladder width each rank buckets up to, and
    rank-histogram summaries the auto policy decides from. Computed once per
    operator/factorization generation through :func:`tile_plan` (memoized on
    the ranks array; a new ranks array -- every functional update makes one
    -- gets a new plan).
    """

    ranks_host: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    widths: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))

    @property
    def max_rank(self) -> int:
        return int(self.ranks_host.max(initial=0))

    @property
    def median_rank(self) -> float:
        """Median over the *positive* ranks (rank-0 tiles never touch a
        kernel, so they say nothing about useful batch width)."""
        live = self.ranks_host[self.ranks_host > 0]
        return float(np.median(live)) if live.size else 0.0

    @property
    def rank_skew(self) -> float:
        """max/median rank -- the histogram statistic the auto policy
        thresholds on (>= 4 means the flat r_max-wide batch pads most
        tiles by 4x or worse)."""
        med = self.median_rank
        return float(self.max_rank) / med if med > 0 else 1.0

    @property
    def max_width(self) -> int:
        """Smallest ladder width covering every rank (0 for all-zero)."""
        return int(self.widths.max(initial=0))

    def padded_cols(self) -> int:
        """Factor columns the ranked dispatch touches: sum of bucket-padded
        count x bucket width (count-ladder zero tiles included)."""
        return sum(bk.padded * bk.width for bk in self.buckets)

    def useful_cols(self) -> int:
        """Factor columns that actually carry data: sum of the ranks."""
        return int(self.ranks_host.sum())

    def flat_cols(self) -> int:
        """Factor columns the flat r_max-wide dispatch touches."""
        return self.n * self.cap

    def padded_flop_ratio(self) -> float:
        """Padded-vs-useful work of the flat path relative to the ranked
        one, for any kernel whose arithmetic is linear in the dispatched
        factor columns (the two-product read chains; QR is superlinear, so
        this is a floor for the rounding cores). Recorded in ``stats`` by
        the auto policy; >= 1, with 1.0 meaning bucketing cannot help."""
        ranked = self.padded_cols()
        return float(self.flat_cols()) / float(ranked) if ranked else 1.0

    def bucket_flops(self, b: int, r_out: int | None = None, *,
                     dtype=np.float64, impl: str | None = None) -> list[float]:
        """Per-bucket XLA ``cost_analysis`` FLOPs of the rounding core at
        each bucket's true dispatch shape (``kernels/ops.py::flop_estimate``;
        lowers + compiles, nothing executes; cached process-wide by shape).
        One entry per ``self.buckets`` element."""
        return [_round_core_flops(bk.padded, b, bk.width,
                                  min(r_out or b, bk.width), dtype,
                                  ops.resolve_impl(impl))
                for bk in self.buckets]

    def flat_flops(self, b: int, r_out: int | None = None, *,
                   dtype=np.float64, impl: str | None = None) -> float:
        """The flat path's rounding-core FLOPs at the full (n, b, cap)
        dispatch shape -- the denominator of the measured (not analytic)
        padded-vs-useful ratio ``flat_flops / sum(bucket_flops)``."""
        if self.n == 0 or self.cap == 0:
            return 0.0
        return _round_core_flops(self.n, b, self.cap, min(r_out or b, b),
                                 dtype, ops.resolve_impl(impl))


def _flops_cache_key(n, b, w, r_out, dtype, impl):
    return (int(n), int(b), int(w), int(r_out), np.dtype(dtype).str, impl)


_ROUND_FLOPS_CACHE: dict[tuple, float] = {}


def _round_core_flops(n, b, w, r_out, dtype, impl) -> float:
    """``flop_estimate`` of the rank-bucket rounding core at one dispatch
    shape, cached process-wide (lower+compile once per shape, like the jit
    cache itself)."""
    key = _flops_cache_key(n, b, w, r_out, dtype, impl)
    hit = _ROUND_FLOPS_CACHE.get(key)
    if hit is not None:
        return hit
    from .algebra import _round_factors_impl

    U = jax.ShapeDtypeStruct((int(n), int(b), int(w)), np.dtype(dtype))
    eps = jax.ShapeDtypeStruct((), np.dtype(dtype))
    fl = ops.flop_estimate(
        partial(_round_factors_impl, r_out=int(r_out), rel=False, impl=impl),
        U, U, eps)
    _ROUND_FLOPS_CACHE[key] = fl
    return fl


def plan_rank_buckets(ranks, cap: int) -> TilePlan:
    """Group tile indices by ``bucket_up(rank)`` on the rank ladder.

    Runs on the host (the per-tile ranks are pulled once per dispatch --
    the same host orchestration the paper's dynamic batching and the
    left-looking driver's Algorithm 5 eviction loop already do). Rank-0
    tiles land in ``zero_idx`` and never touch a kernel. Prefer
    :func:`tile_plan`, which memoizes the result on the ranks array.
    """
    rk = np.asarray(ranks).astype(np.int64).reshape(-1)
    n = int(rk.shape[0])
    ladder = np.asarray(rank_ladder(cap), np.int64)
    cladder = _bucket_ladder(n)
    zero = rk <= 0
    zero_idx = np.nonzero(zero)[0].astype(np.int32)
    buckets = []
    widths = np.zeros(n, np.int64)
    if n and ladder.size:
        pos = np.searchsorted(ladder, np.clip(rk, 1, int(ladder[-1])))
        pos = np.minimum(pos, ladder.size - 1)
        widths = np.where(zero, 0, ladder[pos])
        for p in sorted(set(pos[~zero].tolist())):
            idx = np.nonzero((pos == p) & ~zero)[0].astype(np.int32)
            cnt = int(idx.shape[0])
            buckets.append(RankBucket(width=int(ladder[p]), idx=idx,
                                      count=cnt,
                                      padded=_bucket_up(cnt, cladder)))
    return TilePlan(n=n, cap=int(cap), buckets=tuple(buckets),
                    zero_idx=zero_idx, ranks_host=rk, widths=widths)


# -- plan memoization (one plan per operator/factorization generation) ---------

_PLAN_CACHE: OrderedDict[tuple[int, int], tuple] = OrderedDict()
_PLAN_CACHE_SIZE = 32


def _ranks_fingerprint(ranks) -> tuple | None:
    """Cheap content checksum for *mutable* host rank arrays (the
    right-looking driver's ``tile_w`` is updated in place); device arrays
    are immutable, so identity alone is a sound cache key for them."""
    if isinstance(ranks, np.ndarray):
        rk = ranks.reshape(-1)
        return (int(rk.shape[0]), int(rk.sum()), int(rk.max(initial=0)))
    return None


def tile_plan(ranks, cap: int) -> TilePlan:
    """The memoized :class:`TilePlan` for this ranks array at this cap.

    Keyed on the *identity* of the ranks array (plus a content checksum for
    host arrays, which unlike device arrays can mutate in place): every
    functional update of a ``TLRMatrix`` builds a new ranks array, so a new
    operator/factorization generation invalidates its plan automatically,
    while repeated reads (every matvec of a PCG loop, every TRSM of a
    multi-solve) reuse the plan without re-pulling ranks to the host. The
    cache holds strong references to the last ``_PLAN_CACHE_SIZE`` rank
    arrays, so an entry's ``id`` can never be recycled while it is live.
    """
    key = (id(ranks), int(cap))
    hit = _PLAN_CACHE.get(key)
    if hit is not None:
        ref, fp, plan = hit
        if ref is ranks and fp == _ranks_fingerprint(ranks):
            _PLAN_CACHE.move_to_end(key)
            return plan
        del _PLAN_CACHE[key]
    plan = plan_rank_buckets(ranks, cap)
    _PLAN_CACHE[key] = (ranks, _ranks_fingerprint(ranks), plan)
    while len(_PLAN_CACHE) > _PLAN_CACHE_SIZE:
        _PLAN_CACHE.popitem(last=False)
    return plan


# -- the auto policy (cost-model-driven knobs; DESIGN.md section 9) ------------

# "ranked" pays off when the flat r_max-wide batch mostly multiplies zeros:
# the decision statistic is the rank histogram's max/median (the ROADMAP
# heuristic), with >= 4 meaning a typical tile wastes 4x its useful width.
RANK_SKEW_RANKED = 4.0


def choose_batching(plan: TilePlan) -> str:
    """Histogram rule: "ranked" when max/median rank >= 4 and there is
    anything to bucket; "flat" otherwise (uniform ranks gain nothing from
    bucketing and the flat path has no gather/scatter marshaling)."""
    if plan.n == 0 or plan.max_rank == 0:
        return "flat"
    return "ranked" if plan.rank_skew >= RANK_SKEW_RANKED else "flat"


def resolve_policy(batching: str | None, plan: TilePlan, *, b: int,
                   dtype=np.float64, right_flush: int = 0) -> dict:
    """Resolve the ``batching`` / ``right_flush`` knobs against a plan and
    return the decision record the drivers put in ``stats["policy"]``.

    ``batching="auto"`` applies :func:`choose_batching`; explicit values
    pass through (the record still carries the histogram so the choice is
    auditable). ``right_flush=0`` means auto: flat keeps the tuned default
    of 2 accumulated columns between flushes, while ranked appends land at
    each tile's own bucket width (~the median width, not r_max), so the
    same accumulation window absorbs ~cap/median_width columns -- the
    cost-model estimate below picks the flush cadence that fills it.
    """
    requested = batching or "auto"
    if requested not in BATCHINGS:
        raise ValueError(
            f"batching must be one of {BATCHINGS}, got {requested!r}")
    decision = choose_batching(plan) if requested == "auto" else requested
    med_w = _bucket_up(max(int(np.ceil(plan.median_rank)), 1),
                       rank_ladder(plan.cap)) if plan.cap else 1
    if right_flush:
        flush = max(1, int(right_flush))
    elif decision == "ranked":
        flush = max(2, min(8, plan.cap // max(med_w, 1)))
    else:
        flush = 2
    from ..launch.costmodel import tile_batch_cost

    est = tile_batch_cost([(bk.padded, bk.width) for bk in plan.buckets],
                          n=plan.n, b=b, cap=plan.cap,
                          itemsize=np.dtype(dtype).itemsize)
    return {
        "requested": requested,
        "batching": decision,
        "right_flush": flush,
        "rank_max": plan.max_rank,
        "rank_median": plan.median_rank,
        "rank_skew": plan.rank_skew,
        "bucket_widths": [bk.width for bk in plan.buckets],
        "padded_flop_ratio": plan.padded_flop_ratio(),
        **est,
    }


# -- jitted bucket cores -------------------------------------------------------


@partial(jax.jit, static_argnames=("r_out", "rel", "impl"))
def _round_bucket(U, V, eps, *, r_out: int, rel: bool, impl: str):
    """One rank bucket's recompression at its own width (<= b): batched QR
    of both factor stacks + small-SVD of the width x width core."""
    trace_event("batching")
    from .algebra import _round_factors_impl

    return _round_factors_impl(U, V, eps, r_out=r_out, rel=rel, impl=impl)


@partial(jax.jit, static_argnames=("r_out", "rel", "impl"))
def _densify_round_bucket(U, V, ranks, eps, *, r_out: int, rel: bool,
                          impl: str):
    """Bucket whose accumulated width exceeds the tile size: densify at the
    bucket width (cheaper *and* exact for b x b tiles), then compress."""
    trace_event("batching")
    from .algebra import _compress_dense_impl

    dense = ops.batched_gemm(U, jnp.swapaxes(V, 1, 2),
                             ranks.astype(jnp.int32), impl=impl)
    return _compress_dense_impl(dense, eps, r_out=r_out, rel=rel, impl=impl)


def _pad_width(x: jax.Array, width: int) -> jax.Array:
    if x.shape[-1] == width:
        return x
    pad = [(0, 0)] * x.ndim
    pad[-1] = (0, width - x.shape[-1])
    return jnp.pad(x, pad)


def bucketed_round_tiles(U, V, ranks, eps, r_out=None, *, rel: bool = False,
                         impl=None):
    """Rank-bucketed rounding pass: the ``batching="ranked"`` counterpart of
    ``tlr_round_tiles`` / the core of ranked ``tlr_round``.

    ``U`` / ``V`` are ``(N, b, W)`` factor stacks whose per-tile meaningful
    width is bounded by ``ranks`` (columns past it are zero -- the layout
    invariant; accumulated concatenations use the axpy width convention).
    Tiles are gathered into rank buckets, each bucket recompresses at its
    ladder width (factored QR + core SVD when the width fits the tile size,
    densify-then-compress above it), and results scatter back into one
    ``(N, b, r_out)`` output. Rank-0 tiles are skipped outright: their
    output is the zero factor pair at rank 0 with zero rounding error.

    Returns ``(U, V, ranks, err)`` with identical truncation semantics to
    the flat pass -- parity is exact up to floating-point reduction order.
    """
    impl = ops.resolve_impl(impl)
    N, b, w_in = U.shape
    r_out = r_out or min(w_in, b)
    dtype = U.dtype
    outU = jnp.zeros((N, b, r_out), dtype)
    outV = jnp.zeros((N, b, r_out), dtype)
    out_ranks = jnp.zeros((N,), jnp.int32)
    out_err = jnp.zeros((N,), dtype)
    if N == 0:
        return outU, outV, out_ranks, out_err
    eps = jnp.asarray(eps, dtype)
    plan = tile_plan(ranks, w_in)
    if _TILE_MESH["mesh"] is not None:
        # End-to-end sharding: place the scatter bases so every bucket's
        # results land sharded over the mesh (the drivers' panel / flush
        # outputs inherit this placement), and each bucket's gathered
        # stack so the rounding cores themselves run data-parallel.
        outU, outV = shard_tile_batch(outU, outV, preserve_shape=True)
    for bk in plan.buckets:
        attrs = {}
        if obs.enabled():
            attrs = bucket_span_attrs(plan, bk, b, r_out, dtype, impl)
        with obs.span("round.bucket", cat="algebra", **attrs):
            idx = jnp.asarray(bk.idx)
            Ug = _pad_axis(jnp.take(U, idx, axis=0)[:, :, :bk.width],
                           bk.padded)
            Vg = _pad_axis(jnp.take(V, idx, axis=0)[:, :, :bk.width],
                           bk.padded)
            if _TILE_MESH["mesh"] is not None:
                Ug, Vg = shard_tile_batch(Ug, Vg, preserve_shape=True)
            if bk.width <= b:
                Ub, Vb, rb, eb = _round_bucket(
                    Ug, Vg, eps, r_out=min(r_out, bk.width), rel=rel,
                    impl=impl)
            else:
                rg = _pad_axis(jnp.take(jnp.asarray(ranks), idx), bk.padded)
                Ub, Vb, rb, eb = _densify_round_bucket(
                    Ug, Vg, rg, eps, r_out=min(r_out, b), rel=rel, impl=impl)
            n = bk.count
            outU = outU.at[idx].set(_pad_width(Ub[:n], r_out))
            outV = outV.at[idx].set(_pad_width(Vb[:n], r_out))
            out_ranks = out_ranks.at[idx].set(rb[:n])
            out_err = out_err.at[idx].set(eb[:n].astype(dtype))
    return outU, outV, out_ranks, out_err


def bucket_span_attrs(plan: TilePlan, bk: RankBucket, b: int, r_out: int,
                      dtype, impl) -> dict:
    """Telemetry attributes for one rank-bucket launch (enabled mode only):
    the dispatched (``flops_padded``, cost_analysis at the true dispatch
    shape -- width > b uses the densify path's shape, a close proxy) vs.
    useful (scaled by the bucket's true rank mass over its padded
    ``count x width`` slots) FLOPs, plus the HBM traffic of the gather +
    scatter marshaling."""
    fl_pad = _round_core_flops(bk.padded, b, min(bk.width, b),
                               min(r_out, bk.width), dtype,
                               ops.resolve_impl(impl))
    useful = float(plan.ranks_host[bk.idx].sum())
    fl = fl_pad * useful / float(bk.padded * bk.width)
    itemsize = np.dtype(dtype).itemsize
    nbytes = 2 * (bk.padded * b * bk.width + bk.count * b * r_out) * itemsize
    return {"width": bk.width, "count": bk.count, "padded": bk.padded,
            "flops": fl, "flops_padded": fl_pad, "bytes": nbytes}


# -- tile-batch sharding hook (ROADMAP: sharded tile algebra) ------------------

TILE_MESH_MODES = ("pad", "error")

_TILE_MESH = {"mesh": None, "on_indivisible": "pad"}


def set_tile_mesh(mesh, *, on_indivisible: str = "pad"):
    """Install (or clear, with ``None``) the mesh that the tile-algebra
    batches shard their leading output-tile axis over. Returns the
    previously installed mesh so callers can restore it.

    ``on_indivisible`` decides what :func:`shard_tile_batch` does when a
    batch axis does not divide the mesh's DP axis size -- there is no
    silent identity fallback any more:

    * ``"pad"`` (default): zero-pad the leading axis up to the next
      multiple and shard the padded array. Zero tiles are numerically
      inert in every accumulation path, and the index-driven gathers /
      scatters of the tile algebra never reference the trailing pad
      slots, so results are unchanged. Call sites that must keep the
      caller-visible shape (``preserve_shape=True``) replicate instead.
    * ``"error"``: raise ``ValueError`` with the offending sizes, so a
      topology mismatch fails at the first sharded dispatch instead of
      silently running replicated.
    """
    if on_indivisible not in TILE_MESH_MODES:
        raise ValueError(f"on_indivisible must be one of {TILE_MESH_MODES}, "
                         f"got {on_indivisible!r}")
    prev = _TILE_MESH["mesh"]
    _TILE_MESH["mesh"] = mesh
    _TILE_MESH["on_indivisible"] = on_indivisible
    return prev


def tile_mesh():
    return _TILE_MESH["mesh"]


def tile_dp_size() -> int:
    """Size of the installed mesh's data-parallel axes (1 when no mesh)."""
    mesh = _TILE_MESH["mesh"]
    if mesh is None:
        return 1
    from ..launch.mesh import dp_axes

    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)], initial=1))


def pad_tile_batch(n: int) -> int:
    """Smallest batch count >= ``n`` divisible by the installed mesh's DP
    size (``n`` itself without a mesh). The drivers size their persistent
    tile-batch buffers with this so every sharded dispatch divides."""
    dp = tile_dp_size()
    return int(-(-n // dp) * dp) if n else n


def shard_tile_batch(*arrays, preserve_shape: bool = False):
    """Place each array's leading (tile-batch) axis across the installed
    mesh's data axes (``launch/sharding.py``); identity when no mesh is
    set -- the single-device fallback.

    The accumulation batches of ``tlr_gemm`` / ``tlr_syrk`` /
    ``tlr_syrk_column`` are embarrassingly parallel over output tiles, so
    sharding their inputs lets XLA keep the whole batched update local to
    each shard (one batched call per column, no cross-tile dependencies).

    When the axis does not divide the mesh's DP size, the installed
    ``on_indivisible`` mode decides (see :func:`set_tile_mesh`): ``"pad"``
    zero-pads the leading axis up to the next multiple (callers must be
    index-driven or slice back -- the tile algebra's gathers never touch
    the pad slots), ``"error"`` raises. ``preserve_shape=True`` marks call
    sites whose output shape must match the input (persistent driver
    state, scatter bases): they shard when divisible and replicate
    otherwise under ``"pad"``; ``"error"`` still raises.
    """
    mesh = _TILE_MESH["mesh"]
    if mesh is None:
        return arrays[0] if len(arrays) == 1 else arrays
    from ..launch.sharding import tile_batch_sharding

    dp = tile_dp_size()
    mode = _TILE_MESH["on_indivisible"]
    out = []
    for x in arrays:
        n = int(x.shape[0])
        if dp > 1 and n % dp != 0:
            if mode == "error":
                raise ValueError(
                    f"tile-batch axis of size {n} does not divide the "
                    f"mesh's data-parallel size {dp} "
                    f"(mesh {dict(mesh.shape)}); pad the batch to a "
                    f"multiple of {dp} (see pad_tile_batch) or install "
                    f"the mesh with on_indivisible='pad'")
            if not preserve_shape:
                x = _pad_axis(x, pad_tile_batch(n))
        sh = tile_batch_sharding(mesh, int(x.shape[0]), x.ndim)
        out.append(x if sh is None else jax.device_put(x, sh))
    return out[0] if len(out) == 1 else tuple(out)
