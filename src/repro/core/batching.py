"""Rank-bucketed dynamic batching for the TLR hot paths (DESIGN.md section 8).

Every batched compute path of the tile algebra stores its low-rank factors
zero-padded to a single global ``r_max``, so a matrix whose tile ranks range
4-64 pays QR/SVD/GEMM FLOPs and HBM traffic as if every tile were rank 64.
This module is the TPU-friendly analogue of the paper's *dynamic batching*
(and of MAGMA's pointer marshaling in Boukaram et al., arXiv:1902.01829):
tiles are gathered into rank-homogeneous batches on a power-of-two *rank
ladder*, each bucket runs the batched kernels at its own (much narrower)
bucket width, and the results scatter back into the padded storage layout.

Shape discipline (the same contract as ``core/buckets.py``): both the rank
axis and the batch-count axis of every bucket are padded up power-of-two
ladders, so at most ``~log2(r_max) * log2(nt)`` executables compile per
kernel family -- never one per rank distribution. The compile count is a
real, process-wide counter (``batching_trace_count()``) pinned by
``tests/test_batching.py``, mirroring ``algebra_trace_count`` /
``trsm_trace_count``.

Soundness rests on one storage invariant: factor columns past each tile's
``ranks`` entry are exactly zero (DESIGN.md section 1), so slicing a tile's
factors to any width >= its rank is *exact*, not an approximation -- the
error model of every rounding pass is unchanged. Tiles in the rank-0 bucket
are skipped entirely (no QR, no SVD, no phantom rank-1 regrowth; the PR 4
rank-floor semantics extend to the bucketed path).

The module also hosts the tile-batch sharding hook (ROADMAP "sharded tile
algebra"): ``set_tile_mesh(mesh)`` makes the embarrassingly-parallel
accumulation batches of ``tlr_gemm`` / ``tlr_syrk_column`` place their
leading (output-tile) axis across the mesh's data axes, with a no-mesh /
single-device fallback that is the identity.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .buckets import _bucket_ladder, _bucket_up, _pad_axis
from ..kernels import ops


BATCHINGS = ("flat", "ranked")


def resolve_batching(batching: str | None) -> str:
    """Validate a ``batching`` knob up front (``CholOptions.batching``,
    the algebra entry points). ``"flat"`` is the compatibility path: one
    r_max-wide batch, exactly the pre-bucketing behavior."""
    batching = batching or "flat"
    if batching not in BATCHINGS:
        raise ValueError(
            f"batching must be one of {BATCHINGS}, got {batching!r}")
    return batching


# -- trace accounting ----------------------------------------------------------

# One entry per freshly compiled bucket-core variant. The python body of a
# jitted core runs exactly once per compile, so this is a real compile count:
# it must stay O(log2(r_max) * log2(nt)) per shape family and *never* scale
# with the number of tiles or with the rank distribution (the contract
# tests/test_batching.py pins, mirroring ``algebra_trace_count``).
_BATCHING_TRACES = {"count": 0}


def batching_trace_count() -> int:
    """Compiled rank-bucket core variants so far (process-wide)."""
    return _BATCHING_TRACES["count"]


# -- bucket planning (host side) -----------------------------------------------


def rank_ladder(cap: int) -> list[int]:
    """The power-of-two rank ladder [1, 2, 4, ..., cap]."""
    return _bucket_ladder(int(cap))


def bucket_width(ranks, cap: int, floor: int = 1) -> int:
    """Smallest ladder width covering every rank in ``ranks`` (host side).

    The "slice the whole stack" form of rank bucketing: a batched chain whose
    operand stack holds ranks 3-23 inside width-64 storage can run at ladder
    width 32 exactly (columns past each rank are zero). ``floor`` keeps
    degenerate all-zero stacks at a 1-wide batch instead of a 0-width array.
    """
    if cap <= 0:
        return 0
    rk = np.asarray(ranks)
    m = int(rk.max()) if rk.size else 0
    m = min(max(m, floor), int(cap))
    return _bucket_up(m, rank_ladder(cap))


@dataclasses.dataclass(frozen=True)
class RankBucket:
    """One rank-homogeneous batch: ``idx`` (host gather indices) of the
    tiles whose rank buckets up to ``width``; the batch count is padded up
    the count ladder to ``padded`` slots (trailing slots are zero tiles)."""

    width: int
    idx: np.ndarray
    count: int
    padded: int


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Host-side dispatch plan: rank buckets plus the skipped rank-0 set."""

    n: int
    cap: int
    buckets: tuple[RankBucket, ...]
    zero_idx: np.ndarray

    @property
    def zero_count(self) -> int:
        return int(self.zero_idx.shape[0])


def plan_rank_buckets(ranks, cap: int) -> BatchPlan:
    """Group tile indices by ``bucket_up(rank)`` on the rank ladder.

    Runs on the host (the per-tile ranks are pulled once per dispatch --
    the same host orchestration the paper's dynamic batching and the
    left-looking driver's Algorithm 5 eviction loop already do). Rank-0
    tiles land in ``zero_idx`` and never touch a kernel.
    """
    rk = np.asarray(ranks).astype(np.int64).reshape(-1)
    n = int(rk.shape[0])
    ladder = np.asarray(rank_ladder(cap), np.int64)
    cladder = _bucket_ladder(n)
    zero = rk <= 0
    zero_idx = np.nonzero(zero)[0].astype(np.int32)
    buckets = []
    if n and ladder.size:
        pos = np.searchsorted(ladder, np.clip(rk, 1, int(ladder[-1])))
        pos = np.minimum(pos, ladder.size - 1)
        for p in sorted(set(pos[~zero].tolist())):
            idx = np.nonzero((pos == p) & ~zero)[0].astype(np.int32)
            cnt = int(idx.shape[0])
            buckets.append(RankBucket(width=int(ladder[p]), idx=idx,
                                      count=cnt,
                                      padded=_bucket_up(cnt, cladder)))
    return BatchPlan(n=n, cap=int(cap), buckets=tuple(buckets),
                     zero_idx=zero_idx)


# -- jitted bucket cores -------------------------------------------------------


@partial(jax.jit, static_argnames=("r_out", "rel", "impl"))
def _round_bucket(U, V, eps, *, r_out: int, rel: bool, impl: str):
    """One rank bucket's recompression at its own width (<= b): batched QR
    of both factor stacks + small-SVD of the width x width core."""
    _BATCHING_TRACES["count"] += 1
    from .algebra import _round_factors_impl

    return _round_factors_impl(U, V, eps, r_out=r_out, rel=rel, impl=impl)


@partial(jax.jit, static_argnames=("r_out", "rel", "impl"))
def _densify_round_bucket(U, V, ranks, eps, *, r_out: int, rel: bool,
                          impl: str):
    """Bucket whose accumulated width exceeds the tile size: densify at the
    bucket width (cheaper *and* exact for b x b tiles), then compress."""
    _BATCHING_TRACES["count"] += 1
    from .algebra import _compress_dense_impl

    dense = ops.batched_gemm(U, jnp.swapaxes(V, 1, 2),
                             ranks.astype(jnp.int32), impl=impl)
    return _compress_dense_impl(dense, eps, r_out=r_out, rel=rel, impl=impl)


def _pad_width(x: jax.Array, width: int) -> jax.Array:
    if x.shape[-1] == width:
        return x
    pad = [(0, 0)] * x.ndim
    pad[-1] = (0, width - x.shape[-1])
    return jnp.pad(x, pad)


def bucketed_round_tiles(U, V, ranks, eps, r_out=None, *, rel: bool = False,
                         impl=None):
    """Rank-bucketed rounding pass: the ``batching="ranked"`` counterpart of
    ``tlr_round_tiles`` / the core of ranked ``tlr_round``.

    ``U`` / ``V`` are ``(N, b, W)`` factor stacks whose per-tile meaningful
    width is bounded by ``ranks`` (columns past it are zero -- the layout
    invariant; accumulated concatenations use the axpy width convention).
    Tiles are gathered into rank buckets, each bucket recompresses at its
    ladder width (factored QR + core SVD when the width fits the tile size,
    densify-then-compress above it), and results scatter back into one
    ``(N, b, r_out)`` output. Rank-0 tiles are skipped outright: their
    output is the zero factor pair at rank 0 with zero rounding error.

    Returns ``(U, V, ranks, err)`` with identical truncation semantics to
    the flat pass -- parity is exact up to floating-point reduction order.
    """
    impl = ops.resolve_impl(impl)
    N, b, w_in = U.shape
    r_out = r_out or min(w_in, b)
    dtype = U.dtype
    outU = jnp.zeros((N, b, r_out), dtype)
    outV = jnp.zeros((N, b, r_out), dtype)
    out_ranks = jnp.zeros((N,), jnp.int32)
    out_err = jnp.zeros((N,), dtype)
    if N == 0:
        return outU, outV, out_ranks, out_err
    eps = jnp.asarray(eps, dtype)
    plan = plan_rank_buckets(ranks, w_in)
    for bk in plan.buckets:
        idx = jnp.asarray(bk.idx)
        Ug = _pad_axis(jnp.take(U, idx, axis=0)[:, :, :bk.width], bk.padded)
        Vg = _pad_axis(jnp.take(V, idx, axis=0)[:, :, :bk.width], bk.padded)
        if bk.width <= b:
            Ub, Vb, rb, eb = _round_bucket(
                Ug, Vg, eps, r_out=min(r_out, bk.width), rel=rel, impl=impl)
        else:
            rg = _pad_axis(jnp.take(jnp.asarray(ranks), idx), bk.padded)
            Ub, Vb, rb, eb = _densify_round_bucket(
                Ug, Vg, rg, eps, r_out=min(r_out, b), rel=rel, impl=impl)
        n = bk.count
        outU = outU.at[idx].set(_pad_width(Ub[:n], r_out))
        outV = outV.at[idx].set(_pad_width(Vb[:n], r_out))
        out_ranks = out_ranks.at[idx].set(rb[:n])
        out_err = out_err.at[idx].set(eb[:n].astype(dtype))
    return outU, outV, out_ranks, out_err


# -- tile-batch sharding hook (ROADMAP: sharded tile algebra) ------------------

_TILE_MESH = {"mesh": None}


def set_tile_mesh(mesh):
    """Install (or clear, with ``None``) the mesh that the tile-algebra
    accumulation batches shard their leading output-tile axis over. Returns
    the previously installed mesh so callers can restore it."""
    prev = _TILE_MESH["mesh"]
    _TILE_MESH["mesh"] = mesh
    return prev


def tile_mesh():
    return _TILE_MESH["mesh"]


def shard_tile_batch(*arrays):
    """Place each array's leading (tile-batch) axis across the installed
    mesh's data axes (``launch/sharding.py``); identity when no mesh is set
    or the axis does not divide -- the single-device fallback.

    The accumulation batches of ``tlr_gemm`` / ``tlr_syrk`` /
    ``tlr_syrk_column`` are embarrassingly parallel over output tiles, so
    sharding their inputs lets XLA keep the whole batched update local to
    each shard (one batched call per column, no cross-tile dependencies).
    """
    mesh = _TILE_MESH["mesh"]
    if mesh is None:
        return arrays[0] if len(arrays) == 1 else arrays
    from ..launch.sharding import tile_batch_sharding

    out = []
    for x in arrays:
        sh = tile_batch_sharding(mesh, int(x.shape[0]), x.ndim)
        out.append(x if sh is None else jax.device_put(x, sh))
    return out[0] if len(out) == 1 else tuple(out)
