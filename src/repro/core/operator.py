"""Operator-first public API: ``TLROperator`` and ``TLRFactorization``.

The paper's end-to-end workflow (compress -> factor -> solve/logdet/sample,
section 6) is exposed as two pytree-registered handles:

* ``TLROperator`` wraps the ``TLRMatrix`` representation with construction
  and algebra: classmethod constructors (``compress`` / ``from_dense`` /
  ``from_kernel``) that route through the *batched* compression path (one
  batched SVD or batched ARA over all nt tiles, no per-tile host loop),
  ``.matvec`` / ``@``, ``.to_dense``, ``.memory_stats``, and
  ``.cholesky(opts)`` / ``.ldlt(opts)`` returning a factorization handle.
  Shape/dtype follow the ``scipy.sparse.linalg.LinearOperator`` convention.
* ``TLRFactorization`` is the active result handle of the left-looking
  factorizations: ``.solve(y)`` (single or batched right-hand sides through
  the jitted bucketed TRSM), ``.logdet()``, ``.sample(key, num)``,
  ``.tri_matvec(x, trans=...)``, and ``.serve()`` (a continuous-batching
  inference server with this handle resident; ``repro.serve``, DESIGN.md
  section 10). As a *preconditioner* its operator action
  is ``A^{-1}``, so ``.matvec`` aliases ``.solve`` -- anything with a
  ``.matvec`` plugs into ``pcg`` directly.

Both handles are registered pytrees: factor/tile arrays are data leaves,
the tile permutation and host-side stats are static aux data, so handles
pass transparently through ``jax.tree`` utilities.

Every read path (``matvec``, ``tri_matvec``, the TRSM solves, ``sample``)
and every batched algebra method takes a ``batching`` knob defaulting to
``"auto"``: the memoized :func:`~.batching.tile_plan` of the operator's
ranks decides flat vs rank-bucketed dispatch (DESIGN.md section 9). The
pre-PR-2 free function ``from_dense`` survives as a deprecated shim; the
``tlr_factor_solve`` / ``tlr_logdet`` / ``mvn_sample`` shims were removed
in PR 6 (DESIGN.md section 5).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .ara import ARAParams, ara_compress_dense
from .tlr import TLRMatrix, tril_pairs
from . import solve as _solve


# -- batched tile compression (construction hot path) --------------------------


def _split_tiles(A: jax.Array, nb: int, b: int):
    """One reshape-based gather of all tiles: diag (nb,b,b) + lower (nt,b,b)."""
    Ab = A.reshape(nb, b, nb, b).transpose(0, 2, 1, 3)
    diag = jnp.arange(nb)
    D = Ab[diag, diag]
    pairs = tril_pairs(nb)
    if len(pairs):
        tiles = Ab[pairs[:, 0], pairs[:, 1]]
    else:
        tiles = jnp.zeros((0, b, b), A.dtype)
    return D, tiles


@partial(jax.jit, static_argnames=("r_max", "rel"))
def _svd_compress_tiles(tiles, eps, *, r_max: int, rel: bool):
    """Batched truncated SVD of (nt, b, b) tiles at the ``from_dense``
    truncation semantics: keep singular values > eps (absolute) or
    > eps * s_max (relative), 0 <= rank <= r_max, columns past the rank
    zeroed (the layout's load-bearing invariant, DESIGN.md section 1).
    A numerically-zero tile compresses to rank 0 (all-zero factors) --
    the same floor the algebra's rounding pass uses, so compression and
    ``tlr_round`` agree on what a zero tile is (a rank-1 phantom factor
    would skew ``memory_stats`` and every rank-masked GEMM)."""
    b = tiles.shape[1]
    k = min(r_max, b)
    Ub, s, Vt = jnp.linalg.svd(tiles, full_matrices=False)
    cut = eps * (s[:, :1] if rel else jnp.ones_like(s[:, :1]))
    ranks = jnp.clip(jnp.sum(s > cut, axis=1), 0, r_max).astype(jnp.int32)
    mask = (jnp.arange(k)[None, :] < ranks[:, None]).astype(tiles.dtype)
    U = Ub[:, :, :k] * (s[:, None, :k] * mask[:, None, :])
    V = jnp.swapaxes(Vt, 1, 2)[:, :, :k] * mask[:, None, :]
    if r_max > k:
        pad = ((0, 0), (0, 0), (0, r_max - k))
        U, V = jnp.pad(U, pad), jnp.pad(V, pad)
    return U, V, ranks


# -- the operator handle -------------------------------------------------------


@dataclasses.dataclass
class TLROperator:
    """Symmetric TLR operator handle wrapping a ``TLRMatrix`` (pytree)."""

    A: TLRMatrix

    # -- scipy.sparse.linalg-style introspection --------------------------

    @property
    def shape(self) -> tuple[int, int]:
        return (self.A.n, self.A.n)

    @property
    def dtype(self):
        return self.A.dtype

    @property
    def nb(self) -> int:
        return self.A.nb

    @property
    def b(self) -> int:
        return self.A.b

    @property
    def n(self) -> int:
        return self.A.n

    @property
    def r_max(self) -> int:
        return self.A.r_max

    @property
    def ranks(self) -> jax.Array:
        return self.A.ranks

    # -- construction -----------------------------------------------------

    @classmethod
    def compress(
        cls,
        dense: Union[jax.Array, np.ndarray],
        tile: int,
        r_max: Optional[int] = None,
        eps: float = 1e-6,
        *,
        rel: bool = False,
        method: str = "svd",
        store_dtype=None,
        bs: int = 16,
        key: Optional[jax.Array] = None,
    ) -> "TLROperator":
        """Compress a dense symmetric matrix into TLR form, batched.

        All nt off-diagonal tiles are gathered with one reshape and
        compressed in a single batched call -- a batched (vmapped) SVD
        (``method="svd"``, rank oracle) or the batched ARA of Algorithm 1
        (``method="ara"``, the paper's sampling-based compressor) -- instead
        of the O(nb^2) per-tile host SVD loop of the old ``from_dense``.

        ``store_dtype``: optional lower precision for the off-diagonal U/V
        factors (the paper's section 7 mixed-precision proposal); diagonal
        tiles stay in the working precision.
        """
        host_dtype = np.asarray(dense).dtype if method == "svd" else None
        A = jnp.asarray(dense)
        n = A.shape[0]
        if n % tile:
            raise ValueError(f"n={n} must be a multiple of tile size b={tile}")
        nb = n // tile
        r_max = r_max or tile
        if host_dtype is not None and host_dtype != A.dtype:
            # jnp.asarray narrowed the input (f64 input, jax_enable_x64 off).
            # Truncating at eps against narrowed SVD noise would destroy the
            # compression (f32 singular-value noise ~1e-7*s_max swamps tight
            # thresholds), so rank detection runs host-side at the input
            # precision -- one *batched* numpy SVD, still no per-tile loop --
            # and only the resulting factors narrow on device, exactly the
            # old from_dense behavior.
            return cls._compress_host(np.asarray(dense), nb, tile, r_max,
                                      eps, rel=rel, store_dtype=store_dtype)
        D, tiles = _split_tiles(A, nb, tile)
        nt = tiles.shape[0]
        if nt == 0:
            U = jnp.zeros((0, tile, r_max), A.dtype)
            V = jnp.zeros((0, tile, r_max), A.dtype)
            ranks = jnp.zeros((0,), jnp.int32)
        elif method == "svd":
            U, V, ranks = _svd_compress_tiles(
                tiles, jnp.asarray(eps, A.dtype), r_max=r_max, rel=rel)
        elif method == "ara":
            if rel:
                raise ValueError("rel thresholds are SVD-only; ARA uses the "
                                 "absolute 2-norm residual estimate")
            p = ARAParams(bs=min(bs, r_max), r_max=r_max, eps=eps)
            key = key if key is not None else jax.random.PRNGKey(0)
            U, B, ranks, _ = ara_compress_dense(tiles, key, p)
            V = B  # tile ~= Q B^T  =>  U=Q, V=B
        else:
            raise ValueError(f"method must be 'svd' or 'ara', got {method!r}")
        if store_dtype is not None:
            sdt = jnp.dtype(store_dtype)
            U, V = U.astype(sdt), V.astype(sdt)
        return cls(TLRMatrix(D=D, U=U, V=V, ranks=ranks))

    @classmethod
    def _compress_host(cls, A: np.ndarray, nb: int, tile: int, r_max: int,
                       eps: float, *, rel: bool, store_dtype) -> "TLROperator":
        """Batched-SVD compression at full host precision (numpy), for f64
        inputs when the device dtype would narrow them. Same truncation
        semantics as ``_svd_compress_tiles``; one batched ``np.linalg.svd``
        call over all nt tiles, no per-tile loop."""
        b = tile
        k = min(r_max, b)
        Ab = A.reshape(nb, b, nb, b).transpose(0, 2, 1, 3)
        D = Ab[np.arange(nb), np.arange(nb)]
        pairs = tril_pairs(nb)
        tiles = (Ab[pairs[:, 0], pairs[:, 1]] if len(pairs)
                 else np.zeros((0, b, b), A.dtype))
        nt = tiles.shape[0]
        U = np.zeros((nt, b, r_max), A.dtype)
        V = np.zeros((nt, b, r_max), A.dtype)
        if nt:
            Ub, s, Vt = np.linalg.svd(tiles, full_matrices=False)
            cut = eps * (s[:, :1] if rel else 1.0)
            # rank floor 0, matching _svd_compress_tiles / tlr_round
            ranks = np.clip((s > cut).sum(axis=1), 0, r_max).astype(np.int32)
            mask = (np.arange(k)[None, :] < ranks[:, None]).astype(A.dtype)
            U[:, :, :k] = Ub[:, :, :k] * (s[:, None, :k] * mask[:, None, :])
            V[:, :, :k] = np.swapaxes(Vt, 1, 2)[:, :, :k] * mask[:, None, :]
        else:
            ranks = np.zeros((0,), np.int32)
        sdt = np.dtype(store_dtype) if store_dtype is not None else A.dtype
        return cls(TLRMatrix(
            D=jnp.asarray(D), U=jnp.asarray(U.astype(sdt)),
            V=jnp.asarray(V.astype(sdt)), ranks=jnp.asarray(ranks)))

    @classmethod
    def from_dense(cls, dense, tile: int, r_max: Optional[int] = None,
                   eps: float = 1e-6, **kw) -> "TLROperator":
        """Alias of :meth:`compress` (scipy-style constructor name)."""
        return cls.compress(dense, tile, r_max, eps, **kw)

    @classmethod
    def from_kernel(
        cls,
        points: np.ndarray,
        kernel: Union[str, Callable[[np.ndarray], np.ndarray]] = "exp",
        *,
        tile: int,
        eps: float = 1e-8,
        ell: Optional[float] = None,
        nugget: float = 1e-8,
        r_max: Optional[int] = None,
        **kw,
    ) -> "TLROperator":
        """Build a covariance operator from a point cloud and a kernel.

        ``kernel`` is ``"exp"`` / ``"matern32"`` (paper section 6.1 kernels,
        with the paper's default correlation lengths per dimension) or any
        callable ``points -> dense (n, n)``. ``points`` must already be in
        tile order (apply ``kd_tree_ordering`` first, or use
        ``covariance_problem``, which returns ordered points) -- the
        operator's rows follow the point order, so reordering internally
        would silently misalign every vector the caller passes later.
        """
        from .generators import exp_covariance, matern32_covariance

        pts = np.asarray(points)
        if callable(kernel):
            K = kernel(pts)
        else:
            ell = ell if ell is not None else (0.1 if pts.shape[1] == 2 else 0.2)
            if kernel == "exp":
                K = exp_covariance(pts, ell, nugget)
            elif kernel == "matern32":
                K = matern32_covariance(pts, ell, nugget)
            else:
                raise ValueError(f"unknown kernel {kernel!r}")
        return cls.compress(jnp.asarray(K), tile, r_max, eps, **kw)

    # -- algebra ----------------------------------------------------------

    def matvec(self, x: jax.Array, *,
               batching: str | None = "auto") -> jax.Array:
        """y = A @ x; x is (n,) or batched (n, m). ``batching`` picks flat
        vs rank-bucketed dispatch (``"auto"`` lets the plan decide)."""
        return _solve.tlr_matvec(self.A, x, batching=batching)

    def plan(self):
        """The memoized :class:`~.batching.TilePlan` for this operator's
        rank distribution (rank buckets, ladder widths, FLOP estimates) --
        the execution plan every batched path dispatches through."""
        from .batching import tile_plan

        return tile_plan(self.A.ranks, self.A.r_max)

    def __matmul__(self, x):
        if isinstance(x, (jax.Array, np.ndarray)):
            return self.matvec(jnp.asarray(x))
        return NotImplemented

    def to_dense(self) -> jax.Array:
        return self.A.to_dense()

    def memory_stats(self) -> dict:
        return self.A.memory_stats()

    def diagonal_tiles(self) -> jax.Array:
        return self.A.D

    def trace(self) -> jax.Array:
        """tr(A): sum of the dense diagonal tiles' diagonals (the
        Newton-Schulz scaling ``alpha = 1/trace``, core/precond.py)."""
        return jnp.einsum("kbb->", self.A.D)

    def diagonal(self) -> jax.Array:
        """diag(A) as an (n,) vector, from the dense diagonal tiles."""
        return jnp.einsum("kbb->kb", self.A.D).reshape(self.n)

    # -- tile-algebra arithmetic (core/algebra.py; DESIGN.md section 6) ----

    def __add__(self, other):
        """A + B, exact low-rank concatenation (ranks add; call
        :meth:`round` to recompress)."""
        from .algebra import tlr_axpy

        if isinstance(other, TLROperator):
            return TLROperator(tlr_axpy(1.0, self.A, other.A))
        return NotImplemented

    def __sub__(self, other):
        from .algebra import tlr_axpy

        if isinstance(other, TLROperator):
            return TLROperator(tlr_axpy(-1.0, other.A, self.A))
        return NotImplemented

    def __mul__(self, alpha):
        from .algebra import tlr_scale

        if isinstance(alpha, (int, float, np.number)) or (
                isinstance(alpha, (jax.Array, np.ndarray))
                and jnp.ndim(alpha) == 0):
            return TLROperator(tlr_scale(alpha, self.A))
        return NotImplemented

    __rmul__ = __mul__

    def __neg__(self):
        return self * -1.0

    def compose(self, other, eps: float = 0.0, r_max_out=None, *, impl=None,
                batching: str = "auto"):
        """C = A @ other as a general (nonsymmetric) ``TLRTiles`` grid,
        compressed at ``eps`` (0.0 keeps everything up to the rank cap;
        pass a real threshold to bound ranks). ``other`` is a
        ``TLROperator``, ``TLRMatrix``, or ``TLRTiles``.
        ``batching="ranked"`` runs the accumulation chains at the
        rank-bucketed widths (core/batching.py); ``"auto"`` (default)
        lets the rank histogram decide."""
        from .algebra import tlr_gemm

        return tlr_gemm(self.A, other, eps, r_max_out, impl=impl,
                        batching=batching)

    def round(self, eps: float, r_max_out=None, *, impl=None,
              batching: str = "auto") -> "TLROperator":
        """Recompress every off-diagonal tile at ``eps`` (one batched
        QR + small-SVD pass, ``core/algebra.py``; ``batching="ranked"``
        dispatches rank-homogeneous buckets instead of one r_max-wide
        batch, DESIGN.md section 8; ``"auto"`` lets the rank histogram
        decide)."""
        from .algebra import tlr_round

        return TLROperator(tlr_round(self.A, eps, r_max_out, impl=impl,
                                     batching=batching))

    # -- factorization ----------------------------------------------------

    def cholesky(self, opts=None) -> "TLRFactorization":
        """TLR Cholesky; returns the factorization handle.

        ``opts.algo`` picks the driver: ``"left"`` (default) is the paper's
        left-looking sampling-chain factorization (Algorithm 6 / 9),
        ``"right"`` the right-looking variant that eagerly applies trailing
        Schur updates through the batched tile algebra (DESIGN.md
        section 7) -- better batch width at small nb, and the layout
        multi-device sharding wants.
        """
        from .cholesky import CholOptions, tlr_cholesky

        return tlr_cholesky(self.A, opts or CholOptions())

    def ldlt(self, opts=None) -> "TLRFactorization":
        """TLR LDL^T (Algorithm 10); returns the handle. ``opts.algo``
        selects left- vs right-looking, as in :meth:`cholesky`."""
        from .cholesky import CholOptions, tlr_ldlt

        return tlr_ldlt(self.A, opts or CholOptions())


jax.tree_util.register_dataclass(
    TLROperator, data_fields=("A",), meta_fields=())


# -- the factorization handle --------------------------------------------------


@dataclasses.dataclass
class TLRFactorization:
    """Active handle for a TLR factorization  P A P^T = L L^T  (or L D L^T).

    ``L.D`` holds the dense diagonal blocks L(k,k) (unit-lower for LDL^T),
    ``d`` the LDL diagonal (None for Cholesky), ``perm`` the tile-level
    pivot permutation (logical -> original), ``stats`` the driver's
    per-column instrumentation. Solves run through the jitted bucketed TRSM
    (``core/solve.py``) and accept single or batched right-hand sides.
    """

    L: TLRMatrix
    d: Optional[jax.Array]
    perm: np.ndarray
    stats: dict

    @property
    def nb(self) -> int:
        return self.L.nb

    @property
    def b(self) -> int:
        return self.L.b

    @property
    def n(self) -> int:
        return self.L.n

    @property
    def shape(self) -> tuple[int, int]:
        return (self.L.n, self.L.n)

    @property
    def dtype(self):
        return self.L.dtype

    @property
    def is_ldlt(self) -> bool:
        return self.d is not None

    def solve(self, y: jax.Array) -> jax.Array:
        """x = A^{-1} y through the factorization; y is (n,) or (n, m)."""
        return _solve._factor_solve_impl(self, y)

    def matvec(self, y: jax.Array) -> jax.Array:
        """Preconditioner action: the operator a factorization applies is
        M^{-1} ~= A^{-1}, so ``matvec`` aliases :meth:`solve` (this is what
        lets a factorization plug into ``pcg`` anywhere an operator fits)."""
        return self.solve(y)

    def tri_matvec(self, x: jax.Array, *, trans: bool = False,
                   batching: str | None = "auto") -> jax.Array:
        """y = L @ x (or L^T @ x)."""
        return _solve.tlr_tri_matvec(self.L, x, trans=trans,
                                     batching=batching)

    def tri_solve(self, y: jax.Array, *, trans: bool = False,
                  batching: str | None = "auto") -> jax.Array:
        """x = L^{-1} y (or L^{-T} y) via the jitted bucketed TRSM
        (``batching`` picks flat vs plan-width column steps)."""
        return _solve.tlr_trsv(self.L, y, trans=trans, batching=batching)

    def plan(self):
        """The memoized :class:`~.batching.TilePlan` of the factor's rank
        distribution (what the TRSM / tri_matvec read paths dispatch on)."""
        from .batching import tile_plan

        return tile_plan(self.L.ranks, self.L.r_max)

    def logdet(self) -> jax.Array:
        """log |det A| from the factorization diagonals."""
        return _solve._logdet_impl(self)

    def sample(self, key: jax.Array, num: int = 1) -> jax.Array:
        """x ~ N(0, A) via x = P^T L z (Cholesky factorizations only)."""
        return _solve._mvn_sample_impl(self, key, num)

    def serve(self, *, operator=None, slots: int = 8, check_every: int = 4,
              seed: int = 0, warmup: bool = True):
        """A :class:`~repro.serve.TLRServer` with this factorization
        resident (fid ``"default"``): continuous-batching solve / logdet /
        sample / pcg_solve through fixed ``(n, slots)`` RHS blocks.

        Pass ``operator`` (the compressed A this handle factors) to enable
        ``pcg_solve`` requests -- the server builds a width-``slots``
        batched PCG engine over it preconditioned by this factorization.
        ``warmup=True`` compiles the serve path before returning, so the
        first tick is already recompile-free (DESIGN.md section 10).
        """
        from ..serve import TLRServer

        srv = TLRServer(slots, check_every=check_every, seed=seed)
        srv.register("default", self, operator=operator)
        if warmup:
            srv.warmup()
        return srv


jax.tree_util.register_dataclass(
    TLRFactorization, data_fields=("L", "d"), meta_fields=("perm", "stats"))
