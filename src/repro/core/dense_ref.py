"""Dense reference oracles for validating the TLR algorithms."""

from __future__ import annotations

import numpy as np


def dense_cholesky(A: np.ndarray) -> np.ndarray:
    return np.linalg.cholesky(np.asarray(A))


def dense_ldlt(A: np.ndarray):
    """Unpivoted LDL^T (textbook column algorithm), for modest n."""
    A = np.asarray(A, dtype=np.float64)
    n = A.shape[0]
    L = np.eye(n)
    d = np.zeros(n)
    for j in range(n):
        d[j] = A[j, j] - (L[j, :j] ** 2) @ d[:j]
        if j + 1 < n:
            L[j + 1 :, j] = (A[j + 1 :, j] - L[j + 1 :, :j] @ (d[:j] * L[j, :j])) / d[j]
    return L, d


def blocked_cholesky_left(A: np.ndarray, b: int) -> np.ndarray:
    """Dense left-looking tiled Cholesky (Algorithm 3), no compression.

    Step-for-step mirror of the paper's Algorithm 3, used to validate the TLR
    factorization column by column.
    """
    A = np.array(A, dtype=np.float64, copy=True)
    n = A.shape[0]
    assert n % b == 0
    nb = n // b

    def blk(M, i, j):
        return M[i * b : (i + 1) * b, j * b : (j + 1) * b]

    L = np.zeros_like(A)
    for k in range(nb):
        acc = blk(A, k, k).copy()
        for j in range(k):
            acc -= blk(L, k, j) @ blk(L, k, j).T
        Lkk = np.linalg.cholesky(acc)
        L[k * b : (k + 1) * b, k * b : (k + 1) * b] = Lkk
        for i in range(k + 1, nb):
            upd = blk(A, i, k).copy()
            for j in range(k):
                upd -= blk(L, i, j) @ blk(L, k, j).T
            # solve X Lkk^T = upd  =>  X = (Lkk^{-1} upd^T)^T
            L[i * b : (i + 1) * b, k * b : (k + 1) * b] = np.linalg.solve(
                Lkk, upd.T
            ).T
    return L


def spectral_norm_est(A, n_iter: int = 30, seed: int = 0) -> float:
    """2-norm estimate via power iteration (paper verifies ||A - LL^T|| this way).

    ``A`` may be a dense ndarray or a callable ``x -> A @ x``.
    """
    if callable(A):
        matvec = A
        # probe dimension lazily: caller must pass vectors of right size; we
        # require dense input to infer n, so callables must wrap a closure
        raise TypeError("pass (matvec, n) via spectral_norm_est_op for callables")
    A = np.asarray(A)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(A.shape[1])
    x /= np.linalg.norm(x)
    sigma = 0.0
    for _ in range(n_iter):
        y = A @ x
        y = A.T @ y
        nrm = np.linalg.norm(y)
        if nrm == 0:
            return 0.0
        x = y / nrm
        sigma = np.sqrt(nrm)
    return float(sigma)


def spectral_norm_est_op(matvec, n: int, n_iter: int = 30, seed: int = 0) -> float:
    """Power-iteration 2-norm estimate for a symmetric operator callable."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n)
    x /= np.linalg.norm(x)
    lam = 0.0
    for _ in range(n_iter):
        y = np.asarray(matvec(x))
        nrm = np.linalg.norm(y)
        if nrm == 0:
            return 0.0
        lam = nrm
        x = y / nrm
    return float(lam)
