"""Tile Low Rank (TLR) symmetric matrix representation.

The matrix is partitioned into an ``nb x nb`` grid of ``b x b`` tiles.
Diagonal tiles are stored dense; each strictly-lower off-diagonal tile
``A(i, j), i > j`` is stored as a low rank factorization ``U V^T`` padded to a
static maximum rank ``r_max`` (XLA requires static shapes; the CUDA original
reallocates per-tile storage instead). The upper triangle is implied by
symmetry: ``A(j, i) = V U^T``.

Packed lower-triangle indexing: tile ``(i, j)`` with ``i > j`` lives at flat
index ``i * (i - 1) // 2 + j``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def tril_index(i: int, j: int) -> int:
    """Flat index of strictly-lower tile (i, j), i > j."""
    if i <= j:
        raise ValueError(f"tril_index requires i > j, got ({i}, {j})")
    return i * (i - 1) // 2 + j


def num_tiles(nb: int) -> int:
    return nb * (nb - 1) // 2


def tril_pairs(nb: int) -> np.ndarray:
    """(nt, 2) array of (i, j) pairs in packed order."""
    out = np.zeros((num_tiles(nb), 2), dtype=np.int64)
    for i in range(1, nb):
        for j in range(i):
            out[tril_index(i, j)] = (i, j)
    return out


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TLRMatrix:
    """Symmetric TLR matrix (pytree).

    Attributes:
      D:     (nb, b, b)      dense diagonal tiles.
      U:     (nt, b, r_max)  left low-rank factors, zero-padded past ``ranks``.
      V:     (nt, b, r_max)  right low-rank factors, zero-padded past ``ranks``.
      ranks: (nt,) int32     per-tile numerical rank (<= r_max).
    """

    D: jax.Array
    U: jax.Array
    V: jax.Array
    ranks: jax.Array

    @property
    def nb(self) -> int:
        return self.D.shape[0]

    @property
    def b(self) -> int:
        return self.D.shape[1]

    @property
    def n(self) -> int:
        return self.nb * self.b

    @property
    def r_max(self) -> int:
        return self.U.shape[2]

    @property
    def dtype(self):
        return self.D.dtype

    # -- conversion ---------------------------------------------------------

    def to_dense(self) -> jax.Array:
        return tlr_to_dense(self.D, self.U, self.V, self.nb, self.b)

    # -- accounting ---------------------------------------------------------

    def memory_stats(self) -> dict:
        """Logical (paper's Sum 2*b*k_ij) and padded byte counts.

        Byte counts follow the *stored* dtypes: diagonal tiles are always
        held in the compute dtype (``D.dtype``); the off-diagonal U/V
        factors may be stored lower-precision (``store_dtype`` under the
        section 7 mixed-precision proposal), and every low-rank byte count
        uses that stored itemsize consistently. ``full_dense_bytes`` /
        ``dense_equivalent_gb`` are what an uncompressed matrix would
        occupy at the compute dtype.
        """
        compute_itemsize = jnp.dtype(self.dtype).itemsize
        store_itemsize = jnp.dtype(self.U.dtype).itemsize  # mixed-prec storage
        ranks = np.asarray(self.ranks)
        dense_bytes = self.D.size * compute_itemsize
        logical_lr = int(2 * self.b * ranks.sum()) * store_itemsize
        padded_lr = (self.U.size + self.V.size) * store_itemsize
        full_dense = self.n * self.n * compute_itemsize
        return {
            "n": self.n,
            "tile_size": self.b,
            "compute_dtype": str(jnp.dtype(self.dtype)),
            "store_dtype": str(jnp.dtype(self.U.dtype)),
            "dense_diag_bytes": int(dense_bytes),
            "lowrank_bytes_logical": int(logical_lr),
            "lowrank_bytes_padded": int(padded_lr),
            "total_bytes_logical": int(dense_bytes + logical_lr),
            "total_bytes_padded": int(dense_bytes + padded_lr),
            "full_dense_bytes": int(full_dense),
            "dense_equivalent_gb": float(full_dense) / 2**30,
            "compression_ratio": float(full_dense)
            / float(dense_bytes + logical_lr),
            "avg_rank": float(ranks.mean()) if ranks.size else 0.0,
            "max_rank": int(ranks.max()) if ranks.size else 0,
        }


def _tile_of(A: jax.Array, i: int, j: int, b: int) -> jax.Array:
    return A[i * b : (i + 1) * b, j * b : (j + 1) * b]


@partial(jax.jit, static_argnums=(3, 4))
def tlr_to_dense(D, U, V, nb: int, b: int):
    n = nb * b
    out = jnp.zeros((n, n), D.dtype)
    for i in range(nb):
        out = out.at[i * b : (i + 1) * b, i * b : (i + 1) * b].set(D[i])
    for i in range(1, nb):
        for j in range(i):
            t = tril_index(i, j)
            block = U[t] @ V[t].T
            out = out.at[i * b : (i + 1) * b, j * b : (j + 1) * b].set(block)
            out = out.at[j * b : (j + 1) * b, i * b : (i + 1) * b].set(block.T)
    return out


def from_dense(
    A: jax.Array | np.ndarray,
    b: int,
    r_max: int,
    eps: float,
    *,
    rel: bool = False,
    store_dtype=None,
) -> TLRMatrix:
    """Deprecated shim: use ``TLROperator.compress`` / ``.from_dense``.

    Same truncation semantics (keep singular values > eps absolute, or
    > eps * s_max with ``rel``; ``store_dtype`` for mixed-precision U/V
    storage), but construction now routes through the batched compression
    path -- one batched SVD over all nt tiles instead of the per-tile host
    SVD loop this function used to run. Returns the bare ``TLRMatrix``.
    """
    from .operator import TLROperator
    from .solve import _deprecated

    _deprecated("from_dense", "TLROperator.compress / TLROperator.from_dense")

    return TLROperator.compress(
        A, b, r_max, eps, rel=rel, store_dtype=store_dtype).A


def zeros_like_structure(nb: int, b: int, r_max: int, dtype) -> TLRMatrix:
    nt = num_tiles(nb)
    return TLRMatrix(
        D=jnp.zeros((nb, b, b), dtype),
        U=jnp.zeros((nt, b, r_max), dtype),
        V=jnp.zeros((nt, b, r_max), dtype),
        ranks=jnp.zeros((nt,), jnp.int32),
    )


def rank_heatmap(A: TLRMatrix) -> np.ndarray:
    """(nb, nb) array of tile ranks (diag = b, upper mirrored) for plots."""
    nb, b = A.nb, A.b
    H = np.zeros((nb, nb), np.int32)
    ranks = np.asarray(A.ranks)
    for i in range(nb):
        H[i, i] = b
    for i in range(1, nb):
        for j in range(i):
            H[i, j] = H[j, i] = ranks[tril_index(i, j)]
    return H
