"""Batched Adaptive Randomized Approximation (ARA), Algorithm 1 / [14].

The operator being compressed is only touched through black-box sampling
closures, which is what lets the TLR factorization compress the *matrix
expression* ``A(i,k) - sum_j L(i,j) L(k,j)^T`` without ever forming it:

  sample_fn(data, Omega) -> Y = Op @ Omega      (T, b, s)
  samplet_fn(data, Q)    -> B = Op^T @ Q        (T, m, R)

``data`` is an explicit pytree of operand arrays (tile gathers); it is an
argument rather than a closure capture so jitted steps are reusable across
the dynamic-batching refills of Algorithm 5.

TPU adaptation (see DESIGN.md section 2): the batch is *uniform* -- every
tile owns a zero-padded rank-``r_max`` basis buffer ``Q`` and a rank counter.
Zero padding makes the padded columns numerically inert (projections against
zero columns are zero), so no masking is needed in the orthogonalization.
Convergence is tracked per tile; the two execution modes differ in who drives
the loop:

* host mode  ("dynamic")  -- python loop + jitted step, convergence pulled to
  host each block-iteration; enables Algorithm 5's converged-tile eviction /
  refill at stable shapes.
* fused mode ("fused")    -- a single ``lax.while_loop`` that runs until every
  tile in the batch converges; one jit for the whole column.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ARAParams:
    bs: int = 16          # number of sample vectors per block iteration
    r_max: int = 128      # basis buffer width (static rank bound)
    eps: float = 1e-6     # absolute threshold on the 2-norm residual estimate
    calib: float = 1.0    # estimator calibration constant
    gs_passes: int = 2    # block Gram-Schmidt passes against Q
    max_iters: int = 0    # 0 => r_max // bs
    qr: str = "householder"  # "householder" (robust) | "cholqr" (TPU-fast)

    @property
    def iters(self) -> int:
        return self.max_iters or max(1, self.r_max // self.bs)


class ARAState(NamedTuple):
    Q: jax.Array          # (T, b, r_max) zero-padded orthonormal bases
    rank: jax.Array       # (T,) int32
    converged: jax.Array  # (T,) bool
    err: jax.Array        # (T,) last residual-norm estimate
    it: jax.Array         # () int32


def init_state(T: int, b: int, p: ARAParams, dtype, valid=None) -> ARAState:
    """Fresh ARA state for a batch of T slots.

    ``valid``: optional (T,) bool mask marking which slots host real tiles.
    Invalid (padding) slots -- the tail of a column batch padded up to a
    bucket size (DESIGN.md section 2) -- start converged at rank 0 with zero
    error, so they never sample, never append, and never hold back the
    all-converged termination test.
    """
    if valid is None:
        converged = jnp.zeros((T,), bool)
        err = jnp.full((T,), jnp.inf, dtype)
    else:
        converged = ~valid
        err = jnp.where(valid, jnp.inf, 0.0).astype(dtype)
    return ARAState(
        Q=jnp.zeros((T, b, p.r_max), dtype),
        rank=jnp.zeros((T,), jnp.int32),
        converged=converged,
        err=err,
        it=jnp.zeros((), jnp.int32),
    )


def rank_overflow(ranks, err, p: ARAParams) -> np.ndarray:
    """Host-side mask of tiles that exhausted the rank budget unconverged.

    A tile overflows when it sits at the cap with a residual estimate
    still above ``p.eps`` (the ``~room`` forced-convergence path of
    :func:`ara_iteration`), or when its error estimate is non-finite --
    the dynamic driver's safety valve records never-processed tiles at
    rank 0 with ``err = inf``, and those need the same remedy ladder
    (eps-loosened re-pass, then densify; DESIGN.md section 13).
    """
    ranks = np.asarray(ranks)
    err = np.asarray(err)
    with np.errstate(invalid="ignore"):
        unconverged = ~(err <= p.eps)          # NaN err counts as overflow
    return ((ranks >= p.r_max) & unconverged) | ~np.isfinite(err)


def _orthonormalize(Y: jax.Array, method: str, drop_tol: float) -> jax.Array:
    """Orthonormalize the (T, b, s) panel; zero out numerically-dead columns.

    Columns whose norm (or orthogonalized residual, via the R diagonal) falls
    below ``drop_tol`` carry no information at the target accuracy and are
    zeroed -- zero columns are inert in all downstream projections. This is
    what keeps the panel QR stable when the sampled spectrum dies inside a
    block (rank-deficient panel).

    ``cholqr`` is the paper's mixed-precision CholeskyQR2 analogue (Gram +
    Cholesky, MXU-friendly); ``householder`` is the robust default used for
    CPU validation.
    """
    col_norm = jnp.linalg.norm(Y, axis=1)                      # (T, s)
    keep = col_norm > drop_tol
    # Relative cut: in a rank-deficient panel the dead directions are
    # normalized numerical noise whose R-diagonal can still exceed an
    # absolute tolerance; keeping one such column (it is NOT orthogonal to
    # the accumulated basis) poisons every later iteration.
    rel = 1e-8 if Y.dtype == jnp.float64 else 1e-4
    if method == "householder":
        Q, R = jnp.linalg.qr(Y)
        rdiag = jnp.abs(jnp.diagonal(R, axis1=-2, axis2=-1))   # (T, s)
        rmax = jnp.max(rdiag, axis=-1, keepdims=True)
        keep = keep & (rdiag > drop_tol) & (rdiag > rel * rmax)
        return Q * keep[:, None, :]

    # CholeskyQR2 on norm-equilibrated columns with trace-scaled jitter.
    cmax = jnp.max(col_norm, axis=-1, keepdims=True)
    keep = keep & (col_norm > rel * cmax)
    Yn = Y / jnp.maximum(col_norm, drop_tol)[:, None, :]
    Yn = Yn * keep[:, None, :]
    s = Y.shape[-1]
    eye = jnp.eye(s, dtype=Y.dtype)
    jit0 = 1e-12 if Y.dtype == jnp.float64 else 1e-5

    def one_pass(Yp):
        G = jnp.einsum("tbs,tbc->tsc", Yp, Yp)
        scale = jnp.maximum(jnp.trace(G, axis1=-2, axis2=-1), 1.0)
        R = jnp.linalg.cholesky(G + jit0 * scale[:, None, None] * eye)
        Yq = jax.scipy.linalg.solve_triangular(
            jnp.swapaxes(R, -1, -2), jnp.swapaxes(Yp, -1, -2), lower=False
        )
        return jnp.swapaxes(Yq, -1, -2)

    Q = one_pass(one_pass(Yn))
    return Q * keep[:, None, :]


def ara_iteration(
    sample_fn: Callable[[Any, jax.Array], jax.Array],
    data: Any,
    state: ARAState,
    key: jax.Array,
    p: ARAParams,
    *,
    share_omega: bool,
    T: int,
    b: int,
) -> ARAState:
    """One block iteration: sample, orthogonalize, estimate, append."""
    dtype = state.Q.dtype
    kit = jax.random.fold_in(key, state.it)
    shape = (b, p.bs) if share_omega else (T, b, p.bs)
    Omega = jax.random.normal(kit, shape, dtype)

    Y = sample_fn(data, Omega)  # (T, b, bs)
    # Two-pass block Gram-Schmidt against the accumulated basis. Padded
    # (zero) columns of Q contribute nothing, so no column masking needed.
    for _ in range(p.gs_passes):
        proj = jnp.einsum("tbr,tbs->trs", state.Q, Y)
        Y = Y - jnp.einsum("tbr,trs->tbs", state.Q, proj)

    # Residual 2-norm estimate from the projected-out samples: for a shared
    # Gaussian probe, max_j ||y_j|| concentrates around the residual norm.
    col_norms = jnp.linalg.norm(Y, axis=1)            # (T, bs)
    err = p.calib * jnp.max(col_norms, axis=1)        # (T,)

    newly = err <= p.eps
    active = ~state.converged & ~newly                # tiles that append
    room = state.rank + p.bs <= p.r_max
    active = active & room

    Qy = _orthonormalize(Y, p.qr, drop_tol=p.eps * 1e-3)
    Qy = jnp.where(active[:, None, None], Qy, jnp.zeros_like(Qy))

    # Append Qy into each tile's buffer at its own rank offset. The write is
    # masked per tile: for inactive tiles (converged or rank buffer full)
    # dynamic_update_slice would CLAMP the out-of-bounds offset and wipe the
    # final appended block with zeros.
    def put(Qi, Qyi, r):
        zero = jnp.zeros((), r.dtype)
        return jax.lax.dynamic_update_slice(Qi, Qyi, (zero, r))

    Q_cand = jax.vmap(put)(state.Q, Qy, state.rank)
    Q = jnp.where(active[:, None, None], Q_cand, state.Q)
    rank = state.rank + jnp.where(active, p.bs, 0)
    converged = state.converged | newly | (~room & ~state.converged)
    err = jnp.where(state.converged, state.err, err)
    return ARAState(Q=Q, rank=rank, converged=converged, err=err,
                    it=state.it + 1)


def run_ara_fused(
    sample_fn, samplet_fn, data, key, *, T: int, b: int, m: int,
    p: ARAParams, dtype, share_omega: bool = True, valid=None,
    project: bool = True,
):
    """Single-jit ARA for a whole batch: while_loop until all tiles converge.

    ``valid`` marks real slots when the batch is zero-padded up to a bucket
    size (see ``init_state``); padding slots are inert.

    ``project=False`` skips the trailing projection ``B = Op^T Q`` and
    returns ``B = None``: the rank-bucketed factorization path
    (``CholOptions.batching="ranked"``) pulls the detected ranks to the
    host first, then projects against ``Q`` sliced to the rank-ladder
    width that covers them (columns of ``Q`` past each tile's rank are
    zero, so the slice is exact) -- the projection chain runs at the
    bucketed width instead of ``r_max``.
    """
    state0 = init_state(T, b, p, dtype, valid=valid)

    def cond(state: ARAState):
        return (~jnp.all(state.converged)) & (state.it < p.iters)

    def body(state: ARAState):
        return ara_iteration(
            sample_fn, data, state, key, p, share_omega=share_omega, T=T, b=b
        )

    state = jax.lax.while_loop(cond, body, state0)
    if not project:
        return state.Q, None, state.rank, state
    B = samplet_fn(data, state.Q)  # (T, m, r_max); cols past rank are zero
    return state.Q, B, state.rank, state


def run_ara_host(
    step_fn, sample_fn, samplet_fn, data, key, *, T: int, b: int,
    p: ARAParams, dtype, share_omega: bool = True,
):
    """Host-driven ARA: python loop, convergence pulled each iteration.

    ``step_fn`` must be (a jitted wrapper of) ``ara_iteration`` partial'd on
    ``sample_fn`` with ``data``/``state``/``key`` as traced args.
    """
    state = init_state(T, b, p, dtype)
    for _ in range(p.iters):
        state = step_fn(data, state, key)
        if bool(jnp.all(state.converged)):
            break
    B = samplet_fn(data, state.Q)
    return state.Q, B, state.rank, state


# -- dense-operand convenience (used by Schur compensation & tests) ----------


def dense_batch_sampler(A: jax.Array):
    """Samplers for a batch of dense operators A: (T, b, m)."""

    def sample(data, Omega):
        if Omega.ndim == 2:
            return jnp.einsum("tbm,ms->tbs", data, Omega)
        return jnp.einsum("tbm,tms->tbs", data, Omega)

    def sample_t(data, Q):
        return jnp.einsum("tbm,tbq->tmq", data, Q)

    return sample, sample_t, A


def ara_compress_dense(
    A: jax.Array, key, p: ARAParams, *, share_omega: bool = True
):
    """Compress a batch of dense matrices (T, b, m) -> (Q, B, ranks)."""
    T, b, m = A.shape
    sample, sample_t, data = dense_batch_sampler(A)
    return run_ara_fused(
        sample, sample_t, data, key, T=T, b=b, m=m, p=p, dtype=A.dtype,
        share_omega=share_omega,
    )
