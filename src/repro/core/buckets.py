"""Bucket-ladder machinery shared by the shape-stable pipelines.

XLA compiles one executable per operand shape, so any host-driven loop whose
batch size changes every step (factorization columns, triangular-solve
columns) would retrace O(nb) times. Padding each batch up to a small ladder
of power-of-two bucket sizes keeps the number of compiled variants at
~log2(nb) (DESIGN.md section 2). Originally private to ``core/cholesky.py``;
hoisted here so the bucketed TRSM in ``core/solve.py`` reuses it without an
import cycle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _bucket_ladder(cap: int) -> list[int]:
    """Powers of two capped at ``cap``: [1, 2, 4, ..., cap]."""
    if cap <= 0:
        return []
    vals = []
    v = 1
    while v < cap:
        vals.append(v)
        v *= 2
    vals.append(cap)
    return vals


def _bucket_up(x: int, ladder: list[int]) -> int:
    """Smallest ladder value >= x."""
    for v in ladder:
        if v >= x:
            return v
    return ladder[-1]


def _column_buckets(nb: int, k: int, ladder: list[int]) -> tuple[int, int]:
    """Coupled (T, J) bucket pair for factorization column ``k``.

    T = nb-1-k and J = k always sum to nb-1, so bucketing T up the ladder
    determines an interval [Tmin, Tb] of columns sharing the compiled step;
    padding J up to nb-1-Tmin covers every column in the interval. The number
    of distinct pairs equals the ladder length, ~log2(nb), instead of one
    executable per column.
    """
    T = nb - 1 - k
    Tb = _bucket_up(T, ladder)
    i = ladder.index(Tb)
    Tmin = (ladder[i - 1] + 1) if i > 0 else 1
    Jb = max(1, nb - 1 - Tmin)
    return Tb, Jb


def _pad_axis(x: jax.Array, size: int, axis: int = 0) -> jax.Array:
    if x.shape[axis] == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, size - x.shape[axis])
    return jnp.pad(x, pad)


# Public aliases: the ladder originally only sized host-loop batches
# (columns, trailing rows); since the rank-bucketed dispatch layer
# (core/batching.py, DESIGN.md section 8) it also sizes the *rank* axis of
# every bucketed kernel, so the names are part of the public vocabulary.
bucket_ladder = _bucket_ladder
bucket_up = _bucket_up


# -- unified trace registry (the compile-count contract, DESIGN.md section 9) --

# One keyed counter per jitted-core family. The python body of a jitted core
# runs exactly once per compile, so ``trace_event(key)`` inside the body is a
# real compile count. Keys in use:
#
#   "trsm"     -- blocked-TRSM column steps (core/solve.py),
#   "algebra"  -- flat algebra cores: rounding pass, GEMM assembly, SYRK
#                 (core/algebra.py),
#   "batching" -- rank-bucketed rounding/densify cores (core/batching.py),
#   "plan"     -- rank-bucketed read-path cores: matvec / tri_matvec chains
#                 driven by a TilePlan (core/solve.py).
#
# Every family must stay O(ladder length) per shape family and never scale
# with the tile count or the rank distribution; the per-family views
# (``trsm_trace_count`` etc.) and the tests that pin them all read this one
# registry, so the contract lives in one place.
_TRACES: dict[str, int] = {}


def trace_event(key: str) -> None:
    """Record one freshly compiled jitted-core variant under ``key``.
    Call only from inside a jitted python body (runs once per compile)."""
    _TRACES[key] = _TRACES.get(key, 0) + 1


def trace_count(key: str | None = None) -> int:
    """Compiled-variant count for one registry key, or the total across
    every family when ``key`` is None (process-wide, monotone)."""
    if key is None:
        return sum(_TRACES.values())
    return _TRACES.get(key, 0)


def trace_counts() -> dict[str, int]:
    """Snapshot of the whole registry (a copy; mutating it is inert)."""
    return dict(_TRACES)


def trace_counts_diff(before: dict[str, int]) -> dict[str, int]:
    """Per-key compile-count deltas since the ``trace_counts()`` snapshot
    ``before``; keys with a zero delta are omitted, so an empty dict means
    "no new compiles anywhere" -- the form every compile-pin test and the
    telemetry layer's retrace counters want::

        snap = trace_counts()
        ...exercise the warmed path...
        assert trace_counts_diff(snap) == {}
    """
    out = {}
    for key, val in _TRACES.items():
        d = val - before.get(key, 0)
        if d:
            out[key] = d
    return out
