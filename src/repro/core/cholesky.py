"""TLR Cholesky / LDL^T drivers: left-looking batched ARA (Algorithms 4-6,
9, 10) and a right-looking variant built on the PR-3 tile algebra.

``CholOptions.algo`` selects the driver; both share the stats schema and
the bucket-ladder shape discipline.

LEFT-LOOKING (``algo="left"``, the paper's driver). Per block column ``k``
(host-driven, like the paper's CUDA host orchestration):

  1. dense diagonal update  A(k,k) -= sum_j L(k,j) L(k,j)^T
     (optionally Schur-compensated, section 5.1.1),
  2. dense Cholesky (or LDL^T) of the diagonal tile, with a modified-Cholesky
     fallback (section 5.1.2),
  3. ARA compression of every updated tile in the column: the matrix
     expression ``A(i,k) - sum_j L(i,j) L(k,j)^T`` is sampled through the
     4-product chain (Eq. 2; 5-product for LDL^T, Eq. 3) -- compression
     happens ONCE per output tile, ab initio,
  4. batched triangular solve  V(i,k) = L(k,k)^{-1} B_i  (+ D^{-1} scaling
     for LDL^T).

Dynamic batching (Algorithm 5): tiles are sorted by their rank in A
descending; a fixed-size slot buffer processes a subset, evicting converged
tiles and refilling from the remainder at *stable shapes* (the TPU-friendly
equivalent of MAGMA pointer-marshaling; see DESIGN.md section 2).

Shape-stable column pipeline (DESIGN.md sections 2-3): the row-batch size
``T = nb-k-1`` and prior-column count ``J = k`` change every column, which
would retrace the jitted ARA step ``nb`` times. Instead each column is
zero-padded up to a (T, J) *bucket pair* drawn from a power-of-two ladder
(``_bucket_ladder``), with a per-slot validity mask making padded slots
numerically inert, so ~log2(nb) compiled variants serve all columns. All
sampling / projection GEMMs route through the ``repro.kernels.ops`` dispatch
layer, selected by ``CholOptions.impl``.

RIGHT-LOOKING (``algo="right"``; DESIGN.md section 7). No sampling chain:
every tile of the trailing matrix is kept *materialized* as an accumulated
low-rank concatenation. Per column ``k``:

  1. dense factor of the diagonal tile -- already fully updated, because
     every earlier column applied its Schur update eagerly,
  2. one batched rounding pass (QR + small-SVD, ``tlr_round_tiles``)
     recompresses the column panel's accumulated factors,
  3. batched TRSM into the panel bases,
  4. the trailing matrix receives column ``k``'s rank-r_k outer product via
     the column-scoped ``tlr_syrk_column`` (core/algebra.py): off-diagonal
     trailing tiles append a concatenated factor pair, diagonal tiles
     subtract the dense product. Appends accumulate for
     ``CholOptions.right_flush`` columns between full rounding passes.

The eager trailing update is embarrassingly parallel over output tiles --
the batch layout the multi-device sharding item in ROADMAP.md wants -- and
trades the left-looking sampling chain for wider batches at small nb.
Inter-tile pivoting (Algorithm 9) is left-looking only.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import types

from . import ara as ara_mod
from .algebra import (algebra_trace_count, tlr_round_tiles, tlr_syrk_column)
from .ara import ARAParams, ara_iteration, init_state, run_ara_fused
from .batching import (batching_trace_count, bucket_width,
                       bucketed_round_tiles, pad_tile_batch, resolve_policy,
                       shard_tile_batch, tile_mesh, tile_plan)
from .buckets import _bucket_ladder, _bucket_up, _column_buckets, _pad_axis
from .health import (FactorizationBreakdown, HealthMonitor,  # noqa: F401
                     RetryPolicy, column_flags)
from .operator import TLRFactorization
from .stages import (LookaheadSchedule, SequentialSchedule, Stage, run_graph)
from .tlr import (TLRMatrix, num_tiles, tril_index, tril_pairs,
                  zeros_like_structure)
from ..kernels import ops
from .. import faults, obs


@dataclasses.dataclass(frozen=True)
class CholOptions:
    eps: float = 1e-6
    bs: int = 16
    r_max_out: int = 0            # 0 => A.r_max
    algo: str = "left"            # "left" (ARA sampling) | "right" (eager updates)
    mode: str = "dynamic"         # "dynamic" | "fused" (left-looking only)
    bucket: int = 0               # 0 => whole column in one batch
    share_omega: bool = True      # share Omega across the column (beyond-paper)
    schur: Optional[str] = "diag" # None | "diag" | "full"
    modified_chol: bool = True
    pivot: Optional[str] = None   # None | "frobenius" | "power"
    ldl: bool = False
    calib: float = 1.0
    gs_passes: int = 2
    max_iters: int = 0            # ARA iteration cap; 0 => r_max // bs
    right_flush: int = 0          # algo="right": columns of rank-r appends
                                  # accumulated between trailing rounding
                                  # passes; 0 => the auto policy picks the
                                  # cadence from the rank histogram
    batching: str = "auto"        # "auto" (rank-histogram policy, DESIGN.md
                                  # section 9) | "flat" (r_max-wide batches,
                                  # compatibility) | "ranked" (rank-bucketed
                                  # dynamic batching, DESIGN.md section 8)
    seed: int = 0
    impl: Optional[str] = None    # None => backend default; "ref" | "interpret" | "pallas"
    lookahead: bool = False       # algo="right": schedule column k+1's
                                  # diag+panel between the head and tail of
                                  # column k's trailing update (DESIGN.md
                                  # section 12); the sequential schedule
                                  # stays the exact-parity default. Ignored
                                  # by algo="left" (its column graph is a
                                  # serial chain).
    check: bool = False           # breakdown detection + bounded recovery
                                  # at stage boundaries (DESIGN.md section
                                  # 13). Off (the default) costs nothing
                                  # and reproduces factors bitwise; on, a
                                  # clean run is also bitwise identical
                                  # (checks only read) at <= a few % wall
                                  # time.
    retry: RetryPolicy = RetryPolicy()
                                  # remedy escalation schedule used when
                                  # ``check`` is on: diagonal jitter on SPD
                                  # breakdown, eps-loosened ARA re-pass +
                                  # per-tile densify on rank overflow.

    def ara_params(self, r_max: int) -> ARAParams:
        return ARAParams(bs=self.bs, r_max=r_max, eps=self.eps,
                         calib=self.calib, gs_passes=self.gs_passes,
                         max_iters=self.max_iters)


# TLRFactorization (the active result handle) lives in core/operator.py;
# the bucket-ladder helpers (DESIGN.md section 2) in core/buckets.py, shared
# with the bucketed TRSM in core/solve.py. Both are re-exported here for the
# existing import sites (tests reach _bucket_ladder through this module).


# -- tile gathers -------------------------------------------------------------


def _row_indices(i: int, k: int) -> list[int]:
    """Packed indices of tiles (i, j) for j < k (requires i >= k)."""
    return [tril_index(i, j) for j in range(k)]


def _gather_L_rows(L: TLRMatrix, rows: np.ndarray, k: int):
    """L tiles (i, j) for each i in rows, j<k: (T, k, b, r) each."""
    idx = np.array([_row_indices(int(i), k) for i in rows], np.int32)
    idx = idx.reshape(len(rows), k)
    return jnp.take(L.U, idx, axis=0), jnp.take(L.V, idx, axis=0)


def _gather_L_row(L: TLRMatrix, i: int, k: int):
    idx = np.array(_row_indices(i, k), np.int32)
    return jnp.take(L.U, idx, axis=0), jnp.take(L.V, idx, axis=0)


def _gather_A_tiles(A: TLRMatrix, pairs: list[tuple[int, int]], perm: np.ndarray):
    """Original-A tiles + ranks for logical (i, j) pairs, resolving the pivot
    perm.

    A logical tile (i, j) maps to original (perm[i], perm[j]); when
    perm[i] < perm[j] the stored tile is its transpose, so the U/V roles swap.
    """
    idx, flip = [], []
    for (i, j) in pairs:
        oi, oj = int(perm[i]), int(perm[j])
        if oi > oj:
            idx.append(tril_index(oi, oj)); flip.append(False)
        else:
            idx.append(tril_index(oj, oi)); flip.append(True)
    idx = np.asarray(idx, np.int32)
    flip = np.asarray(flip)
    U0 = jnp.take(A.U, idx, axis=0)
    V0 = jnp.take(A.V, idx, axis=0)
    ranks = jnp.take(A.ranks, jnp.asarray(idx))
    f = jnp.asarray(flip)[:, None, None]
    Ua = jnp.where(f, V0, U0)
    Va = jnp.where(f, U0, V0)
    return Ua, Va, ranks


# -- sampling closures (Eq. 2 / Eq. 3) ----------------------------------------


def make_column_samplers(ldl: bool, impl: str | None = None):
    """Samplers for the column expression A(i,k) - sum_j L(i,j) D_j L(k,j)^T.

    data = dict(Uk, Vk: (J,b,r) row-k tiles of L;  Ui, Vi: (T,J,b,r) row-i
    tiles;  Ua, Va: (T,b,rA) original A(i,k);  ranksA: (T,) A-tile ranks;
    dk: (J,b) LDL diagonals or None). Omega is (b,s) when shared across the
    column, else (T,b,s). All axes may be zero-padded up to bucket sizes;
    padded tiles are zero, hence numerically inert in every product.

    Every GEMM routes through the ``repro.kernels.ops`` dispatch layer
    (DESIGN.md section 3): the A-term uses the rank-masked ``batched_gemm``,
    the per-j intermediate ``W2 = V(k,j) (U(k,j)^T Omega)`` uses
    ``tile_chain``, and the j-reduction uses the fused ``lr_sample`` kernel
    (shared-Omega path) or a flattened ``tile_chain`` (per-tile Omega).
    """

    def _dk_flat(dk, T, J, b):
        return jnp.broadcast_to(dk[None], (T, J, b)).reshape(T * J, b)

    def sample(data, Omega):
        Ua, Va, Uk, Vk, Ui, Vi = (
            data["Ua"], data["Va"], data["Uk"], data["Vk"],
            data["Ui"], data["Vi"],
        )
        T, b = Ua.shape[0], Ua.shape[1]
        J, r = Uk.shape[0], Uk.shape[2]
        s = Omega.shape[-1]
        shared = Omega.ndim == 2
        Om_t = jnp.broadcast_to(Omega, (T, b, s)) if shared else Omega
        # A-term: Ya[t] = Ua[t][:, :rank_t] @ (Va[t]^T Omega_t)
        VtOm = jnp.einsum("tbr,tbs->trs", Va, Om_t)
        Ya = ops.batched_gemm(Ua, VtOm, data["ranksA"], impl=impl)
        if shared:
            # Hoisted per-column intermediate, then the fused j-reduction.
            OmJ = jnp.broadcast_to(Omega, (J, b, s))
            W2 = ops.tile_chain(Vk, Uk, OmJ, impl=impl)          # (J, b, s)
            if ldl:
                W2 = W2 * data["dk"][:, :, None]
            Yu = ops.lr_sample(Ui, Vi, W2, impl=impl)
        else:
            Uk_r = jnp.broadcast_to(Uk[None], (T, J, b, r)).reshape(T * J, b, r)
            Vk_r = jnp.broadcast_to(Vk[None], (T, J, b, r)).reshape(T * J, b, r)
            Om_r = jnp.broadcast_to(
                Om_t[:, None], (T, J, b, s)).reshape(T * J, b, s)
            W2 = ops.tile_chain(Vk_r, Uk_r, Om_r, impl=impl)
            if ldl:
                W2 = W2 * _dk_flat(data["dk"], T, J, b)[:, :, None]
            Yu = ops.tile_chain(Ui.reshape(T * J, b, r),
                                Vi.reshape(T * J, b, r), W2, impl=impl)
            Yu = Yu.reshape(T, J, b, s).sum(axis=1)
        return Ya - Yu

    def sample_t(data, Q):
        Ua, Va, Uk, Vk, Ui, Vi = (
            data["Ua"], data["Va"], data["Uk"], data["Vk"],
            data["Ui"], data["Vi"],
        )
        T, b = Ua.shape[0], Ua.shape[1]
        J, r = Uk.shape[0], Uk.shape[2]
        R = Q.shape[-1]
        UtQ = jnp.einsum("tbr,tbq->trq", Ua, Q)
        Ba = ops.batched_gemm(Va, UtQ, data["ranksA"], impl=impl)
        # S2[t,j] = Vi[t,j] (Ui[t,j]^T Q[t]);  Bu[t] = sum_j Uk[j] (Vk[j]^T S2)
        Q_r = jnp.broadcast_to(Q[:, None], (T, J, b, R)).reshape(T * J, b, R)
        S2 = ops.tile_chain(Vi.reshape(T * J, b, r),
                            Ui.reshape(T * J, b, r), Q_r, impl=impl)
        if ldl:
            S2 = S2 * _dk_flat(data["dk"], T, J, b)[:, :, None]
        Uk_r = jnp.broadcast_to(Uk[None], (T, J, b, r)).reshape(T * J, b, r)
        Vk_r = jnp.broadcast_to(Vk[None], (T, J, b, r)).reshape(T * J, b, r)
        Bu = ops.tile_chain(Uk_r, Vk_r, S2, impl=impl)
        Bu = Bu.reshape(T, J, b, R).sum(axis=1)
        return Ba - Bu

    return sample, sample_t


# -- diagonal machinery --------------------------------------------------------


def _diag_update_sum(Uk, Vk, dk=None):
    """sum_j L(k,j) D_j L(k,j)^T as a dense (b, b) block."""
    if dk is None:
        G = jnp.einsum("jbr,jbq->jrq", Vk, Vk)
    else:
        G = jnp.einsum("jbr,jb,jbq->jrq", Vk, dk, Vk)
    M = jnp.einsum("jbr,jrq->jbq", Uk, G)
    return jnp.einsum("jbq,jcq->bc", M, Uk)


def _schur_compensate(Akk, Dsum, mode: str, eps: float, bs: int, key):
    """Section 5.1.1: subtract a *compressed* update / diagonal-compensate."""
    b = Akk.shape[0]
    p = ARAParams(bs=min(bs, b), r_max=b, eps=eps)
    Q, B, rank, _ = ara_mod.ara_compress_dense(Dsum[None], key, p)
    Dbar = Q[0] @ B[0].T
    Dbar = 0.5 * (Dbar + Dbar.T)
    if mode == "full":
        # A - Dbar  ==  A - D + (D - Dbar), the PSD-compensated update
        return Akk - Dbar
    # "diag": A - D + diag(rowsum |D - Dbar|)   (diagonal compensation [8])
    comp = jnp.sum(jnp.abs(Dsum - Dbar), axis=1)
    return Akk - Dsum + jnp.diag(comp)


def robust_cholesky(Akk, delta):
    """Dense Cholesky with eigenvalue-clamp fallback (Algorithm 8 analogue).

    The paper repairs failing tiles with a Cheng-Higham modified Cholesky via
    LDL^T; with no pivoted LDL in JAX we use the spectral equivalent: clamp
    eigenvalues to ``delta`` (the minimal-norm symmetric E making A+E PD).
    Returns (L, modified?).
    """
    L = jnp.linalg.cholesky(Akk)
    bad = jnp.any(jnp.isnan(L))

    def fallback(_):
        w, W = jnp.linalg.eigh(Akk)
        w = jnp.maximum(w, delta)
        Amod = (W * w) @ W.T
        Amod = 0.5 * (Amod + Amod.T)
        return jnp.linalg.cholesky(Amod)

    Lout = jax.lax.cond(bad, fallback, lambda _: L, operand=None)
    return Lout, bad


def dense_ldlt_tile(Akk):
    """Unpivoted dense LDL^T of one tile: returns unit-lower L and d (b,)."""
    b = Akk.shape[0]
    dtype = Akk.dtype
    eye = jnp.eye(b, dtype=dtype)
    ar = jnp.arange(b)

    def body(j, carry):
        L, d = carry
        w = jnp.where(ar < j, d * L[j, :], 0.0)
        c = Akk[:, j] - L @ w
        dj = c[j]
        tiny = jnp.asarray(1e-30, dtype)
        dj = jnp.where(jnp.abs(dj) < tiny, tiny, dj)
        col = jnp.where(ar > j, c / dj, 0.0)
        L = L.at[:, j].set(col + eye[:, j])
        d = d.at[j].set(dj)
        return L, d

    L0 = jnp.zeros((b, b), dtype)
    d0 = jnp.zeros((b,), dtype)
    return jax.lax.fori_loop(0, b, body, (L0, d0))


def _factor_diag_tile(Akk, opts: CholOptions, stats: dict):
    """Dense-factor one (fully updated) diagonal tile per the options.

    Shared by both drivers: LDL^T tile factor, or Cholesky with the
    eigenvalue-clamp fallback (``modified_chol`` accounting lands in
    ``stats``). Returns ``(Lkk, dk)`` with ``dk`` None for Cholesky.
    """
    if opts.ldl:
        return dense_ldlt_tile(Akk)
    delta = opts.eps * jnp.maximum(jnp.max(jnp.abs(jnp.diag(Akk))), 1.0)
    if opts.modified_chol:
        Lkk, bad = robust_cholesky(Akk, delta)
        stats["modified_chol"] += int(bad)
    else:
        Lkk = jnp.linalg.cholesky(Akk)
    return Lkk, None


def _jittered(Akk, shift: float):
    """``Akk + shift * scale * I`` -- the escalating-jitter remedy for an
    SPD breakdown (DESIGN.md section 13; the diagonal-shift recovery of
    Chen & Martinsson). ``scale`` is the tile's max |diag| entry (floored
    at 1) so the shift schedule is relative to the tile's magnitude."""
    b = Akk.shape[-1]
    scale = jnp.maximum(jnp.max(jnp.abs(jnp.diag(Akk))), 1.0)
    return Akk + shift * scale * jnp.eye(b, dtype=Akk.dtype)


def _spd_shift(Akk, rp, attempt: int) -> float:
    """Relative jitter for retry ``attempt``: enough to clear the tile's
    most negative eigenvalue (one b x b eigvalsh, failure path only), plus
    the policy's base shift, escalated by ``growth``. A non-finite tile
    gets the bare policy schedule -- no shift fixes a NaN, and the bounded
    ladder is what turns that into a structured breakdown."""
    finite = bool(jnp.all(jnp.isfinite(Akk)))
    base = 0.0
    if finite:
        scale = float(jnp.maximum(jnp.max(jnp.abs(jnp.diag(Akk))), 1.0))
        lam = float(jnp.min(jnp.linalg.eigvalsh(Akk)))
        base = max(0.0, -lam) / scale
    return (base + rp.shift(0)) * rp.growth ** attempt


def _diag_check_hook(k, st, opts, stats, health):
    """Check hook for a diag stage with no panel after it (the last
    column in either driver): the panel-boundary hook elsewhere owns the
    jitter ladder, so the trailing diagonal gets its own. Retries
    re-factor the stashed updated tile ``st.col[k]["Akk"]``; exhaustion
    raises with the column's full remedy history."""

    def check():
        c = st.col[k]
        rp = health.policy
        for attempt in range(rp.max_retries + 1):
            pivots = c["dk"] if opts.ldl else jnp.diag(c["Lkk"])
            flags = column_flags(pivots)
            bad = flags[1] > 0 or (not opts.ldl and flags[2] <= 0.0)
            if not bad:
                break
            if attempt >= rp.max_retries:
                health.fail(k, "diag", "spd_breakdown",
                            pivot_index=int(flags[3]),
                            min_pivot=float(flags[2]),
                            nonfinite_pivots=int(flags[1]))
            shift = _spd_shift(c["Akk"], rp, attempt)
            health.record("spd_breakdown", k, "diag", remedy="jitter",
                          attempt=attempt + 1, shift=shift)
            Lkk, dk_new = _factor_diag_tile(_jittered(c["Akk"], shift),
                                            opts, stats)
            if opts.ldl:
                st.dvec = st.dvec.at[k].set(dk_new)
            st.LD = st.LD.at[k].set(Lkk)
            c.update(Lkk=Lkk, dk=dk_new)
        health.columns_checked += 1

    return check


def _final_gate(st, opts, health, b):
    """The returned-factors guarantee: one fused scan over every factor
    array and every pivot before the driver returns. Nothing that reaches
    the caller is non-finite (or non-positive, for Cholesky) -- a failure
    here is a breakdown, never a silently poisoned factorization."""
    if opts.ldl:
        pivots = st.dvec.reshape(-1)
        arrays = (st.LD, st.LU, st.LV)
    else:
        pivots = jnp.diagonal(st.LD, axis1=1, axis2=2).reshape(-1)
        arrays = (st.LU, st.LV)
    flags = column_flags(pivots, arrays)
    if flags[0] > 0 or flags[1] > 0:
        health.fail(-1, "final", "nonfinite_factor",
                    nonfinite=int(flags[0]),
                    nonfinite_pivots=int(flags[1]))
    if not opts.ldl and flags[2] <= 0.0:
        health.fail(int(flags[3]) // b, "final", "spd_breakdown",
                    pivot_index=int(flags[3]) % b,
                    min_pivot=float(flags[2]))


# -- column processing ---------------------------------------------------------


def _build_column_data(A, Lout, rows, k, perm, dvec, ldl,
                       Tb: int | None = None, Jb: int | None = None,
                       wA: int | None = None, wL: int | None = None):
    """Operand gather for one column, zero-padded up to bucket sizes.

    Padding rows/columns are all-zero tiles: every product against them is
    zero, so they are numerically inert; ``valid`` marks the real row slots
    (used to pre-converge the padding in the ARA state).

    ``wA`` / ``wL`` (ranked batching) slice the A-tile and L-tile factor
    stacks to the rank-ladder widths covering their actual ranks -- exact,
    since factor columns past each tile's rank are zero -- so the sampling
    chains run at the bucketed width instead of ``r_max``.
    """
    T = len(rows)
    Tb = T if Tb is None else Tb
    Jb = max(1, k) if Jb is None else Jb
    Ui, Vi = _gather_L_rows(Lout, rows, k)                   # (T, k, b, r)
    Uk, Vk = _gather_L_row(Lout, k, k)                       # (k, b, r)
    Ua, Va, ra = _gather_A_tiles(A, [(int(i), k) for i in rows], perm)
    if wA is not None:
        Ua, Va = Ua[:, :, :wA], Va[:, :, :wA]
    if wL is not None:
        Uk, Vk = Uk[:, :, :wL], Vk[:, :, :wL]
        Ui, Vi = Ui[..., :wL], Vi[..., :wL]
    data = {
        "Ua": _pad_axis(Ua, Tb), "Va": _pad_axis(Va, Tb),
        "ranksA": _pad_axis(ra, Tb),
        "Uk": _pad_axis(Uk, Jb), "Vk": _pad_axis(Vk, Jb),
        "Ui": _pad_axis(_pad_axis(Ui, Jb, axis=1), Tb),
        "Vi": _pad_axis(_pad_axis(Vi, Jb, axis=1), Tb),
        "valid": jnp.arange(Tb) < T,
        "dk": _pad_axis(dvec[:k], Jb) if ldl else None,
    }
    return data


def _trsm(Lkk, dk_new, B, ldl: bool):
    """V(i,k) = L(k,k)^{-1} B_i (paper: batchTrsm); LDL adds D^{-1}."""
    Vnew = jax.vmap(
        lambda Bi: jax.scipy.linalg.solve_triangular(Lkk, Bi, lower=True)
    )(B)
    if ldl:
        # L(i,k) = Q B^T (L D)^{-T}  =>  V(i,k) = D^{-1} L^{-1} B
        Vnew = Vnew / dk_new[None, :, None]
    return Vnew


_SCATTER_TRACES = 0


def _panel_scatter_body(U, V, R, idx, valid, Qn, Vw, rn):
    """Body of the donated ``Lout`` writer both pipelines share.

    One fused executable per row bucket scatters a factored panel (bases,
    scaled factors, ranks) into the output factor's packed-lower stacks.
    ``donate_argnums=(0, 1, 2)`` aliases the three stacks input->output,
    so the per-column write is in-place instead of copying the three
    widest persistent arrays of the factorization (the eager ``at[].set``
    it replaces could never alias: the caller's reference kept the old
    buffer alive). Add-scatter with a masked payload is exact: every
    packed-lower slot is written exactly once across the factorization
    (pivot swaps only permute already-written slots), so targets are
    zero, and padded slots add zero to slot 0. Sharding (when a tile
    mesh placed the stacks) survives the aliasing untouched.

    Jitted once at module scope (below) rather than per pipeline: the
    body is pure, so the compiled variants are shared by every
    factorization in the process -- per-factorization jits here would
    recompile the widest write of the driver on every call.
    """
    global _SCATTER_TRACES
    _SCATTER_TRACES += 1
    m = valid[:, None, None]
    U = U.at[idx].add(jnp.where(m, Qn, jnp.zeros_like(Qn)))
    V = V.at[idx].add(jnp.where(m, Vw, jnp.zeros_like(Vw)))
    R = R.at[idx].add(jnp.where(valid, rn, jnp.zeros_like(rn)))
    return U, V, R


_panel_scatter = jax.jit(_panel_scatter_body, donate_argnums=(0, 1, 2))


def scatter_trace_count() -> int:
    """Process-wide compile count of the shared panel scatter."""
    return _SCATTER_TRACES


class _ColumnPipeline:
    """Per-factorization cache of the shape-stable jitted column steps.

    One jitted callable per role (fused column, dynamic ARA step, projection,
    diagonal update); jax's shape-keyed jit cache plus the bucket ladder keeps
    the number of compiled variants at ~log2(nb). The python body of each
    callable runs exactly once per compiled variant, so the ``traces``
    counters report real compile counts (surfaced in ``stats``).
    """

    def __init__(self, opts: CholOptions, p: ARAParams):
        self.opts = opts
        self.p = p
        self.sample, self.sample_t = make_column_samplers(opts.ldl, opts.impl)
        self.traces = {"column": 0, "project": 0, "diag": 0}
        self._column_traced = False
        self._scatter_t0 = _SCATTER_TRACES
        ldl = opts.ldl
        share = opts.share_omega

        def fused_col(data, Lkk, dk_new, key):
            self._mark("column")
            Tb, b = data["Ua"].shape[0], data["Ua"].shape[1]
            Q, B, ranks, state = run_ara_fused(
                self.sample, self.sample_t, data, key, T=Tb, b=b, m=b,
                p=p, dtype=data["Ua"].dtype, share_omega=share,
                valid=data["valid"],
            )
            return Q, _trsm(Lkk, dk_new, B, ldl), ranks, state.it, state.err

        def fused_sample(data, key):
            # Ranked batching: sampling only -- the projection runs after
            # the detected ranks reach the host, against Q sliced to the
            # rank-ladder width that covers them (see run_ara_fused).
            self._mark("column")
            Tb, b = data["Ua"].shape[0], data["Ua"].shape[1]
            Q, _, ranks, state = run_ara_fused(
                self.sample, self.sample_t, data, key, T=Tb, b=b, m=b,
                p=p, dtype=data["Ua"].dtype, share_omega=share,
                valid=data["valid"], project=False,
            )
            return Q, ranks, state.it, state.err

        def dyn_step(data, state, key):
            self._mark("column")
            Tb, b = state.Q.shape[0], state.Q.shape[1]
            return ara_iteration(self.sample, data, state, key, p,
                                 share_omega=share, T=Tb, b=b)

        def project(data, Q, Lkk, dk_new):
            self._mark("project")
            return _trsm(Lkk, dk_new, self.sample_t(data, Q), ldl)

        def diag_update(Uk, Vk, dk):
            self._mark("diag")
            return _diag_update_sum(Uk, Vk, dk)

        self.fused_col = jax.jit(fused_col)
        self.fused_sample = jax.jit(fused_sample)
        self.dyn_step = jax.jit(dyn_step)
        self.project = jax.jit(project)
        self.diag_update = jax.jit(diag_update)
        self.scatter = _panel_scatter

    def _mark(self, kind: str) -> None:
        self.traces[kind] += 1
        if kind == "column":
            self._column_traced = True

    def begin_column(self) -> None:
        self._column_traced = False

    @property
    def scatter_traces(self) -> int:
        """Fresh compiles of the shared scatter during this factorization
        (0 in the steady state -- the executable cache is process-wide)."""
        return _SCATTER_TRACES - self._scatter_t0

    @property
    def column_traced(self) -> bool:
        """Did the current column trigger a fresh trace of the ARA step?"""
        return self._column_traced


def _column_ara_fused(pipe: _ColumnPipeline, A, Lout, rows, k, perm, dvec,
                      Lkk, dk_new, key, ladder, widths=(None, None)):
    T = len(rows)
    Tb, Jb = _column_buckets(A.nb, k, ladder)
    wA, wL = widths
    data = _build_column_data(A, Lout, rows, k, perm, dvec, pipe.opts.ldl,
                              Tb=Tb, Jb=Jb, wA=wA, wL=wL)
    if pipe.opts.batching == "ranked":
        # Sample-then-project: the projection chain runs at the rank-ladder
        # width covering the detected ranks, not at r_max (exact -- columns
        # of Q past each tile's rank are zero).
        Q, ranks, it, err = pipe.fused_sample(data, key)
        wq = bucket_width(np.asarray(ranks[:T]), pipe.p.r_max)
        Vnew = pipe.project(data, Q[:, :, :wq], Lkk, dk_new)
        Vnew = _pad_axis(Vnew, pipe.p.r_max, axis=2)
    else:
        wq = None
        Q, Vnew, ranks, it, err = pipe.fused_col(data, Lkk, dk_new, key)
    info = {"iters": int(it), "err": np.asarray(err[:T]), "T": T,
            "Tb": Tb, "Jb": Jb, "safety_valve": False, "wQ": wq}
    return Q[:T], Vnew[:T], ranks[:T], info


def _column_ara_dynamic(pipe: _ColumnPipeline, A, Lout, rows, k, perm, dvec,
                        Lkk, dk_new, key, ladder, widths=(None, None)):
    """Algorithm 5: rank-sorted subset with converged-tile eviction/refill."""
    opts, p = pipe.opts, pipe.p
    wA, wL = widths
    T_col = len(rows)
    requested = opts.bucket if opts.bucket > 0 else T_col
    requested = min(requested, T_col)
    Tb_col, Jb = _column_buckets(A.nb, k, ladder)
    Tb = _bucket_up(requested, ladder)
    n_slots = min(Tb, T_col)

    # Sort rows by the rank of the original A tile, descending (section 4.2):
    # big tiles stay in the batch longest, so they enter first.
    a_ranks = np.asarray(A.ranks)
    key_rank = np.array(
        [a_ranks[tril_index(max(int(perm[i]), int(perm[k])),
                            min(int(perm[i]), int(perm[k])))]
         for i in rows]
    )
    order = np.argsort(-key_rank, kind="stable")
    queue = [int(rows[o]) for o in order]

    # Slot state: each slot hosts one tile's ARA run; slots past n_slots are
    # permanent padding (pre-converged via the validity mask).
    slot_rows = queue[:n_slots]
    queue = queue[n_slots:]
    data = _build_column_data(A, Lout, np.asarray(slot_rows), k, perm, dvec,
                              opts.ldl, Tb=Tb, Jb=Jb, wA=wA, wL=wL)
    state = init_state(Tb, A.b, p, A.dtype, valid=data["valid"])

    done_Q = {}
    done_rank = {}
    done_err = {}
    total_iters = 0
    safety_valve = False
    slot_live = [True] * len(slot_rows)

    while any(slot_live):
        state = pipe.dyn_step(data, state, key)
        total_iters += 1
        conv = np.asarray(state.converged)
        # Evict converged tiles; refill their slots from the queue.
        refills = []
        for s, live in enumerate(slot_live):
            if live and conv[s]:
                done_Q[slot_rows[s]] = state.Q[s]
                done_rank[slot_rows[s]] = int(state.rank[s])
                done_err[slot_rows[s]] = float(state.err[s])
                if queue:
                    slot_rows[s] = queue.pop(0)
                    refills.append(s)
                else:
                    slot_live[s] = False
        if refills:
            sr = np.asarray(refills, np.int32)
            new_rows = np.asarray([slot_rows[s] for s in refills])
            nd = _build_column_data(A, Lout, new_rows, k, perm, dvec,
                                    opts.ldl, Tb=len(refills), Jb=Jb,
                                    wA=wA, wL=wL)
            for name in ("Ua", "Va", "ranksA", "Ui", "Vi"):
                data[name] = data[name].at[sr].set(nd[name])
            state = state._replace(
                Q=state.Q.at[sr].set(0.0),
                rank=state.rank.at[sr].set(0),
                converged=state.converged.at[sr].set(False),
                err=state.err.at[sr].set(jnp.inf),
            )
        if any(slot_live) and total_iters > p.iters * max(1, T_col):
            # Safety valve: the iteration budget for the whole column is
            # exhausted. Flush the still-live slots with their current
            # partial bases (best basis accumulated so far) instead of
            # dropping them -- the assembly below indexes done_Q by row, so
            # leaving a live slot unrecorded was a guaranteed KeyError.
            safety_valve = True
            n_live, n_queued = sum(slot_live), len(queue)
            for s, live in enumerate(slot_live):
                if live:
                    done_Q[slot_rows[s]] = state.Q[s]
                    done_rank[slot_rows[s]] = int(state.rank[s])
                    done_err[slot_rows[s]] = float(state.err[s])
                    slot_live[s] = False
            # Rows still queued never entered a slot: record them at rank 0
            # (zero basis => zero tile) with an infinite error estimate so
            # the caller can see they were never processed.
            for i in queue:
                done_Q[i] = jnp.zeros_like(state.Q[0])
                done_rank[i] = 0
                done_err[i] = float("inf")
            warnings.warn(
                f"TLR column {k}: ARA safety valve tripped after "
                f"{total_iters} iterations; {n_live} tile(s) kept their "
                f"partial bases and {n_queued} queued tile(s) were "
                f"recorded at rank 0 -- the factorization is degraded "
                f"(raise max_iters/r_max or loosen eps; see "
                f"stats['safety_valve'])", RuntimeWarning, stacklevel=4)
            queue = []
            break

    # Assemble per-row results in the original row order, then project once
    # (batched, bucket-padded full column) into the bases.
    Q_all = jnp.stack([done_Q[int(i)] for i in rows])
    ranks_h = np.asarray([done_rank[int(i)] for i in rows], np.int32)
    ranks = jnp.asarray(ranks_h)
    full_data = _build_column_data(A, Lout, rows, k, perm, dvec, opts.ldl,
                                   Tb=Tb_col, Jb=Jb, wA=wA, wL=wL)
    if opts.batching == "ranked":
        # Project at the rank-ladder width covering the detected ranks.
        wq = bucket_width(ranks_h, p.r_max)
        Vnew = pipe.project(full_data,
                            _pad_axis(Q_all[:, :, :wq], Tb_col), Lkk, dk_new)
        Vnew = _pad_axis(Vnew, p.r_max, axis=2)
    else:
        wq = None
        Vnew = pipe.project(full_data, _pad_axis(Q_all, Tb_col), Lkk, dk_new)
    info = {"iters": total_iters, "T": T_col, "Tb": Tb, "Jb": Jb,
            "err": np.asarray([done_err[int(i)] for i in rows]),
            "safety_valve": safety_valve, "wQ": wq}
    return Q_all, Vnew[:T_col], ranks, info


# -- main drivers ---------------------------------------------------------------


def _dispatch(A: TLRMatrix, opts: CholOptions) -> TLRFactorization:
    if opts.algo == "right":
        driver = _factorize_right
    elif opts.algo == "left":
        driver = _factorize
    else:
        raise ValueError(f"algo must be 'left' or 'right', got {opts.algo!r}")
    if not obs.enabled():
        return driver(A, opts)
    # Telemetry: one root span per factorization; its subtree becomes the
    # ``stats["telemetry"]`` metrics snapshot (per-phase FLOP/s,
    # padded-vs-useful ratios), with the plan-level analytic ratio from
    # ``stats["policy"]`` copied alongside for parity checks, and the
    # compile-count registry folded in as a counter sample.
    mesh = tile_mesh()
    sched = "lookahead" if (opts.lookahead and opts.algo == "right") \
        else "sequential"
    with obs.span("chol.factorize", cat="factor", algo=opts.algo,
                  nb=A.nb, b=A.b, schedule=sched,
                  devices=(mesh.devices.size if mesh is not None else 1),
                  mesh=(str(dict(mesh.shape)) if mesh is not None else "")
                  ) as root:
        fact = driver(A, opts)
    obs.record_retraces()
    snap = obs.metrics_snapshot(root=root)
    snap["padded_flop_ratio_plan"] = fact.stats["policy"]["padded_flop_ratio"]
    fact.stats["telemetry"] = snap
    return fact


def tlr_cholesky(A: TLRMatrix, opts: CholOptions) -> TLRFactorization:
    """TLR Cholesky: left-looking (Algorithm 6; Algorithm 9 when pivoting)
    or right-looking on the tile algebra, per ``opts.algo``."""
    return _dispatch(A, dataclasses.replace(opts, ldl=False))


def tlr_ldlt(A: TLRMatrix, opts: CholOptions) -> TLRFactorization:
    """TLR LDL^T (Algorithm 10; right-looking variant per ``opts.algo``).
    Pivoting unsupported (paper 5.3)."""
    if opts.pivot is not None:
        raise ValueError("inter-tile pivoting is not defined for LDL^T (section 5.3)")
    return _dispatch(A, dataclasses.replace(opts, ldl=True, schur=None))


def _factorize(A: TLRMatrix, opts: CholOptions) -> TLRFactorization:
    nb, b = A.nb, A.b
    r_out = opts.r_max_out or A.r_max
    p = opts.ara_params(r_out)
    impl = ops.resolve_impl(opts.impl)  # validate the knob up front
    policy = resolve_policy(opts.batching, tile_plan(A.ranks, A.r_max),
                            b=b, dtype=A.dtype,
                            right_flush=opts.right_flush)
    batching = policy["batching"]
    key = jax.random.PRNGKey(opts.seed)

    Lout = zeros_like_structure(nb, b, r_out, A.dtype)
    dvec = jnp.zeros((nb, b), A.dtype) if opts.ldl else None
    perm = np.arange(nb)
    ladder = _bucket_ladder(nb - 1)
    jd = max(1, nb - 1)  # static pad width for the diagonal-update gather
    pipe = _ColumnPipeline(opts, p)
    # Ranked batching: the A-tile gather width is fixed by A's ranks; the
    # L-tile gather width follows the running max of the written factor
    # ranks (monotone up the ladder, so it changes at most ~log2(r_max)
    # times over the whole factorization -- the compile count stays
    # O(log nb + log r_max) instead of multiplying).
    wA = bucket_width(np.asarray(A.ranks), A.r_max) if batching == "ranked" \
        else None
    wL = 1 if batching == "ranked" else None
    stats = {
        "column_iters": [], "column_ranks": [], "modified_chol": 0,
        "pivots": [], "mode": opts.mode, "impl": impl, "algo": "left",
        "bucket_ladder": list(ladder), "column_events": [],
        "column_traces": 0, "project_traces": 0, "diag_traces": 0,
        "safety_valve": False, "batching": batching, "policy": policy,
    }
    health = HealthMonitor(opts.retry, "left", nb) if opts.check else None
    # Rank-overflow remedies re-run the failing rows' ARA pass at a
    # loosened eps. ARAParams.eps is static in the traced step, so each
    # escalation level gets its own (cached, rarely built) pipeline; the
    # re-pass always runs fused over just the overflowing row subset.
    retry_pipes: dict[int, _ColumnPipeline] = {}

    def _retry_pipe(attempt: int) -> _ColumnPipeline:
        if attempt not in retry_pipes:
            o2 = dataclasses.replace(
                opts, eps=opts.retry.eps_at(opts.eps, attempt),
                mode="fused", batching="flat", check=False)
            retry_pipes[attempt] = _ColumnPipeline(o2, o2.ara_params(r_out))
        return retry_pipes[attempt]

    # Mutable factorization state the stage closures share. The left
    # driver's column graph is a serial chain -- diag(k) and panel(k) both
    # gather every previously written L column -- so only the sequential
    # schedule is legal (``opts.lookahead`` is recorded but has nothing to
    # overlap here; the right-looking driver is the lookahead target).
    st = types.SimpleNamespace(
        LD=Lout.D, LU=Lout.U, LV=Lout.V, LR=Lout.ranks, dvec=dvec,
        perm=perm, wL=wL, col=[{} for _ in range(nb)],
        # Pivoted mode keeps running diagonal-update sums (section 5.2).
        Dsum_all=jnp.zeros((nb, b, b), A.dtype) if opts.pivot else None,
    )
    if tile_mesh() is not None:
        st.LU, st.LV, st.LR = shard_tile_batch(st.LU, st.LV, st.LR,
                                               preserve_shape=True)

    def _Lmat() -> TLRMatrix:
        return TLRMatrix(D=st.LD, U=st.LU, V=st.LV, ranks=st.LR)

    def _diag_stage(k: int):
        kkey = jax.random.fold_in(key, k)

        def fn():
            # ---- pivot selection & swap (Algorithm 9 lines 11-14) ----------
            if opts.pivot:
                diag_orig = jnp.take(A.D, jnp.asarray(st.perm[k:], np.int32),
                                     axis=0)
                cand = diag_orig - st.Dsum_all[k:]
                if opts.pivot == "frobenius":
                    norms = jnp.sqrt(jnp.sum(cand * cand, axis=(1, 2)))
                elif opts.pivot == "power":
                    norms = _power_norms(cand, iters=10, key=kkey)
                else:
                    raise ValueError(opts.pivot)
                pidx = k + int(jnp.argmax(norms))
                stats["pivots"].append(pidx)
                if pidx != k:
                    st.perm[[k, pidx]] = st.perm[[pidx, k]]
                    st.Dsum_all = _swap_rows(st.Dsum_all, k, pidx)
                    L = _swap_L_rows(_Lmat(), k, pidx)
                    st.LU, st.LV, st.LR = L.U, L.V, L.ranks

            # ---- diagonal tile: update, compensate, factor -----------------
            with obs.span("chol.diag", cat="factor", k=k):
                Akk = A.D[st.perm[k]]
                if k > 0:
                    Uk, Vk = _gather_L_row(_Lmat(), k, k)
                    if batching == "ranked":
                        Uk, Vk = Uk[:, :, :st.wL], Vk[:, :, :st.wL]
                    dk = _pad_axis(st.dvec[:k], jd) if opts.ldl else None
                    Dsum = pipe.diag_update(_pad_axis(Uk, jd),
                                            _pad_axis(Vk, jd), dk)
                    if opts.schur and not opts.ldl:
                        Akk = _schur_compensate(Akk, Dsum, opts.schur,
                                                opts.eps, opts.bs, kkey)
                    else:
                        Akk = Akk - Dsum
                if faults.active():
                    Akk = faults.corrupt_diag(Akk, k)
                mc0 = stats["modified_chol"]
                Lkk, dk_new = _factor_diag_tile(Akk, opts, stats)
                if opts.ldl:
                    st.dvec = st.dvec.at[k].set(dk_new)
                st.LD = st.LD.at[k].set(Lkk)
                st.col[k].update(Lkk=Lkk, dk=dk_new)
                if health is not None:
                    # Keep the updated (unfactored) tile for jitter retries;
                    # an eigenvalue-clamp repair is itself a health event.
                    st.col[k]["Akk"] = Akk
                    if stats["modified_chol"] > mc0:
                        health.record("spd_breakdown", k, "diag",
                                      remedy="clamp")

        return fn

    def _densify_rows(rows_bad, k, Lkk, dk_new):
        """Last-resort rank-overflow remedy: exact tile expressions via an
        identity probe through the sampling chain, then the *optimal*
        rank-``r_out`` truncation (batched SVD). Factor columns past each
        tile's detected rank are zeroed (the storage invariant)."""
        Tb, Jb = _column_buckets(A.nb, k, ladder)
        Tb = _bucket_up(len(rows_bad), ladder)
        data = _build_column_data(A, _Lmat(), rows_bad, k, st.perm, st.dvec,
                                  opts.ldl, Tb=Tb, Jb=Jb, wA=wA, wL=st.wL)
        E = pipe.sample(data, jnp.eye(b, dtype=A.dtype))[:len(rows_bad)]
        Us, S, Vt = jnp.linalg.svd(E, full_matrices=False)
        keep = min(r_out, b)
        Qd = Us[:, :, :keep]
        Bd = jnp.swapaxes(Vt[:, :keep, :], 1, 2) * S[:, None, :keep]
        tol = S[:, :1] * np.finfo(np.dtype(A.dtype)).eps * b
        rd = jnp.minimum(jnp.sum(S > tol, axis=1), keep).astype(jnp.int32)
        mask = (jnp.arange(keep)[None, None, :] < rd[:, None, None])
        Qd = jnp.where(mask, Qd, 0.0)
        Bd = jnp.where(mask, Bd, 0.0)
        Vd = _trsm(Lkk, dk_new, Bd, opts.ldl)
        ed = np.asarray(S[:, keep], float) if keep < b \
            else np.zeros(len(rows_bad))
        return (_pad_axis(Qd, r_out, axis=2), _pad_axis(Vd, r_out, axis=2),
                rd, ed)

    def _repair_column(k, rows, compute, kkey, Q, Vnew, ranks, ranks_h,
                       info):
        """The panel-boundary decision tree (DESIGN.md section 13): jitter
        escalation on SPD breakdown, hard failure on non-finite panel
        output, eps-loosen + densify on rank overflow."""
        rp = health.policy
        c = st.col[k]
        Tbs = _bucket_up(len(rows), ladder)
        # -- SPD breakdown: escalate diagonal jitter, redo diag + panel --
        for attempt in range(rp.max_retries + 1):
            pivots = c["dk"] if opts.ldl else jnp.diag(c["Lkk"])
            # Bucket-pad the scanned panel (padding is zero => finite and
            # inert) so the flags reduction compiles on the ladder.
            flags = column_flags(pivots, (_pad_axis(Q, Tbs),
                                          _pad_axis(Vnew, Tbs)))
            bad_piv = flags[1] > 0 or (not opts.ldl and flags[2] <= 0.0)
            if not bad_piv:
                break
            if attempt >= rp.max_retries:
                health.fail(k, "panel", "spd_breakdown",
                            pivot_index=int(flags[3]),
                            min_pivot=float(flags[2]),
                            nonfinite_pivots=int(flags[1]))
            shift = _spd_shift(c["Akk"], rp, attempt)
            health.record("spd_breakdown", k, "panel", remedy="jitter",
                          attempt=attempt + 1, shift=shift)
            Lkk, dk_new = _factor_diag_tile(_jittered(c["Akk"], shift),
                                            opts, stats)
            if opts.ldl:
                st.dvec = st.dvec.at[k].set(dk_new)
            st.LD = st.LD.at[k].set(Lkk)
            c.update(Lkk=Lkk, dk=dk_new)
            Q, Vnew, ranks, ranks_h, info = compute()
        # -- non-finite panel output with healthy pivots: unrecoverable --
        if flags[0] > 0:
            health.fail(k, "panel", "nonfinite_panel",
                        nonfinite=int(flags[0]))
        # -- rank overflow: eps-loosened re-pass, then densify -----------
        err_h = np.asarray(info["err"], float).copy()
        over = ara_mod.rank_overflow(ranks_h, err_h, p)
        for attempt in range(1, rp.max_retries + 1):
            if not over.any():
                break
            eps_a = rp.eps_at(opts.eps, attempt)
            pos = np.nonzero(over)[0]
            health.record("rank_overflow", k, "panel",
                          remedy="eps_loosen", attempt=attempt,
                          rows=[int(rows[i]) for i in pos], eps=eps_a)
            Qb, Vb, rb, ib = _column_ara_fused(
                _retry_pipe(attempt), A, _Lmat(), rows[pos], k, st.perm,
                st.dvec, c["Lkk"], c["dk"],
                jax.random.fold_in(kkey, 7000 + attempt), ladder,
                widths=(wA, st.wL))
            posj = jnp.asarray(pos)
            Q = Q.at[posj].set(Qb)
            Vnew = Vnew.at[posj].set(Vb)
            ranks = ranks.at[posj].set(rb)
            ranks_h = np.asarray(ranks)
            err_h[pos] = np.asarray(ib["err"], float)
            over[:] = False
            over[pos] = ara_mod.rank_overflow(
                ranks_h[pos], err_h[pos],
                dataclasses.replace(p, eps=eps_a))
        if over.any() and rp.densify:
            pos = np.nonzero(over)[0]
            health.record("rank_overflow", k, "panel", remedy="densify",
                          rows=[int(rows[i]) for i in pos])
            Qd, Vd, rd, ed = _densify_rows(rows[pos], k, c["Lkk"], c["dk"])
            posj = jnp.asarray(pos)
            Q = Q.at[posj].set(Qd)
            Vnew = Vnew.at[posj].set(Vd)
            ranks = ranks.at[posj].set(rd)
            ranks_h = np.asarray(ranks)
            err_h[pos] = ed
            over[:] = False
            over[pos] = ~(ed <= rp.eps_floor(opts.eps))
        if over.any():
            pos = np.nonzero(over)[0]
            health.fail(k, "panel", "rank_overflow",
                        rows=[int(rows[i]) for i in pos],
                        err=[float(err_h[i]) for i in pos],
                        eps_floor=rp.eps_floor(opts.eps))
        info = dict(info)
        info["err"] = err_h
        return Q, Vnew, ranks, ranks_h, info

    def _panel_stage(k: int):
        kkey = jax.random.fold_in(key, k)
        rows = np.arange(k + 1, nb)
        T = len(rows)
        Tbs = _bucket_up(T, ladder)

        def compute():
            Lkk, dk_new = st.col[k]["Lkk"], st.col[k]["dk"]
            pipe.begin_column()
            with obs.span("chol.panel", cat="factor", k=k) as _psp:
                L = _Lmat()
                if opts.mode == "fused":
                    Q, Vnew, ranks, info = _column_ara_fused(
                        pipe, A, L, rows, k, st.perm, st.dvec, Lkk, dk_new,
                        kkey, ladder, widths=(wA, st.wL))
                else:
                    Q, Vnew, ranks, info = _column_ara_dynamic(
                        pipe, A, L, rows, k, st.perm, st.dvec, Lkk, dk_new,
                        kkey, ladder, widths=(wA, st.wL))
                if faults.active():
                    Q = faults.corrupt_panel(Q, k)
                jax.block_until_ready((Q, Vnew, ranks))
                ranks_h = np.asarray(ranks)
                if obs.enabled():
                    _psp.set(T=info["T"], Tb=info["Tb"], Jb=info["Jb"],
                             iters=info["iters"],
                             rank_hist=obs.rank_hist(ranks_h, r_out))
            return Q, Vnew, ranks, ranks_h, info

        def commit(Q, Vnew, ranks, ranks_h, info, t0):
            dt = time.perf_counter() - t0
            if batching == "ranked":
                st.wL = max(st.wL, bucket_width(ranks_h, r_out))
            stats["column_iters"].append(info["iters"])
            stats["column_ranks"].append(ranks_h)
            stats["safety_valve"] |= info["safety_valve"]
            stats["column_events"].append({
                "k": k, "T": info["T"], "Tb": info["Tb"], "Jb": info["Jb"],
                "seconds": dt, "traced": pipe.column_traced,
                "err": np.asarray(info["err"]), "wQ": info.get("wQ"),
            })

            idxp = np.zeros(Tbs, np.int64)
            idxp[:T] = [tril_index(int(i), k) for i in rows]
            st.LU, st.LV, st.LR = pipe.scatter(
                st.LU, st.LV, st.LR, jnp.asarray(idxp, jnp.int32),
                jnp.asarray(np.arange(Tbs) < T), _pad_axis(Q, Tbs),
                _pad_axis(Vnew, Tbs), _pad_axis(ranks, Tbs))
            if opts.pivot:
                # Dsum_all[i] += L(i,k) L(i,k)^T for the remaining rows.
                G = jnp.einsum("tbr,tbq->trq", Vnew, Vnew)
                upd = jnp.einsum("tbr,trq,tcq->tbc", Q, G, Q)
                st.Dsum_all = st.Dsum_all.at[k + 1 :].add(upd)

        def fn():
            t0 = time.perf_counter()
            out = compute()
            if health is None:
                commit(*out, t0)
            else:
                # Defer the commit to the stage's check hook: the scatter
                # is a donated *add*, so it must happen exactly once --
                # after validation has settled the panel's final content.
                st.col[k]["pending"] = (out, t0)

        def check():
            out, t0 = st.col[k].pop("pending")
            out = _repair_column(k, rows, compute, kkey, *out)
            commit(*out, t0)
            health.columns_checked += 1

        return fn, (check if health is not None else None)

    stages = []
    for k in range(nb):
        # The last column has no panel stage, so its pivots get their own
        # boundary check; every other diag is validated by the following
        # panel's hook (which owns the jitter + recompute ladder).
        dcheck = _diag_check_hook(k, st, opts, stats, health) \
            if health is not None and k + 1 >= nb else None
        stages.append(Stage(
            name=f"diag:{k}", kind="diag", k=k, fn=_diag_stage(k),
            check=dcheck,
            reads=(("L", k - 1),) if k else (), writes=(("Lkk", k),),
            seq=len(stages)))
        if k + 1 < nb:
            pfn, pcheck = _panel_stage(k)
            stages.append(Stage(
                name=f"panel:{k}", kind="panel", k=k, fn=pfn, check=pcheck,
                reads=(("L", k - 1), ("Lkk", k)), writes=(("L", k),),
                seq=len(stages)))
    sched = run_graph(stages, SequentialSchedule())
    sched["requested_lookahead"] = bool(opts.lookahead)
    stats["schedule"] = sched
    stats["column_traces"] = pipe.traces["column"]
    stats["project_traces"] = pipe.traces["project"]
    stats["diag_traces"] = pipe.traces["diag"]
    stats["scatter_traces"] = pipe.scatter_traces
    if health is not None:
        _final_gate(st, opts, health, b)
        stats["health"] = health.summary()
    return TLRFactorization(L=_Lmat(), d=st.dvec, perm=st.perm, stats=stats)


# -- right-looking driver (DESIGN.md section 7) --------------------------------


class _RightPipeline:
    """Per-factorization cache of the jitted right-looking panel step.

    The panel step (densify the accumulated column, one rounding pass,
    batched TRSM) is the only driver-owned executable; the trailing update
    and the flush rounding live in ``core/algebra.py`` behind their own
    trace counter (``algebra_trace_count``). Bucket padding keeps both at
    ~log2(nb) compiled variants, mirroring the left driver's contract.
    """

    def __init__(self, opts: CholOptions, r_p: int, impl: str):
        self.traces = {"column": 0}
        self._column_traced = False
        self._scatter_t0 = _SCATTER_TRACES
        ldl = opts.ldl

        def panel_step(aU, aV, Lkk, dk_new, eps):
            self._mark()
            # One rounding pass over the accumulated panel; ``err`` is the
            # per-tile norm of the discarded singular values -- the
            # right-looking analogue of the ARA error estimate the left
            # driver reports per column, for free from the truncation.
            Q, B, ranks, err = tlr_round_tiles(aU, aV, eps, r_out=r_p,
                                               impl=impl)
            return Q, _trsm(Lkk, dk_new, B, ldl), ranks, err

        def trsm_step(B, Lkk, dk_new):
            # Ranked batching: the panel rounding runs through the rank
            # buckets of core/batching.py (its compiles are counted by
            # batching_trace_count), so only the TRSM remains driver-owned.
            self._mark()
            return _trsm(Lkk, dk_new, B, ldl)

        self.panel_step = jax.jit(panel_step)
        self.trsm = jax.jit(trsm_step)
        self.scatter = _panel_scatter

    def _mark(self, kind: str = "column") -> None:
        self.traces[kind] += 1
        if kind == "column":
            self._column_traced = True

    def begin_column(self) -> None:
        self._column_traced = False

    @property
    def scatter_traces(self) -> int:
        """Fresh compiles of the shared scatter during this factorization
        (0 in the steady state -- the executable cache is process-wide)."""
        return _SCATTER_TRACES - self._scatter_t0

    @property
    def column_traced(self) -> bool:
        return self._column_traced


def _factorize_right(A: TLRMatrix, opts: CholOptions) -> TLRFactorization:
    """Right-looking TLR Cholesky / LDL^T on the batched tile algebra.

    Per column: factor the (already fully-updated) dense diagonal tile,
    round + TRSM the materialized column panel, then eagerly push the
    column's rank-r_k Schur update onto the trailing matrix through
    ``tlr_syrk_column``. Trailing tiles carry growing concatenated factors;
    every ``opts.right_flush`` columns a full rounding pass
    (``tlr_round_tiles``) compacts them. No sampling chain, no ARA --
    ``opts.mode`` / ``bs`` / ``share_omega`` / ``schur`` are left-looking
    knobs and are ignored here.
    """
    if opts.pivot is not None:
        raise ValueError(
            "inter-tile pivoting (Algorithm 9) needs the left-looking "
            "driver's running diagonal-update sums and is not supported "
            f"with algo='right'; use algo='left' (got pivot={opts.pivot!r})")
    nb, b = A.nb, A.b
    nt = num_tiles(nb)
    r_p = opts.r_max_out or A.r_max
    impl = ops.resolve_impl(opts.impl)
    policy = resolve_policy(opts.batching, tile_plan(A.ranks, A.r_max),
                            b=b, dtype=A.dtype,
                            right_flush=opts.right_flush)
    batching = policy["batching"]
    ranked = batching == "ranked"
    dtype = A.dtype
    flush_cols = policy["right_flush"]
    w_acc = max(b, A.r_max) + flush_cols * r_p

    # Accumulation buffers: every off-diagonal tile's running low-rank
    # concatenation, seeded with A's factors. Flat batching tracks one
    # uniform first-free column ``used`` (every tile (i, j) with j > k
    # receives exactly one rank-r_p append per factored column); ranked
    # batching tracks a per-tile content width ``tile_w`` instead -- each
    # trailing tile's concatenation stays compact (appends land at its own
    # width, at the *bucketed panel rank* wk <= r_p), so the accumulation
    # window fills ~r_max/wk times slower and the rounding passes run at
    # each tile's rank-bucket width (core/batching.py). The tile-batch
    # axis is sized to the mesh's sharding quantum (``pad_tile_batch``):
    # trailing pad tiles are zero with width 0 and no gather ever indexes
    # them, so every sharded dispatch divides the data axes exactly.
    mesh = tile_mesh()
    lookahead = bool(opts.lookahead) and nb > 1
    nt_p = pad_tile_batch(nt)
    accU = jnp.zeros((nt_p, b, w_acc), dtype).at[:nt, :, :A.r_max].set(A.U)
    accV = jnp.zeros((nt_p, b, w_acc), dtype).at[:nt, :, :A.r_max].set(A.V)
    if ranked:
        tile_w = np.zeros(nt_p, np.int64)
        tile_w[:nt] = np.asarray(A.ranks, np.int64)
    else:
        tile_w = None
    pairs_np = tril_pairs(nb)
    Lout = zeros_like_structure(nb, b, r_p, dtype)
    ladder = _bucket_ladder(nb - 1)
    pipe = _RightPipeline(opts, r_p, impl)
    alg0 = algebra_trace_count()
    stats = {
        "column_iters": [], "column_ranks": [], "modified_chol": 0,
        "pivots": [], "mode": opts.mode, "impl": impl, "algo": "right",
        "bucket_ladder": list(ladder), "column_events": [],
        "column_traces": 0, "project_traces": 0, "diag_traces": 0,
        "safety_valve": False, "flushes": 0, "acc_width": w_acc,
        "batching": batching, "policy": policy, "append_widths": [],
    }
    eps = jnp.asarray(opts.eps, dtype)
    health = HealthMonitor(opts.retry, "right", nb) if opts.check else None

    # Mutable factorization state shared by the stage closures. ``D`` is
    # copied up front: the trailing update donates it (zero-copy diagonal
    # subtraction), and donating ``A.D`` itself would invalidate the
    # caller's operator.
    st = types.SimpleNamespace(
        accU=accU, accV=accV, used=A.r_max, tile_w=tile_w, D=jnp.array(A.D),
        LD=Lout.D, LU=Lout.U, LV=Lout.V, LR=Lout.ranks,
        dvec=jnp.zeros((nb, b), dtype) if opts.ldl else None,
        col=[{} for _ in range(nb)],
    )
    if mesh is not None:
        st.accU, st.accV = shard_tile_batch(st.accU, st.accV)
        st.D, st.LU, st.LV, st.LR = shard_tile_batch(
            st.D, st.LU, st.LV, st.LR, preserve_shape=True)

    def _diag_stage(k: int):
        # ---- diagonal tile: fully updated by the eager trailing updates ----
        def fn():
            with obs.span("chol.diag", cat="factor", k=k):
                Dk = st.D[k]
                if faults.active():
                    Dk = faults.corrupt_diag(Dk, k)
                mc0 = stats["modified_chol"]
                Lkk, dk_new = _factor_diag_tile(Dk, opts, stats)
                if opts.ldl:
                    st.dvec = st.dvec.at[k].set(dk_new)
                st.LD = st.LD.at[k].set(Lkk)
                st.col[k].update(Lkk=Lkk, dk=dk_new)
                if health is not None:
                    # Keep the updated (unfactored) tile for jitter retries;
                    # an eigenvalue-clamp repair is itself a health event.
                    st.col[k]["Akk"] = Dk
                    if stats["modified_chol"] > mc0:
                        health.record("spd_breakdown", k, "diag",
                                      remedy="clamp")

        return fn

    def _panel_stage(k: int):
        # ---- column panel: one rounding pass + batched TRSM ----------------
        rows = np.arange(k + 1, nb)
        T = len(rows)
        Tb = _bucket_up(T, ladder)
        tidx_np = np.asarray([tril_index(int(i), k) for i in rows], np.int64)
        tidx = jnp.asarray(tidx_np, jnp.int32)
        c = st.col[k]

        def compute():
            Lkk, dk_new = c["Lkk"], c["dk"]
            with obs.span("chol.panel", cat="factor", k=k, T=T,
                          Tb=Tb) as _psp:
                if ranked:
                    # Rank-bucketed panel recompression: each panel tile
                    # rounds at the ladder width covering its tracked
                    # content width, then one jitted TRSM (bucket-padded
                    # row batch) scales the bases.
                    aU = jnp.take(st.accU, tidx, axis=0)
                    aV = jnp.take(st.accV, tidx, axis=0)
                    Q, B, ranks, err = bucketed_round_tiles(
                        aU, aV, st.tile_w[tidx_np], eps, r_out=r_p,
                        impl=impl)
                    Vn = pipe.trsm(_pad_axis(B, Tb), Lkk, dk_new)
                    Qs, Vns = Q, Vn[:T]
                else:
                    aU = _pad_axis(jnp.take(st.accU, tidx, axis=0), Tb)
                    aV = _pad_axis(jnp.take(st.accV, tidx, axis=0), Tb)
                    Q, Vn, ranks, err = pipe.panel_step(aU, aV, Lkk,
                                                        dk_new, eps)
                    Qs, Vns = Q[:T], Vn[:T]
                if faults.active():
                    Qs = faults.corrupt_panel(Qs, k)
                ranks_h = np.asarray(ranks[:T])
                if obs.enabled():
                    _psp.set(rank_hist=obs.rank_hist(ranks_h, r_p))
            return Qs, Vns, ranks, ranks_h, err

        def commit(Qs, Vns, ranks, ranks_h, err):
            # Donated scatter of the factored panel into Lout's stacks
            # (in-place on the three persistent output arrays; sharding
            # survives the aliasing).
            idxp = np.zeros(Tb, np.int64)
            idxp[:T] = tidx_np
            st.LU, st.LV, st.LR = pipe.scatter(
                st.LU, st.LV, st.LR, jnp.asarray(idxp, jnp.int32),
                jnp.asarray(np.arange(Tb) < T), _pad_axis(Qs, Tb),
                _pad_axis(Vns, Tb), _pad_axis(ranks[:T], Tb))
            if ranked:
                # A rank-0 panel column contributes an exactly-zero Schur
                # update, so the trailing update skips it outright -- no
                # append, no content growth, no eventual flush over
                # unchanged buffers (the rank-floor semantics of the zero
                # bucket, extended to the trailing update).
                wk = bucket_width(ranks_h, r_p) \
                    if int(ranks_h.max(initial=0)) else 0
            else:
                wk = r_p
            c.update(Qs=Qs, Vns=Vns, ranks=ranks, ranks_h=ranks_h, err=err,
                     wk=wk, T=T, Tb=Tb, panel_traced=pipe.column_traced)

        def repair(Qs, Vns, ranks, ranks_h, err):
            rp = health.policy
            # -- SPD breakdown: jitter the stashed diagonal, redo the
            # panel (safe: the panel gathers from the acc buffers and no
            # update stage has donated them yet -- the check hook runs
            # before update_tail(k-1) under lookahead).
            for attempt in range(rp.max_retries + 1):
                pivots = c["dk"] if opts.ldl else jnp.diag(c["Lkk"])
                flags = column_flags(
                    pivots, (_pad_axis(Qs, Tb), _pad_axis(Vns, Tb)),
                    ranks=_pad_axis(ranks[:T], Tb),
                    err=_pad_axis(err[:T], Tb), r_cap=r_p, eps=opts.eps)
                bad_piv = flags[1] > 0 or (not opts.ldl
                                           and flags[2] <= 0.0)
                if not bad_piv:
                    break
                if attempt >= rp.max_retries:
                    health.fail(k, "panel", "spd_breakdown",
                                pivot_index=int(flags[3]),
                                min_pivot=float(flags[2]),
                                nonfinite_pivots=int(flags[1]))
                shift = _spd_shift(c["Akk"], rp, attempt)
                health.record("spd_breakdown", k, "panel", remedy="jitter",
                              attempt=attempt + 1, shift=shift)
                Lkk, dk_new = _factor_diag_tile(
                    _jittered(c["Akk"], shift), opts, stats)
                if opts.ldl:
                    st.dvec = st.dvec.at[k].set(dk_new)
                st.LD = st.LD.at[k].set(Lkk)
                c.update(Lkk=Lkk, dk=dk_new)
                Qs, Vns, ranks, ranks_h, err = compute()
            if flags[0] > 0:
                health.fail(k, "panel", "nonfinite_panel",
                            nonfinite=int(flags[0]))
            if flags[4] > 0:
                # Rank overflow. Unlike the left driver there is no
                # looser re-pass worth making: the rounding pass *is* the
                # optimal rank-r_p truncation of the accumulated column
                # (batched SVD), so a tile over the cap is accepted at
                # its achieved error if that error clears the policy's
                # eps floor, and is a breakdown otherwise.
                err_h = np.asarray(err[:T], float)
                pa = ARAParams(r_max=r_p, eps=opts.eps)
                over = ara_mod.rank_overflow(ranks_h, err_h, pa)
                pos = np.nonzero(over)[0]
                floor = rp.eps_floor(opts.eps)
                health.record("rank_overflow", k, "panel", remedy="accept",
                              rows=[int(rows[i]) for i in pos],
                              err=[float(err_h[i]) for i in pos])
                hard = [i for i in pos if not (err_h[i] <= floor)]
                if hard:
                    health.fail(k, "panel", "rank_overflow",
                                rows=[int(rows[i]) for i in hard],
                                err=[float(err_h[i]) for i in hard],
                                eps_floor=floor)
            return Qs, Vns, ranks, ranks_h, err

        def fn():
            pipe.begin_column()
            c["bt0"] = batching_trace_count()
            c["t0"] = time.perf_counter()
            out = compute()
            if health is None:
                commit(*out)
            else:
                # Defer the donated scatter to the check hook so it runs
                # exactly once, on the panel's settled content.
                c["pending"] = out

        def check():
            out = repair(*c.pop("pending"))
            commit(*out)
            health.columns_checked += 1

        return fn, (check if health is not None else None)

    def _update_stage(k: int, part: str):
        # ---- eager trailing update (column-scoped SYRK) --------------------
        # ``part="all"`` is the sequential driver's single node;
        # ``"head"`` / ``"tail"`` split it for the lookahead schedule
        # (head: column k+1's tiles + D[k+1]; tail: the pair-grid rest).
        trail = np.nonzero(pairs_np[:, 1] > k)[0]
        bump = {"all": trail,
                "head": np.nonzero(pairs_np[:, 1] == k + 1)[0],
                "tail": np.nonzero(pairs_np[:, 1] > k + 1)[0]}[part]
        c = st.col[k]

        def fn():
            Qs, Vns, ranks, dk_new = c["Qs"], c["Vns"], c["ranks"], c["dk"]
            T, wk = c["T"], c["wk"]
            if ranked:
                if wk and part != "tail":
                    # Flush before the column's first append when the next
                    # append would overflow: recompress the whole grid at
                    # the per-tile rank-bucket widths. The single check
                    # covers head+tail -- they append wk to disjoint tile
                    # sets, so the max content width grows by wk once.
                    high = int(st.tile_w[trail].max()) if trail.size else 0
                    if high + wk > w_acc:
                        with obs.span("chol.flush", cat="factor", k=k):
                            Uc, Vc, rc, _ = bucketed_round_tiles(
                                st.accU, st.accV, st.tile_w, eps, r_out=b,
                                impl=impl)
                            st.accU = jnp.zeros_like(st.accU) \
                                .at[:, :, :b].set(Uc)
                            st.accV = jnp.zeros_like(st.accV) \
                                .at[:, :, :b].set(Vc)
                            st.tile_w = np.asarray(rc, dtype=np.int64)
                            if mesh is not None:
                                st.accU, st.accV = shard_tile_batch(
                                    st.accU, st.accV)
                        stats["flushes"] += 1
                if wk:
                    with obs.span("chol.syrk", cat="factor", k=k, wk=wk,
                                  T=T, part=part):
                        st.accU, st.accV, st.D = tlr_syrk_column(
                            st.accU, st.accV, st.tile_w, st.D,
                            Qs[:, :, :wk], Vns[:, :, :wk], ranks[:T],
                            dk_new, k, impl=impl, part=part, donate=True)
                    st.tile_w[bump] += wk
                if part != "head":
                    stats["append_widths"].append(wk)
            else:
                if part != "tail" and st.used + r_p > w_acc:
                    # Flush: recompress every tile's accumulated
                    # concatenation back to width b in one batched rounding
                    # pass over the whole grid. Rows of already-factored
                    # columns are dead (their panels were consumed into
                    # Lout) -- rounding them is wasted work, but one
                    # uniform shape keeps a single compiled flush variant.
                    with obs.span("chol.flush", cat="factor", k=k):
                        Uc, Vc, _, _ = tlr_round_tiles(
                            st.accU, st.accV, eps, r_out=b, impl=impl)
                        st.accU = jnp.zeros_like(st.accU) \
                            .at[:, :, :b].set(Uc)
                        st.accV = jnp.zeros_like(st.accV) \
                            .at[:, :, :b].set(Vc)
                        st.used = b
                        if mesh is not None:
                            st.accU, st.accV = shard_tile_batch(
                                st.accU, st.accV)
                    stats["flushes"] += 1
                with obs.span("chol.syrk", cat="factor", k=k, wk=wk, T=T,
                              part=part):
                    st.accU, st.accV, st.D = tlr_syrk_column(
                        st.accU, st.accV, st.used, st.D, Qs, Vns,
                        ranks[:T], dk_new, k, impl=impl, part=part,
                        donate=True)
                if part != "head":
                    st.used += r_p
            if part != "head":
                if part == "all":
                    # Sequential parity: drain the column's whole dispatch
                    # before timing it. The lookahead schedule skips this
                    # (one final sync after the graph); the span makes the
                    # host-sync gap visible to the bench harness.
                    with obs.span("chol.sync", cat="factor", k=k):
                        jax.block_until_ready((Qs, Vns, ranks, st.accU,
                                               st.D))
                dt = time.perf_counter() - c["t0"]
                stats["column_iters"].append(1)
                stats["column_ranks"].append(c["ranks_h"])
                stats["column_events"].append({
                    "k": k, "T": T, "Tb": c["Tb"], "Jb": 0, "seconds": dt,
                    "traced": c["panel_traced"]
                    or batching_trace_count() > c["bt0"],
                    "err": np.asarray(c["err"][:T]),
                    "wQ": wk if ranked else None,
                })
                c.pop("Qs", None)
                c.pop("Vns", None)

        return fn

    # Stage graph (DESIGN.md section 12). Tokens are versioned values:
    # ("acc", k) / ("Dv", k) is the accumulation / diagonal state after
    # column k's full trailing update, ("acch", k) / ("Dh", k) the
    # intermediate state after its head only. The donating update stages
    # ``destroy`` the buffers they consume, which orders them after every
    # other reader -- under lookahead that is exactly what lets
    # panel(k+1) gather from the pre-tail buffers before update_tail(k)
    # donates them.
    def _update_check_hook(k: int):
        # Sequential schedule only: the "all" update already drains the
        # column's dispatch (the parity sync), so the trailing-diagonal
        # scan rides that sync for free. Under lookahead the updates stay
        # un-checked to preserve the overlap -- the next panel's hook and
        # the final gate keep the no-NaN guarantee.
        def check():
            diag = jnp.diagonal(st.D, axis1=1, axis2=2).reshape(-1)
            flags = column_flags(diag)
            if flags[1] > 0:
                health.fail(k, "update", "nonfinite_update",
                            nonfinite=int(flags[1]))

        return check

    stages = []

    def add(name, kind, k, fn, reads=(), writes=(), destroys=(),
            check=None):
        stages.append(Stage(name=name, kind=kind, k=k, fn=fn, check=check,
                            reads=tuple(reads), writes=tuple(writes),
                            destroys=tuple(destroys), seq=len(stages)))

    for k in range(nb):
        dtok = ("Dh", k - 1) if lookahead else ("Dv", k - 1)
        add(f"diag:{k}", "diag", k, _diag_stage(k),
            reads=[dtok] if k > 0 else [], writes=[("Lkk", k)],
            check=_diag_check_hook(k, st, opts, stats, health)
            if health is not None and k + 1 >= nb else None)
        if k + 1 >= nb:
            continue
        atok = ("acch", k - 1) if lookahead else ("acc", k - 1)
        pfn, pcheck = _panel_stage(k)
        add(f"panel:{k}", "panel", k, pfn,
            reads=([atok] if k > 0 else []) + [("Lkk", k)],
            writes=[("panel", k)], check=pcheck)
        prev = ([("acc", k - 1), ("Dv", k - 1)] if k > 0 else [])
        if lookahead:
            add(f"update_head:{k}", "update_head", k,
                _update_stage(k, "head"), reads=[("panel", k)],
                destroys=prev, writes=[("acch", k), ("Dh", k)])
            add(f"update_tail:{k}", "update_tail", k,
                _update_stage(k, "tail"), reads=[("panel", k)],
                destroys=[("acch", k), ("Dh", k)],
                writes=[("acc", k), ("Dv", k)])
        else:
            add(f"update:{k}", "update", k, _update_stage(k, "all"),
                reads=[("panel", k)], destroys=prev,
                writes=[("acc", k), ("Dv", k)],
                check=_update_check_hook(k) if health is not None
                else None)

    sched = run_graph(stages,
                      LookaheadSchedule() if lookahead
                      else SequentialSchedule())
    if lookahead:
        with obs.span("chol.sync", cat="factor", k=nb - 1):
            jax.block_until_ready((st.LU, st.LV, st.LR, st.accU, st.accV,
                                   st.D))
    sched["requested_lookahead"] = bool(opts.lookahead)
    stats["schedule"] = sched
    stats["column_traces"] = pipe.traces["column"]
    stats["scatter_traces"] = pipe.scatter_traces
    stats["algebra_traces"] = algebra_trace_count() - alg0
    stats["batching_traces"] = batching_trace_count()
    if health is not None:
        _final_gate(st, opts, health, b)
        stats["health"] = health.summary()
    Lmat = TLRMatrix(D=st.LD, U=st.LU, V=st.LV, ranks=st.LR)
    return TLRFactorization(L=Lmat, d=st.dvec, perm=np.arange(nb),
                            stats=stats)


def _swap_rows(arr, i, j):
    ai, aj = arr[i], arr[j]
    return arr.at[i].set(aj).at[j].set(ai)


def _swap_L_rows(L: TLRMatrix, k: int, pidx: int) -> TLRMatrix:
    """Swap already-written L tiles of logical rows k <-> pidx (cols j < k)."""
    if k == 0:
        return L
    ik = np.asarray([tril_index(k, j) for j in range(k)], np.int32)
    ip = np.asarray([tril_index(pidx, j) for j in range(k)], np.int32)
    both = np.concatenate([ik, ip])
    swapped = np.concatenate([ip, ik])

    def sw(arr):
        return arr.at[both].set(arr[swapped])

    return TLRMatrix(D=L.D, U=sw(L.U), V=sw(L.V), ranks=sw(L.ranks))


def _power_norms(tiles, iters: int, key):
    """Batched power-iteration 2-norm estimates for (T, b, b) symmetric tiles."""
    T, b, _ = tiles.shape
    x = jax.random.normal(key, (T, b), tiles.dtype)
    x = x / jnp.linalg.norm(x, axis=1, keepdims=True)

    def body(_, x):
        y = jnp.einsum("tbc,tc->tb", tiles, x)
        return y / jnp.maximum(jnp.linalg.norm(y, axis=1, keepdims=True), 1e-300)

    x = jax.lax.fori_loop(0, iters, body, x)
    y = jnp.einsum("tbc,tc->tb", tiles, x)
    return jnp.linalg.norm(y, axis=1)
