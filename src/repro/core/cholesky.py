"""Left-looking TLR Cholesky / LDL^T with batched ARA (Algorithms 4-6, 9, 10).

Per block column ``k`` (host-driven, like the paper's CUDA host orchestration):

  1. dense diagonal update  A(k,k) -= sum_j L(k,j) L(k,j)^T
     (optionally Schur-compensated, section 5.1.1),
  2. dense Cholesky (or LDL^T) of the diagonal tile, with a modified-Cholesky
     fallback (section 5.1.2),
  3. ARA compression of every updated tile in the column: the matrix
     expression ``A(i,k) - sum_j L(i,j) L(k,j)^T`` is sampled through the
     4-product chain (Eq. 2; 5-product for LDL^T, Eq. 3) -- compression
     happens ONCE per output tile, ab initio,
  4. batched triangular solve  V(i,k) = L(k,k)^{-1} B_i  (+ D^{-1} scaling
     for LDL^T).

Dynamic batching (Algorithm 5): tiles are sorted by their rank in A
descending; a fixed-size slot buffer processes a subset, evicting converged
tiles and refilling from the remainder at *stable shapes* (the TPU-friendly
equivalent of MAGMA pointer-marshaling; see DESIGN.md section 2).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ara as ara_mod
from .ara import ARAParams, ara_iteration, init_state, run_ara_fused
from .tlr import TLRMatrix, num_tiles, tril_index, zeros_like_structure


@dataclasses.dataclass(frozen=True)
class CholOptions:
    eps: float = 1e-6
    bs: int = 16
    r_max_out: int = 0            # 0 => A.r_max
    mode: str = "dynamic"         # "dynamic" | "fused"
    bucket: int = 0               # 0 => whole column in one batch
    share_omega: bool = True      # share Omega across the column (beyond-paper)
    schur: Optional[str] = "diag" # None | "diag" | "full"
    modified_chol: bool = True
    pivot: Optional[str] = None   # None | "frobenius" | "power"
    ldl: bool = False
    calib: float = 1.0
    gs_passes: int = 2
    seed: int = 0

    def ara_params(self, r_max: int) -> ARAParams:
        return ARAParams(bs=self.bs, r_max=r_max, eps=self.eps,
                         calib=self.calib, gs_passes=self.gs_passes)


class TLRFactorization(NamedTuple):
    L: TLRMatrix                  # D holds dense L(k,k) (unit-lower for LDL)
    d: Optional[jax.Array]        # (nb, b) LDL diagonal, None for Cholesky
    perm: np.ndarray              # tile-level permutation (logical -> original)
    stats: dict


# -- tile gathers -------------------------------------------------------------


def _row_indices(i: int, k: int) -> list[int]:
    """Packed indices of tiles (i, j) for j < k (requires i >= k)."""
    return [tril_index(i, j) for j in range(k)]


def _gather_L_rows(L: TLRMatrix, rows: np.ndarray, k: int):
    """L tiles (i, j) for each i in rows, j<k: (T, k, b, r) each."""
    idx = np.array([_row_indices(int(i), k) for i in rows], np.int32)
    idx = idx.reshape(len(rows), k)
    return jnp.take(L.U, idx, axis=0), jnp.take(L.V, idx, axis=0)


def _gather_L_row(L: TLRMatrix, i: int, k: int):
    idx = np.array(_row_indices(i, k), np.int32)
    return jnp.take(L.U, idx, axis=0), jnp.take(L.V, idx, axis=0)


def _gather_A_tiles(A: TLRMatrix, pairs: list[tuple[int, int]], perm: np.ndarray):
    """Original-A tiles for logical (i, j) pairs, resolving the pivot perm.

    A logical tile (i, j) maps to original (perm[i], perm[j]); when
    perm[i] < perm[j] the stored tile is its transpose, so the U/V roles swap.
    """
    idx, flip = [], []
    for (i, j) in pairs:
        oi, oj = int(perm[i]), int(perm[j])
        if oi > oj:
            idx.append(tril_index(oi, oj)); flip.append(False)
        else:
            idx.append(tril_index(oj, oi)); flip.append(True)
    idx = np.asarray(idx, np.int32)
    flip = np.asarray(flip)
    U0 = jnp.take(A.U, idx, axis=0)
    V0 = jnp.take(A.V, idx, axis=0)
    f = jnp.asarray(flip)[:, None, None]
    Ua = jnp.where(f, V0, U0)
    Va = jnp.where(f, U0, V0)
    return Ua, Va


# -- sampling closures (Eq. 2 / Eq. 3) ----------------------------------------


def make_column_samplers(ldl: bool):
    """Samplers for the column expression A(i,k) - sum_j L(i,j) D_j L(k,j)^T.

    data = dict(Uk, Vk: (k,b,r) row-k tiles of L;  Ui, Vi: (T,k,b,r) row-i
    tiles;  Ua, Va: (T,b,rA) original A(i,k);  dk: (k,b) LDL diagonals or
    None). Omega is (b,s) when shared across the column, else (T,b,s).
    """

    def sample(data, Omega):
        Ua, Va, Uk, Vk, Ui, Vi = (
            data["Ua"], data["Va"], data["Uk"], data["Vk"],
            data["Ui"], data["Vi"],
        )
        shared = Omega.ndim == 2
        if shared:
            Ya = jnp.einsum("tbr,trs->tbs", Ua,
                            jnp.einsum("tbr,bs->trs", Va, Omega))
            T1 = jnp.einsum("jbr,bs->jrs", Uk, Omega)
            W2 = jnp.einsum("jbr,jrs->jbs", Vk, T1)
            if ldl:
                W2 = W2 * data["dk"][:, :, None]
            T3 = jnp.einsum("tjbr,jbs->tjrs", Vi, W2)
            Yu = jnp.einsum("tjbr,tjrs->tbs", Ui, T3)
        else:
            Ya = jnp.einsum("tbr,trs->tbs", Ua,
                            jnp.einsum("tbr,tbs->trs", Va, Omega))
            T1 = jnp.einsum("jbr,tbs->tjrs", Uk, Omega)
            W2 = jnp.einsum("jbr,tjrs->tjbs", Vk, T1)
            if ldl:
                W2 = W2 * data["dk"][None, :, :, None]
            T3 = jnp.einsum("tjbr,tjbs->tjrs", Vi, W2)
            Yu = jnp.einsum("tjbr,tjrs->tbs", Ui, T3)
        return Ya - Yu

    def sample_t(data, Q):
        Ua, Va, Uk, Vk, Ui, Vi = (
            data["Ua"], data["Va"], data["Uk"], data["Vk"],
            data["Ui"], data["Vi"],
        )
        Ba = jnp.einsum("tbr,trq->tbq", Va,
                        jnp.einsum("tbr,tbq->trq", Ua, Q))
        S1 = jnp.einsum("tjbr,tbq->tjrq", Ui, Q)
        S2 = jnp.einsum("tjbr,tjrq->tjbq", Vi, S1)
        if ldl:
            S2 = S2 * data["dk"][None, :, :, None]
        S3 = jnp.einsum("jbr,tjbq->tjrq", Vk, S2)
        Bu = jnp.einsum("jbr,tjrq->tbq", Uk, S3)
        return Ba - Bu

    return sample, sample_t


# -- diagonal machinery --------------------------------------------------------


def _diag_update_sum(Uk, Vk, dk=None):
    """sum_j L(k,j) D_j L(k,j)^T as a dense (b, b) block."""
    if dk is None:
        G = jnp.einsum("jbr,jbq->jrq", Vk, Vk)
    else:
        G = jnp.einsum("jbr,jb,jbq->jrq", Vk, dk, Vk)
    M = jnp.einsum("jbr,jrq->jbq", Uk, G)
    return jnp.einsum("jbq,jcq->bc", M, Uk)


def _schur_compensate(Akk, Dsum, mode: str, eps: float, bs: int, key):
    """Section 5.1.1: subtract a *compressed* update / diagonal-compensate."""
    b = Akk.shape[0]
    p = ARAParams(bs=min(bs, b), r_max=b, eps=eps)
    Q, B, rank, _ = ara_mod.ara_compress_dense(Dsum[None], key, p)
    Dbar = Q[0] @ B[0].T
    Dbar = 0.5 * (Dbar + Dbar.T)
    if mode == "full":
        # A - Dbar  ==  A - D + (D - Dbar), the PSD-compensated update
        return Akk - Dbar
    # "diag": A - D + diag(rowsum |D - Dbar|)   (diagonal compensation [8])
    comp = jnp.sum(jnp.abs(Dsum - Dbar), axis=1)
    return Akk - Dsum + jnp.diag(comp)


def robust_cholesky(Akk, delta):
    """Dense Cholesky with eigenvalue-clamp fallback (Algorithm 8 analogue).

    The paper repairs failing tiles with a Cheng-Higham modified Cholesky via
    LDL^T; with no pivoted LDL in JAX we use the spectral equivalent: clamp
    eigenvalues to ``delta`` (the minimal-norm symmetric E making A+E PD).
    Returns (L, modified?).
    """
    L = jnp.linalg.cholesky(Akk)
    bad = jnp.any(jnp.isnan(L))

    def fallback(_):
        w, W = jnp.linalg.eigh(Akk)
        w = jnp.maximum(w, delta)
        Amod = (W * w) @ W.T
        Amod = 0.5 * (Amod + Amod.T)
        return jnp.linalg.cholesky(Amod)

    Lout = jax.lax.cond(bad, fallback, lambda _: L, operand=None)
    return Lout, bad


def dense_ldlt_tile(Akk):
    """Unpivoted dense LDL^T of one tile: returns unit-lower L and d (b,)."""
    b = Akk.shape[0]
    dtype = Akk.dtype
    eye = jnp.eye(b, dtype=dtype)
    ar = jnp.arange(b)

    def body(j, carry):
        L, d = carry
        w = jnp.where(ar < j, d * L[j, :], 0.0)
        c = Akk[:, j] - L @ w
        dj = c[j]
        tiny = jnp.asarray(1e-30, dtype)
        dj = jnp.where(jnp.abs(dj) < tiny, tiny, dj)
        col = jnp.where(ar > j, c / dj, 0.0)
        L = L.at[:, j].set(col + eye[:, j])
        d = d.at[j].set(dj)
        return L, d

    L0 = jnp.zeros((b, b), dtype)
    d0 = jnp.zeros((b,), dtype)
    return jax.lax.fori_loop(0, b, body, (L0, d0))


# -- column processing ---------------------------------------------------------


def _build_column_data(A, Lout, rows, k, perm, dvec, ldl):
    Ui, Vi = _gather_L_rows(Lout, rows, k)
    Uk, Vk = _gather_L_row(Lout, k, k)
    Ua, Va = _gather_A_tiles(A, [(int(i), k) for i in rows], perm)
    dk = dvec[:k] if ldl else None
    return {"Ua": Ua, "Va": Va, "Uk": Uk, "Vk": Vk, "Ui": Ui, "Vi": Vi,
            "dk": dk}


def _column_ara_fused(A, Lout, rows, k, perm, dvec, opts: CholOptions,
                      p: ARAParams, key):
    sample, sample_t = make_column_samplers(opts.ldl)
    data = _build_column_data(A, Lout, rows, k, perm, dvec, opts.ldl)
    T = len(rows)
    Q, B, ranks, state = run_ara_fused(
        sample, sample_t, data, key, T=T, b=A.b, m=A.b, p=p,
        dtype=A.dtype, share_omega=opts.share_omega,
    )
    iters = int(state.it)
    return Q, B, ranks, {"iters": iters, "err": np.asarray(state.err)}


def _column_ara_dynamic(A, Lout, rows, k, perm, dvec, opts: CholOptions,
                        p: ARAParams, key):
    """Algorithm 5: rank-sorted subset with converged-tile eviction/refill."""
    sample, sample_t = make_column_samplers(opts.ldl)
    T_col = len(rows)
    bucket = opts.bucket if opts.bucket > 0 else T_col
    bucket = min(bucket, T_col)

    # Sort rows by the rank of the original A tile, descending (section 4.2):
    # big tiles stay in the batch longest, so they enter first.
    a_ranks = np.asarray(A.ranks)
    key_rank = np.array(
        [a_ranks[tril_index(max(int(perm[i]), int(perm[k])),
                            min(int(perm[i]), int(perm[k])))]
         for i in rows]
    )
    order = np.argsort(-key_rank, kind="stable")
    queue = [int(rows[o]) for o in order]

    # Slot state: each slot hosts one tile's ARA run.
    slot_rows = queue[:bucket]
    queue = queue[bucket:]
    data = _build_column_data(A, Lout, np.asarray(slot_rows), k, perm, dvec,
                              opts.ldl)
    state = init_state(bucket, A.b, p, A.dtype)

    step = jax.jit(
        partial(ara_iteration, sample, p=p, share_omega=opts.share_omega,
                T=bucket, b=A.b)
    )

    done_Q = {}
    done_rank = {}
    total_iters = 0
    slot_live = [True] * len(slot_rows)

    while any(slot_live):
        state = step(data, state, key)
        total_iters += 1
        conv = np.asarray(state.converged)
        # Evict converged tiles; refill their slots from the queue.
        refills = []
        for s, live in enumerate(slot_live):
            if live and conv[s]:
                done_Q[slot_rows[s]] = state.Q[s]
                done_rank[slot_rows[s]] = int(state.rank[s])
                if queue:
                    slot_rows[s] = queue.pop(0)
                    refills.append(s)
                else:
                    slot_live[s] = False
        if refills:
            sr = np.asarray(refills, np.int32)
            new_rows = np.asarray([slot_rows[s] for s in refills])
            nd = _build_column_data(A, Lout, new_rows, k, perm, dvec, opts.ldl)
            for name in ("Ua", "Va", "Ui", "Vi"):
                data[name] = data[name].at[sr].set(nd[name])
            state = state._replace(
                Q=state.Q.at[sr].set(0.0),
                rank=state.rank.at[sr].set(0),
                converged=state.converged.at[sr].set(False),
                err=state.err.at[sr].set(jnp.inf),
            )
        if total_iters > p.iters * max(1, T_col):
            break  # safety valve

    # Assemble per-row results in the original row order, then project once
    # (batched, full column) into the bases.
    Q_all = jnp.stack([done_Q[int(i)] for i in rows])
    ranks = jnp.asarray([done_rank[int(i)] for i in rows], jnp.int32)
    full_data = _build_column_data(A, Lout, rows, k, perm, dvec, opts.ldl)
    B = sample_t(full_data, Q_all)
    return Q_all, B, ranks, {"iters": total_iters}


# -- main drivers ---------------------------------------------------------------


def tlr_cholesky(A: TLRMatrix, opts: CholOptions) -> TLRFactorization:
    """Left-looking TLR Cholesky (Algorithm 6; Algorithm 9 when pivoting)."""
    return _factorize(A, dataclasses.replace(opts, ldl=False))


def tlr_ldlt(A: TLRMatrix, opts: CholOptions) -> TLRFactorization:
    """Left-looking TLR LDL^T (Algorithm 10). Pivoting unsupported (paper 5.3)."""
    if opts.pivot is not None:
        raise ValueError("inter-tile pivoting is not defined for LDL^T (section 5.3)")
    return _factorize(A, dataclasses.replace(opts, ldl=True, schur=None))


def _factorize(A: TLRMatrix, opts: CholOptions) -> TLRFactorization:
    nb, b = A.nb, A.b
    r_out = opts.r_max_out or A.r_max
    p = opts.ara_params(r_out)
    key = jax.random.PRNGKey(opts.seed)

    Lout = zeros_like_structure(nb, b, r_out, A.dtype)
    dvec = jnp.zeros((nb, b), A.dtype) if opts.ldl else None
    perm = np.arange(nb)
    stats = {
        "column_iters": [], "column_ranks": [], "modified_chol": 0,
        "pivots": [], "mode": opts.mode,
    }

    # Pivoted mode keeps running diagonal-update sums for all rows (section 5.2).
    Dsum_all = jnp.zeros((nb, b, b), A.dtype) if opts.pivot else None

    for k in range(nb):
        kkey = jax.random.fold_in(key, k)

        # ---- pivot selection & swap (Algorithm 9 lines 11-14) --------------
        if opts.pivot and k < nb:
            diag_orig = jnp.take(A.D, jnp.asarray(perm[k:], np.int32), axis=0)
            cand = diag_orig - Dsum_all[k:]
            if opts.pivot == "frobenius":
                norms = jnp.sqrt(jnp.sum(cand * cand, axis=(1, 2)))
            elif opts.pivot == "power":
                norms = _power_norms(cand, iters=10, key=kkey)
            else:
                raise ValueError(opts.pivot)
            pidx = k + int(jnp.argmax(norms))
            stats["pivots"].append(pidx)
            if pidx != k:
                perm[[k, pidx]] = perm[[pidx, k]]
                Dsum_all = _swap_rows(Dsum_all, k, pidx)
                Lout = _swap_L_rows(Lout, k, pidx)

        # ---- diagonal tile: update, compensate, factor ----------------------
        Akk = A.D[perm[k]]
        if k > 0:
            Uk, Vk = _gather_L_row(Lout, k, k)
            dk = dvec[:k] if opts.ldl else None
            Dsum = _diag_update_sum(Uk, Vk, dk)
            if opts.schur and not opts.ldl:
                Akk = _schur_compensate(Akk, Dsum, opts.schur, opts.eps,
                                        opts.bs, kkey)
            else:
                Akk = Akk - Dsum
        if opts.ldl:
            Lkk, dk_new = dense_ldlt_tile(Akk)
            dvec = dvec.at[k].set(dk_new)
        else:
            delta = opts.eps * jnp.maximum(jnp.max(jnp.abs(jnp.diag(Akk))), 1.0)
            if opts.modified_chol:
                Lkk, bad = robust_cholesky(Akk, delta)
                stats["modified_chol"] += int(bad)
            else:
                Lkk = jnp.linalg.cholesky(Akk)
        Lout = TLRMatrix(D=Lout.D.at[k].set(Lkk), U=Lout.U, V=Lout.V,
                         ranks=Lout.ranks)

        # ---- off-diagonal column: ARA + trsm --------------------------------
        if k + 1 < nb:
            rows = np.arange(k + 1, nb)
            if opts.mode == "fused":
                Q, B, ranks, info = _column_ara_fused(
                    A, Lout, rows, k, perm, dvec, opts, p, kkey)
            else:
                Q, B, ranks, info = _column_ara_dynamic(
                    A, Lout, rows, k, perm, dvec, opts, p, kkey)
            stats["column_iters"].append(info["iters"])
            stats["column_ranks"].append(np.asarray(ranks))

            # V(i,k) = L(k,k)^{-1} B_i  (paper: batchTrsm); LDL adds D^{-1}.
            Vnew = jax.vmap(
                lambda Bi: jax.scipy.linalg.solve_triangular(Lkk, Bi, lower=True)
            )(B)
            if opts.ldl:
                # L(i,k) = Q B^T (L D)^{-T}  =>  V(i,k) = D^{-1} L^{-1} B
                Vnew = Vnew / dk_new[None, :, None]
            idx = jnp.asarray([tril_index(int(i), k) for i in rows], jnp.int32)
            Lout = TLRMatrix(
                D=Lout.D,
                U=Lout.U.at[idx].set(Q),
                V=Lout.V.at[idx].set(Vnew),
                ranks=Lout.ranks.at[idx].set(ranks),
            )
            if opts.pivot:
                # Dsum_all[i] += L(i,k) L(i,k)^T for the remaining rows.
                G = jnp.einsum("tbr,tbq->trq", Vnew, Vnew)
                upd = jnp.einsum("tbr,trq,tcq->tbc", Q, G, Q)
                Dsum_all = Dsum_all.at[k + 1 :].add(upd)

    return TLRFactorization(L=Lout, d=dvec, perm=perm, stats=stats)


def _swap_rows(arr, i, j):
    ai, aj = arr[i], arr[j]
    return arr.at[i].set(aj).at[j].set(ai)


def _swap_L_rows(L: TLRMatrix, k: int, pidx: int) -> TLRMatrix:
    """Swap already-written L tiles of logical rows k <-> pidx (cols j < k)."""
    if k == 0:
        return L
    ik = np.asarray([tril_index(k, j) for j in range(k)], np.int32)
    ip = np.asarray([tril_index(pidx, j) for j in range(k)], np.int32)
    both = np.concatenate([ik, ip])
    swapped = np.concatenate([ip, ik])

    def sw(arr):
        return arr.at[both].set(arr[swapped])

    return TLRMatrix(D=L.D, U=sw(L.U), V=sw(L.V), ranks=sw(L.ranks))


def _power_norms(tiles, iters: int, key):
    """Batched power-iteration 2-norm estimates for (T, b, b) symmetric tiles."""
    T, b, _ = tiles.shape
    x = jax.random.normal(key, (T, b), tiles.dtype)
    x = x / jnp.linalg.norm(x, axis=1, keepdims=True)

    def body(_, x):
        y = jnp.einsum("tbc,tc->tb", tiles, x)
        return y / jnp.maximum(jnp.linalg.norm(y, axis=1, keepdims=True), 1e-300)

    x = jax.lax.fori_loop(0, iters, body, x)
    y = jnp.einsum("tbc,tc->tb", tiles, x)
    return jnp.linalg.norm(y, axis=1)
