"""Column-stage graph: the shared stage library both Cholesky drivers
schedule over (DESIGN.md section 12).

The drivers in ``core/cholesky.py`` no longer interleave their per-column
work in one host loop. Each column is decomposed into *stages* -- ``diag``
(dense diagonal factor), ``panel`` (round + TRSM), and the trailing update
(``update`` as one node, or the ``update_head`` / ``update_tail`` split the
lookahead schedule needs) -- declared as :class:`Stage` nodes with explicit
``reads`` / ``writes`` / ``destroys`` resource tokens. A small list
scheduler (:func:`Schedule.order`) turns the declared dataflow into an
execution order, and :func:`run_graph` executes it on the host (each stage
body dispatches its batched jax work asynchronously, exactly as before).

Tokens are *versioned values*, written exactly once: e.g. ``("acc", k)`` is
the accumulation-buffer state after column ``k``'s trailing update. Three
edge kinds fall out:

* RAW -- a stage reading a token depends on its (unique) writer;
* WAW -- a token's writer depends on the previous writer of the same token
  (only the init stage and rebuilds hit this);
* donation anti-dependency -- a stage that ``destroys`` a token (it passes
  the backing buffer to a ``donate_argnums`` jit, invalidating it) must run
  after every *other* reader of that token. This is what lets the
  lookahead schedule overlap column ``k``'s trailing update with column
  ``k+1``'s panel: the panel gathers from the pre-update buffers, then the
  donating update consumes them.

``SequentialSchedule`` reproduces program order (the exact-parity default:
every stage's priority is its construction index). ``LookaheadSchedule``
sinks each column's ``update_tail`` below the *next* column's diag + panel,
so the wide trailing update of column ``k`` executes while column ``k+1``'s
panel factorization is already in flight -- classic right-looking lookahead
expressed purely through stage priorities; the dependency edges guarantee
the reorder is legal (and ``order`` re-validates it).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

__all__ = [
    "Stage", "Schedule", "SequentialSchedule", "LookaheadSchedule",
    "build_deps", "run_graph",
]


Token = tuple


@dataclasses.dataclass(frozen=True)
class Stage:
    """One schedulable unit of factorization work.

    ``fn`` runs on the host and mutates the driver's column state (it
    closes over it); the jax work it dispatches is asynchronous. ``reads``
    / ``writes`` / ``destroys`` are tuples of hashable resource tokens --
    versioned values, each written by exactly one stage. ``seq`` is the
    construction index (program order), the tiebreaker every schedule
    falls back to.
    """

    name: str
    kind: str                      # "diag" | "panel" | "update" |
                                   # "update_head" | "update_tail" | "init"
    k: int
    fn: Callable[[], None]
    reads: tuple = ()
    writes: tuple = ()
    destroys: tuple = ()           # tokens whose buffers this stage donates
    seq: int = 0
    check: Optional[Callable[[], None]] = None
                                   # optional health hook (DESIGN.md
                                   # section 13): runs immediately after
                                   # ``fn``, before any other stage -- so a
                                   # repair can recompute from buffers no
                                   # later stage has donated yet. None
                                   # (the default) costs nothing.


def build_deps(stages: list[Stage]) -> dict[str, set[str]]:
    """Dependency edges from the declared tokens.

    Returns ``{stage.name: set of stage names that must run first}``.
    Declaration order carries no meaning -- readers and the destroyer of
    a token may appear in any list order; the edges alone decide legality
    (an unsatisfiable graph surfaces as a cycle in ``Schedule.order``).
    Raises on malformed graphs: duplicate stage names, two writers of one
    token (tokens are versioned values, written once), or two destroyers
    of one token (a buffer can only be donated once).
    """
    writer: dict[Token, str] = {}
    readers: dict[Token, list[str]] = {}
    destroyer: dict[Token, str] = {}
    deps: dict[str, set[str]] = {}
    for s in stages:
        if s.name in deps:
            raise ValueError(f"duplicate stage name {s.name!r}")
        deps[s.name] = set()
        for t in s.reads + s.destroys:
            readers.setdefault(t, []).append(s.name)
        for t in s.destroys:
            if t in destroyer:
                raise ValueError(
                    f"token {t!r} destroyed twice ({destroyer[t]!r} and "
                    f"{s.name!r}); a buffer can only be donated once")
            destroyer[t] = s.name
        for t in s.writes:
            if t in writer:
                raise ValueError(
                    f"token {t!r} written twice ({writer[t]!r} and "
                    f"{s.name!r}); tokens are versioned values")
            writer[t] = s.name
    for s in stages:
        # RAW: a consumer runs after the token's unique writer.
        for t in s.reads + s.destroys:
            w = writer.get(t)
            if w is not None and w != s.name:
                deps[s.name].add(w)
        # Donation anti-dependency: the destroyer runs after every other
        # reader of the token (it invalidates the backing buffer).
        for t in s.destroys:
            for r in readers.get(t, ()):
                if r != s.name:
                    deps[s.name].add(r)
    return deps


class Schedule:
    """Base scheduler: a priority over stages + list scheduling.

    ``order`` runs list scheduling over :func:`build_deps`: among the
    ready stages (all dependencies executed) the minimal ``priority``
    runs next. Subclasses only define the priority.
    """

    name = "base"

    def priority(self, s: Stage) -> tuple:
        raise NotImplementedError

    def order(self, stages: list[Stage]) -> list[Stage]:
        deps = build_deps(stages)
        by_name = {s.name: s for s in stages}
        pending = {s.name: set(deps[s.name]) for s in stages}
        dependents: dict[str, list[str]] = {s.name: [] for s in stages}
        for s in stages:
            for d in deps[s.name]:
                dependents[d].append(s.name)
        ready = sorted((s.name for s in stages if not pending[s.name]),
                       key=lambda n: self.priority(by_name[n]))
        out: list[Stage] = []
        done: set[str] = set()
        while ready:
            nm = ready.pop(0)
            out.append(by_name[nm])
            done.add(nm)
            released = []
            for d in dependents[nm]:
                pending[d].discard(nm)
                if not pending[d] and d not in done:
                    released.append(d)
            if released:
                ready.extend(released)
                ready.sort(key=lambda n: self.priority(by_name[n]))
        if len(out) != len(stages):
            stuck = [n for n, p in pending.items() if p and n not in done]
            raise ValueError(f"stage graph has a cycle; stuck: {stuck}")
        # Re-validate: every dependency precedes its dependent.
        pos = {s.name: i for i, s in enumerate(out)}
        for s in out:
            for d in deps[s.name]:
                if pos[d] >= pos[s.name]:
                    raise AssertionError(
                        f"schedule {self.name!r} ordered {s.name!r} before "
                        f"its dependency {d!r}")
        return out


class SequentialSchedule(Schedule):
    """Program order -- the exact-parity default (and the only legal
    order for the left-looking driver's serial dependency chain)."""

    name = "sequential"

    def priority(self, s: Stage) -> tuple:
        return (s.seq,)


class LookaheadSchedule(Schedule):
    """Right-looking lookahead: ``update_tail(k)`` sinks between
    ``panel(k+1)`` and ``update_head(k+1)``.

    Resulting order per column block: ``... update_head(k) -> diag(k+1)
    -> panel(k+1) -> update_tail(k) -> update_head(k+1) ...`` -- the
    narrow head update (next column's tiles + diagonal) runs eagerly so
    column ``k+1`` can start, the wide tail update overlaps the next
    panel's dispatch, and the donation anti-dependency (the tail consumes
    the buffers the panel gathers from) pins the panel first.
    """

    name = "lookahead"

    _RANK = {"init": -1.0, "diag": 0.0, "panel": 1.0, "update": 2.0,
             "update_head": 3.0}

    def priority(self, s: Stage) -> tuple:
        if s.kind == "update_tail":
            return (s.k + 1, 1.5, s.seq)
        return (s.k, self._RANK.get(s.kind, 2.0), s.seq)


def run_graph(stages: list[Stage], schedule: Schedule,
              on_stage: Optional[Callable[[Stage, float], None]] = None
              ) -> dict:
    """Execute the stage graph under ``schedule`` and return the record
    the drivers put in ``stats["schedule"]``: the schedule name, the
    executed order, and per-kind host wall time."""
    order = schedule.order(stages)
    kind_seconds: dict[str, float] = {}
    checks = 0
    for s in order:
        t0 = time.perf_counter()
        s.fn()
        dt = time.perf_counter() - t0
        kind_seconds[s.kind] = kind_seconds.get(s.kind, 0.0) + dt
        if s.check is not None:
            # Health hook: validated (and possibly repaired) before any
            # later stage can consume -- or donate -- this stage's outputs.
            # Timed separately so the clean-path overhead is attributable.
            t0 = time.perf_counter()
            s.check()
            kind_seconds["check"] = (kind_seconds.get("check", 0.0)
                                     + time.perf_counter() - t0)
            checks += 1
        if on_stage is not None:
            on_stage(s, dt)
    return {
        "name": schedule.name,
        "stages": len(order),
        "order": [s.name for s in order],
        "kind_seconds": kind_seconds,
        "checks": checks,
    }
