"""Problem generators matching the paper's experiments (section 6).

* Spatial-statistics covariance matrices: isotropic exponential kernel
  ``exp(-r / ell)`` with correlation lengths 0.1 (2D) and 0.2 (3D), points on
  a uniform grid or random in a ball.
* Fractional-diffusion-type operator: integral-equation discretization of a
  Riesz-potential kernel ``c / r^{d - 2s}`` (SPD for 0 < s < d/2), singular
  diagonal replaced by a self-interaction term scaled to the mesh width.
  Like the paper's matrix it is SPD but severely ill-conditioned, which is
  what exercises Schur compensation and the preconditioned-CG experiments.
"""

from __future__ import annotations

import numpy as np


# -- point clouds ------------------------------------------------------------


def grid_points(n: int, d: int) -> np.ndarray:
    """~n points on a uniform grid in [0,1]^d (exactly m^d for m=ceil(n^(1/d)))."""
    m = int(round(n ** (1.0 / d)))
    while m**d < n:
        m += 1
    axes = [np.linspace(0.0, 1.0, m) for _ in range(d)]
    pts = np.stack(np.meshgrid(*axes, indexing="ij"), axis=-1).reshape(-1, d)
    return pts[:n]


def ball_points(n: int, d: int, seed: int = 0) -> np.ndarray:
    """n points uniformly distributed in the unit d-ball."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    r = rng.random(n) ** (1.0 / d)
    return x * r[:, None]


# -- kernels -----------------------------------------------------------------


def pairwise_dist(points: np.ndarray) -> np.ndarray:
    g = points @ points.T
    sq = np.diag(g)
    d2 = np.maximum(sq[:, None] + sq[None, :] - 2 * g, 0.0)
    return np.sqrt(d2)


def exp_covariance(
    points: np.ndarray, ell: float, nugget: float = 1e-8
) -> np.ndarray:
    """Isotropic exponential covariance  K = exp(-r/ell) + nugget*I  (SPD)."""
    r = pairwise_dist(points)
    K = np.exp(-r / ell)
    K[np.diag_indices_from(K)] += nugget
    return K


def matern32_covariance(
    points: np.ndarray, ell: float, nugget: float = 1e-8
) -> np.ndarray:
    """Matern nu=3/2 covariance (smoother spectrum than exponential)."""
    r = pairwise_dist(points) * (np.sqrt(3.0) / ell)
    K = (1.0 + r) * np.exp(-r)
    K[np.diag_indices_from(K)] += nugget
    return K


def fractional_diffusion(
    points: np.ndarray, s: float = 0.75, mass: float = 1e-3
) -> np.ndarray:
    """SPD, ill-conditioned fractional-Laplacian collocation matrix.

    Singular-integral form of (-Delta)^s (the paper's [12] integral
    formulation):  (-Delta)^s u(x) = c \\int (u(x)-u(y)) / |x-y|^{d+2s} dy.
    Collocation with double quadrature weight h^{2d} gives the symmetric
    diagonally-dominant matrix

        A_ij = -h^{2d} / r_ij^{d+2s}   (i != j),
        A_ii =  sum_{j!=i} h^{2d}/r_ij^{d+2s} + mass * h^d,

    which is SPD (Gershgorin) with condition number ~ h^{-2s} / mass --
    severely ill-conditioned as n grows, matching the paper's kappa ~ 1e7
    regime for N = 2^17. Off-diagonal *tiles* inherit the low-rank structure
    of the smooth far-field kernel.
    """
    n, d = points.shape
    if not 0.0 < s < 1.0:
        raise ValueError(f"need 0 < s < 1, got s={s}")
    r = pairwise_dist(points)
    h = 1.0 / max(n ** (1.0 / d) - 1.0, 1.0)
    alpha = d + 2 * s
    with np.errstate(divide="ignore"):
        W = (h ** (2 * d)) / np.maximum(r, 1e-300) ** alpha
    np.fill_diagonal(W, 0.0)
    A = -W
    np.fill_diagonal(A, W.sum(axis=1) + mass * h**d)
    return 0.5 * (A + A.T)


# -- assembled problems ------------------------------------------------------


def covariance_problem(
    n: int,
    d: int,
    tile_size: int,
    *,
    geometry: str = "grid",
    seed: int = 0,
    kernel: str = "exp",
):
    """Points (KD-tree ordered) + covariance matrix, paper's section 6.1 setup."""
    from .ordering import kd_tree_ordering

    ell = 0.1 if d == 2 else 0.2
    pts = grid_points(n, d) if geometry == "grid" else ball_points(n, d, seed)
    pts = pts[:n]
    perm = kd_tree_ordering(pts, tile_size)
    pts = pts[perm]
    if kernel == "exp":
        K = exp_covariance(pts, ell)
    elif kernel == "matern32":
        K = matern32_covariance(pts, ell)
    else:
        raise ValueError(kernel)
    return pts, K


def fractional_diffusion_problem(
    n: int, tile_size: int, *, s: float = 0.75, seed: int = 0
):
    """3D fractional-diffusion-type matrix, KD-tree ordered (section 6.2)."""
    from .ordering import kd_tree_ordering

    pts = grid_points(n, 3)[:n]
    perm = kd_tree_ordering(pts, tile_size)
    pts = pts[perm]
    return pts, fractional_diffusion(pts, s=s)
