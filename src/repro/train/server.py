"""Batched decode server with continuous batching.

Fixed decode slots; finished sequences are evicted and refilled from the
request queue at stable shapes -- the serving-side mirror of the paper's
dynamic batched ARA (Algorithm 5): converged work leaves the batch, pending
work enters, shapes never change, occupancy stays high.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_prefill_fn, build_serve_step, \
    init_decode_caches
from repro.models.api import _enc_len
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list[int]


class DecodeServer:
    """Slot-based continuous batching over the one-token serve_step."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self._serve = jax.jit(build_serve_step(cfg))
        self.caches = init_decode_caches(cfg, slots, max_len,
                                         ctx_len=_enc_len(cfg, max_len))
        # slot bookkeeping (host side, like the paper's subset marshaling)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_tokens: list[list[int]] = [[] for _ in range(slots)]
        self.slot_pos = np.zeros(slots, np.int32)

    def _reset_slot_cache(self, s: int):
        def zero_slot(c):
            if c.ndim >= 2 and c.shape[1] == self.slots:
                return c.at[:, s].set(0)
            return c
        self.caches = jax.tree.map(zero_slot, self.caches)

    def run(self, requests: list[Request]) -> list[Completion]:
        queue = list(requests)
        done: list[Completion] = []
        # Note: serve_step uses a single scalar cache_len for the batch, so
        # the server advances all active slots in lockstep and feeds prompt
        # tokens one-at-a-time (teacher forcing) until a slot switches to
        # generation. Positions are therefore uniform across slots.
        while queue or any(r is not None for r in self.slot_req):
            # refill empty slots
            for s in range(self.slots):
                if self.slot_req[s] is None and queue:
                    req = queue.pop(0)
                    self.slot_req[s] = req
                    self.slot_tokens[s] = []
                    self._reset_slot_cache(s)
                    self.slot_pos[s] = 0
            active = [s for s in range(self.slots)
                      if self.slot_req[s] is not None]
            if not active:
                break
            pos = int(self.slot_pos[active].max())
            tok = np.zeros((self.slots, 1), np.int32)
            for s in active:
                req = self.slot_req[s]
                p = int(self.slot_pos[s])
                if p < len(req.prompt):
                    tok[s, 0] = req.prompt[p]
                elif self.slot_tokens[s]:
                    tok[s, 0] = self.slot_tokens[s][-1]
                else:
                    tok[s, 0] = req.prompt[-1]
            logits, self.caches = self._serve(
                self.params, self.caches, jnp.asarray(tok),
                jnp.asarray(pos, jnp.int32))
            logits = np.asarray(logits[:, 0], np.float32)
            for s in active:
                req = self.slot_req[s]
                self.slot_pos[s] += 1
                p = int(self.slot_pos[s])
                if p >= len(req.prompt):
                    if req.temperature > 0:
                        self.key, sub = jax.random.split(self.key)
                        nxt = int(jax.random.categorical(
                            sub, jnp.asarray(logits[s]) / req.temperature))
                    else:
                        nxt = int(np.argmax(logits[s]))
                    self.slot_tokens[s].append(nxt)
                    if len(self.slot_tokens[s]) >= req.max_new_tokens or \
                            p >= self.max_len - 1:
                        done.append(Completion(rid=req.rid,
                                               tokens=self.slot_tokens[s]))
                        self.slot_req[s] = None
        return done
