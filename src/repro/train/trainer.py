"""Training loop with checkpoint/restart, preemption handling, straggler
detection, gradient compression, and pluggable optimizers.

Fault-tolerance contract (scaled-down single-host realization of the
1000-node design; see DESIGN.md section 6):

  * auto-resume: newest checkpoint in ``ckpt_dir`` is restored on start;
    the data pipeline is stateless-by-step so the token stream replays
    exactly;
  * preemption: SIGTERM/SIGINT triggers an emergency checkpoint at the next
    step boundary, then a clean exit (exit code 17 signals "resumable");
  * straggler mitigation: per-step wall times feed a rolling median; steps
    slower than ``straggler_factor`` x median are logged with the step
    payload so an orchestrator can reshard/replace the slow host (on a real
    cluster this hooks the coordination service; here it is surfaced in
    metrics.jsonl);
  * elastic restart: checkpoints store full logical arrays, so a restart may
    use a different mesh/host count (restore_checkpoint re-device_puts).
"""

from __future__ import annotations

import dataclasses
import json
import signal
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (latest_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.data import DataConfig, SyntheticTokens
from repro.models import build_loss_fn, init_model
from repro.models.config import ModelConfig
from repro.optim import (AdamWConfig, CompressConfig, adamw_init,
                         adamw_update, compress_grads, compress_init,
                         global_norm)


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 128
    ckpt_dir: str = "checkpoints"
    save_every: int = 50
    log_every: int = 10
    keep: int = 3
    seed: int = 0
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    compress: Optional[CompressConfig] = None
    straggler_factor: float = 3.0
    metrics_path: Optional[str] = None


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig):
        self.cfg = cfg
        self.tcfg = tcfg
        self.data = SyntheticTokens(DataConfig(
            vocab_size=cfg.vocab_size, batch=tcfg.batch,
            seq_len=tcfg.seq_len, seed=tcfg.seed))
        self._preempted = False
        self._step_times: list[float] = []
        self._metrics_file = None
        if tcfg.metrics_path:
            Path(tcfg.metrics_path).parent.mkdir(parents=True, exist_ok=True)
            self._metrics_file = open(tcfg.metrics_path, "a")

        loss_fn = build_loss_fn(cfg)
        ocfg = tcfg.optimizer

        @jax.jit
        def train_step(params, ostate, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            gnorm = global_norm(grads)
            return loss, grads, gnorm

        @jax.jit
        def apply_update(grads, ostate, params):
            return adamw_update(grads, ostate, params, ocfg)

        self._fwd_bwd = train_step
        self._apply = apply_update

    # -- fault tolerance ---------------------------------------------------

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def _log(self, rec: dict):
        if self._metrics_file:
            self._metrics_file.write(json.dumps(rec) + "\n")
            self._metrics_file.flush()

    def _straggler_check(self, step: int, dt: float):
        self._step_times.append(dt)
        window = self._step_times[-50:]
        med = float(np.median(window))
        if len(window) >= 10 and dt > self.tcfg.straggler_factor * med:
            self._log({"event": "straggler", "step": step, "dt": dt,
                       "median": med})

    # -- main loop -----------------------------------------------------------

    def run(self) -> dict:
        tcfg = self.tcfg
        self._install_signal_handlers()
        key = jax.random.PRNGKey(tcfg.seed)
        params = init_model(key, self.cfg)
        ostate = adamw_init(params, tcfg.optimizer)
        cstate = compress_init(params, tcfg.compress) if tcfg.compress \
            else None
        start_step = 0

        ck = latest_checkpoint(tcfg.ckpt_dir)
        if ck is not None:
            start_step, (params, ostate), meta = restore_checkpoint(
                ck, (params, ostate))
            self._log({"event": "resumed", "step": start_step,
                       "from": str(ck)})

        losses = []
        step = start_step
        for step in range(start_step, tcfg.steps):
            t0 = time.time()
            batch_np = self.data.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            loss, grads, gnorm = self._fwd_bwd(params, ostate, batch)
            if cstate is not None:
                grads, cstate, cstats = compress_grads(
                    grads, cstate, tcfg.compress,
                    jax.random.fold_in(key, step))
            params, ostate = self._apply(grads, ostate, params)
            loss_f = float(loss)
            losses.append(loss_f)
            dt = time.time() - t0
            self._straggler_check(step, dt)
            if step % tcfg.log_every == 0:
                self._log({"event": "step", "step": step, "loss": loss_f,
                           "gnorm": float(gnorm), "dt": dt})
            if (step + 1) % tcfg.save_every == 0:
                save_checkpoint(tcfg.ckpt_dir, step + 1, (params, ostate),
                                keep=tcfg.keep,
                                meta={"loss": loss_f})
            if self._preempted:
                save_checkpoint(tcfg.ckpt_dir, step + 1, (params, ostate),
                                keep=tcfg.keep, meta={"preempted": True})
                self._log({"event": "preempted", "step": step + 1})
                return {"status": "preempted", "step": step + 1,
                        "losses": losses}
        save_checkpoint(tcfg.ckpt_dir, tcfg.steps, (params, ostate),
                        keep=tcfg.keep, meta={"final": True})
        return {"status": "done", "step": tcfg.steps, "losses": losses,
                "params": params}
