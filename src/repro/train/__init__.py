from .trainer import TrainConfig, Trainer  # noqa: F401
from .server import DecodeServer, Request, Completion  # noqa: F401
