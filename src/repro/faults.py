"""Deterministic fault-injection harness (DESIGN.md section 13).

Faults are declared as plain data (:class:`Fault`) and armed with the
:func:`inject` context manager; instrumented sites in the factorization
drivers and the serve loop consult the active set by name:

* ``"chol.diag"``  -- perturb the updated diagonal tile of column
  ``column`` just before its dense factor: ``kind="nan"`` poisons one
  entry, ``kind="indefinite"`` subtracts ``magnitude * scale * I``
  (``scale`` = the tile's max |diag| entry), making the tile genuinely
  indefinite.
* ``"chol.panel"`` -- poison one entry of panel tile ``tile``'s basis
  right after the column's ARA / rounding pass (a NaN produced
  mid-panel).
* ``"serve.admit"`` -- hold request ``rid`` out of slot admission for
  ``delay`` ticks (a delayed request, for deadline/timeout tests).
* ``"serve.solve"`` -- overwrite request ``rid``'s column of a packed
  solve/sample result block with NaN on the host (a poisoned co-batched
  column, for isolation tests).

Everything is host-driven and deterministic: no randomness, no clocks,
and each fault counts its own firings (``once=True`` faults fire a single
time). The instrumented sites gate on :func:`active`, which is one
module-global truthiness check -- with no injection context open the
fast paths never see the harness (the ``obs`` zero-cost contract).

Input-level mutators (:func:`poison_tile`, :func:`make_diag_indefinite`,
:func:`spike_rank`) build corrupted *operands* instead of intercepting
mid-flight -- the honest way to provoke rank overflow (the spiked tile
really has high rank) and indefinite inputs end-to-end. They operate
structurally (``dataclasses.replace``) so this module never imports the
core package (the drivers import *us*).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

__all__ = [
    "Fault", "inject", "active", "corrupt_diag", "corrupt_panel",
    "defer_admission", "corrupt_result_block", "poison_tile",
    "make_diag_indefinite", "spike_rank",
]


@dataclasses.dataclass
class Fault:
    """One armed fault; see the module docstring for the site semantics."""

    site: str                      # "chol.diag" | "chol.panel" |
                                   # "serve.admit" | "serve.solve"
    kind: str = "nan"              # "nan" | "indefinite" | "delay"
    column: Optional[int] = None   # factorization column to fire at
                                   # (None = first visit)
    tile: int = 0                  # panel batch position ("chol.panel")
    magnitude: float = 4.0         # indefinite perturbation strength
    delay: int = 0                 # "serve.admit": ticks to hold
    rid: Optional[int] = None      # serve sites: target request id
    once: bool = True
    fired: int = 0                 # firing count (mutated by the sites)


_STACK: List[List[Fault]] = []


def active() -> bool:
    """True when any :func:`inject` context is open (the site gate)."""
    return bool(_STACK)


@contextlib.contextmanager
def inject(*faults: Fault):
    """Arm ``faults`` for the dynamic extent of the ``with`` block."""
    _STACK.append(list(faults))
    try:
        yield faults
    finally:
        _STACK.pop()


def _matching(site: str, column: Optional[int] = None,
              rid: Optional[int] = None) -> List[Fault]:
    out = []
    for frame in _STACK:
        for f in frame:
            if f.site != site:
                continue
            if f.site != "serve.admit" and f.once and f.fired > 0:
                continue
            if column is not None and f.column is not None \
                    and f.column != column:
                continue
            if rid is not None and f.rid is not None and f.rid != rid:
                continue
            out.append(f)
    return out


# -- factorization sites -------------------------------------------------------


def corrupt_diag(Akk, column: int):
    """Apply armed ``"chol.diag"`` faults to one updated diagonal tile."""
    for f in _matching("chol.diag", column=column):
        f.fired += 1
        if f.kind == "nan":
            Akk = Akk.at[0, 0].set(jnp.nan)
        elif f.kind == "indefinite":
            b = Akk.shape[-1]
            scale = jnp.maximum(jnp.max(jnp.abs(jnp.diagonal(Akk))), 1.0)
            Akk = Akk - f.magnitude * scale * jnp.eye(b, dtype=Akk.dtype)
        else:
            raise ValueError(f"chol.diag fault kind {f.kind!r}")
    return Akk


def corrupt_panel(Q, column: int):
    """Apply armed ``"chol.panel"`` faults to a (T, b, r) panel basis."""
    for f in _matching("chol.panel", column=column):
        if f.kind != "nan":
            raise ValueError(f"chol.panel fault kind {f.kind!r}")
        if f.tile < Q.shape[0]:
            f.fired += 1
            Q = Q.at[f.tile, 0, 0].set(jnp.nan)
    return Q


# -- serve sites ---------------------------------------------------------------


def defer_admission(rid: int) -> bool:
    """True while an armed ``"serve.admit"`` fault still holds ``rid``
    out of slot admission (one firing per held tick, up to ``delay``)."""
    for f in _matching("serve.admit", rid=rid):
        if f.fired < f.delay:
            f.fired += 1
            return True
    return False


def corrupt_result_block(X: np.ndarray, rids: List[Optional[int]]):
    """NaN-poison the columns of a packed host result block whose rids an
    armed ``"serve.solve"`` fault targets (``rids[i]`` None = idle)."""
    for f in _matching("serve.solve"):
        for i, rid in enumerate(rids):
            if rid is None:
                continue
            if f.rid is None or f.rid == rid:
                f.fired += 1
                if not X.flags.writeable:   # np.asarray of a jax array
                    X = X.copy()
                X[:, i] = np.nan
    return X


# -- input-level mutators ------------------------------------------------------


def poison_tile(A, i: int, j: int):
    """A copy of TLR matrix ``A`` with a NaN planted in the stored basis
    of off-diagonal tile ``(i, j)`` (``i > j``, packed-lower index)."""
    from .core.tlr import tril_index

    t = tril_index(i, j)
    return dataclasses.replace(
        A, U=A.U.at[t, 0, 0].set(jnp.nan),
        ranks=A.ranks.at[t].set(jnp.maximum(A.ranks[t], 1)))


def make_diag_indefinite(A, k: int, magnitude: float = 4.0):
    """A copy of ``A`` whose ``k``-th diagonal tile is shifted indefinite
    (subtract ``magnitude * max|diag| * I``)."""
    Dk = A.D[k]
    scale = jnp.maximum(jnp.max(jnp.abs(jnp.diagonal(Dk))), 1.0)
    Dk = Dk - magnitude * scale * jnp.eye(Dk.shape[-1], dtype=Dk.dtype)
    return dataclasses.replace(A, D=A.D.at[k].set(Dk))


def spike_rank(A, i: int, j: int, seed: int = 0, scale: float = 1.0,
               compensate: bool = True):
    """A copy of ``A`` whose tile ``(i, j)`` is replaced by a full-rank
    random factor pair at the storage width -- a genuine rank spike: the
    tile's numerical rank exceeds any cap below ``min(b, r_max)``, so a
    tight-eps factorization must overflow there.

    ``compensate`` (default) bumps diagonal tiles ``i`` and ``j`` by the
    spectral norm of the tile change, which keeps an SPD operand SPD --
    without it the replacement typically makes the matrix indefinite and
    the factorization exercises the SPD-breakdown ladder instead of the
    rank-overflow one."""
    from .core.tlr import tril_index

    t = tril_index(i, j)
    b, r = A.U.shape[1], A.U.shape[2]
    rng = np.random.default_rng(seed)
    Us = rng.standard_normal((b, r)) * scale
    Vs = rng.standard_normal((b, r)) * scale
    D = A.D
    if compensate:
        old = np.asarray(A.U[t]) @ np.asarray(A.V[t]).T
        margin = 1.01 * (np.linalg.norm(Us @ Vs.T, 2)
                         + np.linalg.norm(old, 2))
        eye = margin * jnp.eye(b, dtype=A.D.dtype)
        D = D.at[i].add(eye).at[j].add(eye)
    return dataclasses.replace(
        A, D=D, U=A.U.at[t].set(jnp.asarray(Us, A.U.dtype)),
        V=A.V.at[t].set(jnp.asarray(Vs, A.V.dtype)),
        ranks=A.ranks.at[t].set(r))
