"""Server instrumentation: per-request latency, per-tick occupancy.

``ServerStats`` is the serving analogue of the factorization drivers'
``stats`` dict: every tick records how many slots carried live work, every
completion records its end-to-end latency, and ``summary()`` collapses the
record into the p50/p99 + occupancy numbers the serve bench writes to
``BENCH_serve.json``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class ServerStats:
    """Occupancy / latency record of one server lifetime."""

    slots: int
    ticks: int = 0
    admitted: int = 0
    completed: int = 0
    # Degradation counters (DESIGN.md section 13): submit-time
    # rejections, deadline evictions, degraded (error) completions, and
    # breakdown-retry re-admissions.
    rejected: int = 0
    timeouts: int = 0
    errors: int = 0
    pcg_retries: int = 0
    tick_active: List[int] = dataclasses.field(default_factory=list)
    tick_seconds: List[float] = dataclasses.field(default_factory=list)
    latencies: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict)
    request_ticks: Dict[str, List[int]] = dataclasses.field(
        default_factory=dict)

    def record_tick(self, active: int, seconds: float) -> None:
        self.ticks += 1
        self.tick_active.append(int(active))
        self.tick_seconds.append(float(seconds))

    def record_completion(self, kind: str, latency_s: float,
                          ticks: int) -> None:
        self.completed += 1
        self.latencies.setdefault(kind, []).append(float(latency_s))
        self.request_ticks.setdefault(kind, []).append(int(ticks))

    def occupancy(self) -> float:
        """Mean fraction of slots carrying live work per tick -- the
        serving-side mirror of the factorization's padded-vs-useful ratio
        (idle slots are padding). 0.0 before the first tick."""
        if not self.tick_active:
            return 0.0
        return float(np.mean(self.tick_active)) / float(self.slots)

    def latency_percentiles(self, kind: str | None = None) -> dict:
        """p50/p99 (plus mean/max) latency in seconds, overall or for one
        request kind. When nothing of that kind completed yet the
        percentile fields are ``None`` (JSON ``null``) with ``count`` 0 --
        feeding an empty list to ``np.percentile`` raises, and reporting
        0.0 latency for work that never ran poisons downstream mins."""
        if kind is None:
            vals = [v for lat in self.latencies.values() for v in lat]
        else:
            vals = list(self.latencies.get(kind, []))
        if not vals:
            return {"p50_s": None, "p99_s": None, "mean_s": None,
                    "max_s": None, "count": 0}
        a = np.asarray(vals)
        return {"p50_s": float(np.percentile(a, 50)),
                "p99_s": float(np.percentile(a, 99)),
                "mean_s": float(a.mean()), "max_s": float(a.max()),
                "count": int(a.size)}

    def summary(self) -> dict:
        """The machine-readable record (the ``BENCH_serve.json`` payload):
        occupancy, throughput, and per-kind + overall p50/p99."""
        wall = float(np.sum(self.tick_seconds))
        out = {
            "slots": self.slots,
            "ticks": self.ticks,
            "admitted": self.admitted,
            "completed": self.completed,
            "occupancy": self.occupancy(),
            "wall_s": wall,
            "requests_per_s": (self.completed / wall) if wall > 0 else 0.0,
            "latency": self.latency_percentiles(),
            "health": {
                "rejected": self.rejected,
                "timeouts": self.timeouts,
                "errors": self.errors,
                "pcg_retries": self.pcg_retries,
            },
        }
        for kind in sorted(self.latencies):
            out[f"latency_{kind}"] = self.latency_percentiles(kind)
        from .. import obs

        if obs.enabled():
            # The server's slice of the active telemetry recording:
            # per-tick-stage seconds (pack/dispatch/sync/evict) next to the
            # occupancy/latency record they explain.
            out["telemetry"] = obs.metrics_snapshot(cats=("serve",))
        return out
