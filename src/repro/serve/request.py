"""Request/result records for the TLR inference server.

A :class:`ServeRequest` names one unit of linear-algebra work against a
resident factorization: a direct ``solve`` (one TRSM sweep pair), a
``logdet`` (memoized scalar), a posterior ``sample`` (one triangular
product of a fresh Gaussian draw), or an iterative ``pcg_solve`` with a
*per-request* tolerance and iteration budget. Requests are host-side plain
data -- the server packs their columns into fixed-shape device blocks at
tick time (DESIGN.md section 10).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np


KINDS = ("solve", "logdet", "sample", "pcg_solve")

# how many server ticks each kind occupies a slot for, minimum: the direct
# kinds complete in the tick they are admitted; pcg_solve iterates.
ONE_TICK_KINDS = ("solve", "logdet", "sample")


class RequestRejected(ValueError):
    """Typed submit-time rejection (DESIGN.md section 13).

    Raised before a request can touch the queue or a slot: non-finite or
    mis-shaped right-hand sides, unknown request kinds, and unknown or
    evicted factorization ids. A ``ValueError`` subclass so existing
    callers that guard submit with ``except ValueError`` keep working;
    ``reason`` / ``kind`` / ``fid`` make the rejection machine-readable.
    """

    def __init__(self, reason: str, *, kind: Optional[str] = None,
                 fid: Optional[str] = None):
        super().__init__(reason)
        self.reason = reason
        self.kind = kind
        self.fid = fid


@dataclasses.dataclass
class ServeRequest:
    """One inference request.

    ``rhs`` is required for ``solve`` / ``pcg_solve`` (a length-n vector);
    ``seed`` feeds the per-request PRNG key of ``sample`` (defaults to the
    rid assigned at submit, so results are reproducible from the request
    id alone); ``tol`` / ``maxiter`` apply to ``pcg_solve`` only. ``fid``
    selects the resident factorization (None = the server's sole
    registration).
    """

    kind: str
    rhs: Optional[np.ndarray] = None
    tol: float = 1e-6
    maxiter: int = 200
    seed: Optional[int] = None
    fid: Optional[str] = None
    rid: int = -1                 # assigned by the queue at submit
    deadline_ticks: Optional[int] = None
                                  # evict (error result) if not complete
                                  # within this many ticks of submission
    retries: int = 0              # pcg_solve: re-admissions allowed after
                                  # a breakdown, with exponential backoff

    def sample_key(self) -> jax.Array:
        """The per-request PRNG key (``sample`` kind): derived from
        ``seed`` (or the rid), so a sequential re-run reproduces the
        server's draw exactly."""
        seed = self.seed if self.seed is not None else self.rid
        return jax.random.PRNGKey(int(seed))


@dataclasses.dataclass
class ServeResult:
    """Completion record handed back by the server.

    ``value`` is an ``(n,)`` numpy vector (``solve`` / ``sample`` /
    ``pcg_solve``) or a float (``logdet``). ``iterations`` / ``converged``
    / ``breakdown`` / ``history`` carry the per-column PCG diagnostics for
    ``pcg_solve`` (iterations is 0 and converged True for direct kinds).
    ``latency_s`` spans submit to completion (queue wait included);
    ``ticks`` counts the server ticks the request occupied a slot.

    ``ok`` is False for degraded completions, with ``error`` naming the
    path: deadline timeouts (``"timeout"``, value None), non-finite result
    columns isolated from a co-batched block (``"nonfinite_result"``,
    value None), requests stranded by ``evict_resident``
    (``"resident_evicted"``, value None), and PCG breakdowns that
    exhausted their retry budget (``"pcg_breakdown"`` -- value keeps the
    last finite iterate for diagnostics). ``attempts`` counts admissions
    (> 1 after breakdown-retry re-admissions).
    """

    rid: int
    kind: str
    fid: str
    value: object
    iterations: int = 0
    converged: bool = True
    breakdown: Optional[str] = None
    history: Optional[list] = None
    latency_s: float = 0.0
    ticks: int = 0
    ok: bool = True
    error: Optional[str] = None
    attempts: int = 1
