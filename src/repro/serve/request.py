"""Request/result records for the TLR inference server.

A :class:`ServeRequest` names one unit of linear-algebra work against a
resident factorization: a direct ``solve`` (one TRSM sweep pair), a
``logdet`` (memoized scalar), a posterior ``sample`` (one triangular
product of a fresh Gaussian draw), or an iterative ``pcg_solve`` with a
*per-request* tolerance and iteration budget. Requests are host-side plain
data -- the server packs their columns into fixed-shape device blocks at
tick time (DESIGN.md section 10).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np


KINDS = ("solve", "logdet", "sample", "pcg_solve")

# how many server ticks each kind occupies a slot for, minimum: the direct
# kinds complete in the tick they are admitted; pcg_solve iterates.
ONE_TICK_KINDS = ("solve", "logdet", "sample")


@dataclasses.dataclass
class ServeRequest:
    """One inference request.

    ``rhs`` is required for ``solve`` / ``pcg_solve`` (a length-n vector);
    ``seed`` feeds the per-request PRNG key of ``sample`` (defaults to the
    rid assigned at submit, so results are reproducible from the request
    id alone); ``tol`` / ``maxiter`` apply to ``pcg_solve`` only. ``fid``
    selects the resident factorization (None = the server's sole
    registration).
    """

    kind: str
    rhs: Optional[np.ndarray] = None
    tol: float = 1e-6
    maxiter: int = 200
    seed: Optional[int] = None
    fid: Optional[str] = None
    rid: int = -1                 # assigned by the queue at submit

    def sample_key(self) -> jax.Array:
        """The per-request PRNG key (``sample`` kind): derived from
        ``seed`` (or the rid), so a sequential re-run reproduces the
        server's draw exactly."""
        seed = self.seed if self.seed is not None else self.rid
        return jax.random.PRNGKey(int(seed))


@dataclasses.dataclass
class ServeResult:
    """Completion record handed back by the server.

    ``value`` is an ``(n,)`` numpy vector (``solve`` / ``sample`` /
    ``pcg_solve``) or a float (``logdet``). ``iterations`` / ``converged``
    / ``breakdown`` / ``history`` carry the per-column PCG diagnostics for
    ``pcg_solve`` (iterations is 0 and converged True for direct kinds).
    ``latency_s`` spans submit to completion (queue wait included);
    ``ticks`` counts the server ticks the request occupied a slot.
    """

    rid: int
    kind: str
    fid: str
    value: object
    iterations: int = 0
    converged: bool = True
    breakdown: Optional[str] = None
    history: Optional[list] = None
    latency_s: float = 0.0
    ticks: int = 0
