"""Host-side FIFO request queue for the TLR inference server.

The queue is the "pending work" side of the paper's Algorithm 5 loop:
slots that free up at the end of a tick refill from here in submit order,
so shapes stay fixed and occupancy stays high while there is work to do.
Purely host-side (the server's tick loop is single-threaded, like the
``DecodeServer`` it mirrors); rids are assigned monotonically at submit.
"""

from __future__ import annotations

from collections import deque
from typing import Optional

from .request import ServeRequest


class RequestQueue:
    """FIFO of :class:`ServeRequest` with monotone rid assignment."""

    def __init__(self):
        self._q: deque[ServeRequest] = deque()
        self._next_rid = 0

    def submit(self, req: ServeRequest) -> int:
        """Assign the next rid (unless the caller set one >= 0), enqueue,
        and return the rid."""
        if req.rid < 0:
            req.rid = self._next_rid
        self._next_rid = max(self._next_rid, req.rid) + 1
        self._q.append(req)
        return req.rid

    def pop(self) -> Optional[ServeRequest]:
        """Next request in FIFO order, or None when empty."""
        return self._q.popleft() if self._q else None

    def peek(self) -> Optional[ServeRequest]:
        return self._q[0] if self._q else None

    def drain(self, pred) -> list[ServeRequest]:
        """Remove and return every queued request matching ``pred``,
        preserving FIFO order among both kept and drained requests (the
        server's deadline scan evicts expired requests without perturbing
        the admission order of the rest)."""
        out = [r for r in self._q if pred(r)]
        if out:
            self._q = deque(r for r in self._q if not pred(r))
        return out

    def requeue(self, reqs: list[ServeRequest]) -> None:
        """Push ``reqs`` back to the *front*, preserving their order --
        used to put back requests popped during refill but held out of
        admission (fault-injected delays), so they stay ahead of newer
        work."""
        for r in reversed(reqs):
            self._q.appendleft(r)

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
