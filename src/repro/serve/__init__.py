"""TLR inference serving: continuous batching over resident factorizations.

The subsystem mirrors the paper's Algorithm 5 on the read side: a fixed
``(n, slots)`` right-hand-side block, heterogeneous requests (``solve`` /
``logdet`` / ``sample`` / ``pcg_solve``) packed into its columns,
converged work evicted and refilled from a host-side queue each tick --
shapes fixed, occupancy high, zero recompiles after warmup. See
DESIGN.md section 10 and ``examples/serve_gp.py``.
"""

from .queue import RequestQueue
from .request import KINDS, RequestRejected, ServeRequest, ServeResult
from .server import TLRServer
from .stats import ServerStats

__all__ = [
    "KINDS",
    "RequestQueue",
    "RequestRejected",
    "ServeRequest",
    "ServeResult",
    "ServerStats",
    "TLRServer",
]
