"""``TLRServer``: continuous-batching inference over resident TLR
factorizations.

The server is the serving-side mirror of the paper's Algorithm 5: a fixed
block of ``slots`` right-hand-side columns, heterogeneous requests packed
into it, finished work evicted and the freed columns refilled from a FIFO
queue every tick -- shapes never change, so nothing recompiles after
warmup (the unified ``trace_count`` registry pins this in the tests).

One tick:

0. **deadline scan** -- requests whose ``deadline_ticks`` elapsed leave
   as ``error="timeout"`` results (queued or slotted; a slotted PCG
   column is cancelled mid-flight), freeing their slots for this tick's
   refill (DESIGN.md section 13).
1. **refill** -- free slots pop requests off the queue in submit order;
   ``pcg_solve`` admissions stage their column into the per-factorization
   :class:`~..core.solve.BatchedPCG` engine, ``sample`` admissions draw
   their per-request Gaussian (the same ``(n, 1)`` draw the sequential
   ``.sample`` path makes, so results are reproducible per request id).
2. **compute** -- per resident factorization, the direct kinds run *once*
   for the whole block: solve columns pack host-side into one ``(n,
   slots)`` block through the plan-dispatched multi-RHS TRSM, sample
   columns through one batched ``L @ Z``; ``logdet`` completes from the
   scalar memoized at registration; PCG engines advance one
   ``check_every`` window with per-column convergence masks.
3. **evict** -- every completed request leaves its slot with a
   :class:`ServeResult` (latency, iteration counts, per-column history);
   the slot is free for the next tick's refill. Non-finite columns in a
   packed block are isolated as ``error="nonfinite_result"`` without
   touching co-batched neighbours; PCG breakdowns re-admit with
   exponential backoff up to ``ServeRequest.retries``.

All packing/unpacking is host-side numpy around one device call and one
``np.asarray`` pull per op per tick; no per-column-index device ops touch
the hot path, so the compiled-executable set is closed after
:meth:`TLRServer.warmup` (DESIGN.md section 10).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import solve as _solve
from .. import faults, obs
from .queue import RequestQueue
from .request import KINDS, RequestRejected, ServeRequest, ServeResult
from .stats import ServerStats


@dataclasses.dataclass
class _Resident:
    """One registered factorization and its serving-side cache."""

    fid: str
    fact: object                      # TLRFactorization
    operator: object = None           # TLROperator (pcg_solve matvec), or None
    logdet: Optional[float] = None    # memoized at registration
    engine: object = None             # BatchedPCG, created when operator given


@dataclasses.dataclass
class _Slot:
    """Occupied-slot record: the request plus admission bookkeeping."""

    req: ServeRequest
    admit_tick: int
    z: Optional[np.ndarray] = None    # sample kinds: the admission-time draw
    attempts: int = 1                 # admissions so far (breakdown retries)


class TLRServer:
    """Slot-based continuous-batching server over resident factorizations.

    Parameters
    ----------
    slots : fixed RHS block width -- every device op in the serve path runs
        at this column count, occupied or not (idle columns are zeros).
    check_every : PCG window length per tick (one host sync per window,
        PR 6 semantics).
    seed : base seed for ``sample`` requests that don't carry their own.
    """

    def __init__(self, slots: int = 8, *, check_every: int = 4,
                 seed: int = 0):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        self.slots = int(slots)
        self.check_every = max(1, int(check_every))
        self.seed = int(seed)
        self._residents: Dict[str, _Resident] = {}
        self._queue = RequestQueue()
        self._slots: List[Optional[_Slot]] = [None] * self.slots
        self.stats = ServerStats(slots=self.slots)
        self.results: Dict[int, ServeResult] = {}
        self._submit_t: Dict[int, float] = {}
        self._submit_tick: Dict[int, int] = {}
        self._evicted: set = set()
        # Breakdown-retry holding pen: (req, ready_tick, attempts) tuples
        # re-admitted (ahead of the queue) once their backoff elapses.
        self._backoff: List[tuple] = []
        self._tick = 0
        self._warm = False

    # -- registration ------------------------------------------------------

    def register(self, fid: str, fact, operator=None) -> None:
        """Make factorization ``fact`` resident under name ``fid``.

        ``operator`` (the compressed A) enables ``pcg_solve`` requests
        against this resident: the server builds a width-``slots``
        :class:`BatchedPCG` engine over it, preconditioned by ``fact``.
        The logdet scalar is memoized here so ``logdet`` requests complete
        in one tick with zero device work.
        """
        if fid in self._residents:
            raise ValueError(f"factorization {fid!r} already registered")
        res = _Resident(fid=fid, fact=fact, operator=operator)
        res.logdet = float(fact.logdet())
        if operator is not None:
            res.engine = _solve.BatchedPCG(
                operator, fact.n, self.slots, precond=fact,
                check_every=self.check_every, dtype=fact.dtype)
        self._residents[fid] = res
        self._warm = False

    def _resident(self, fid: Optional[str]) -> _Resident:
        if fid is None:
            if len(self._residents) != 1:
                raise RequestRejected(
                    "request.fid is required when "
                    f"{len(self._residents)} factorizations are registered",
                    fid=fid)
            return next(iter(self._residents.values()))
        if fid not in self._residents:
            if fid in self._evicted:
                raise RequestRejected(
                    f"factorization {fid!r} was evicted and is no longer "
                    f"resident (registered: {sorted(self._residents)})",
                    fid=fid)
            raise RequestRejected(f"unknown factorization {fid!r} "
                                  f"(registered: {sorted(self._residents)})",
                                  fid=fid)
        return self._residents[fid]

    def evict_resident(self, fid: str) -> None:
        """Drop a resident factorization. Requests already queued or
        slotted against it complete as error results
        (``error="resident_evicted"``) rather than vanishing; later
        submits naming it are rejected with an 'evicted' message (a
        sharper error than 'unknown')."""
        if fid not in self._residents:
            raise RequestRejected(
                f"unknown factorization {fid!r} "
                f"(registered: {sorted(self._residents)})", fid=fid)
        res = self._residents.pop(fid)
        self._evicted.add(fid)
        for req in self._queue.drain(lambda r: r.fid == fid):
            self.stats.errors += 1
            self._complete_unslotted(req, error="resident_evicted")
        kept = []
        for req, ready, attempts in self._backoff:
            if req.fid == fid:
                self.stats.errors += 1
                self._complete_unslotted(req, error="resident_evicted")
            else:
                kept.append((req, ready, attempts))
        self._backoff = kept
        for i, slot in enumerate(self._slots):
            if slot is not None and slot.req.fid == fid:
                if slot.req.kind == "pcg_solve" and res.engine is not None:
                    res.engine.cancel(i)
                self.stats.errors += 1
                self._complete(i, None, converged=False, ok=False,
                               error="resident_evicted")

    # -- submission --------------------------------------------------------

    def submit(self, req: ServeRequest) -> int:
        """Validate and enqueue; returns the assigned request id.

        Validation is eager (host-side, before the request can occupy a
        slot): unknown kinds, missing/mis-sized/**non-finite** right-hand
        sides, unknown or evicted factorization ids, ``sample`` against an
        LDL^T factorization, and ``pcg_solve`` against a resident
        registered without its operator all raise :class:`RequestRejected`
        here -- a poisoned RHS is stopped before it can be packed into a
        block next to healthy co-batched requests.
        """
        try:
            return self._validate_and_enqueue(req)
        except RequestRejected:
            self.stats.rejected += 1
            raise

    def _validate_and_enqueue(self, req: ServeRequest) -> int:
        if req.kind not in KINDS:
            raise RequestRejected(f"unknown request kind {req.kind!r} "
                                  f"(one of {KINDS})", kind=req.kind)
        res = self._resident(req.fid)
        req.fid = res.fid
        if req.kind in ("solve", "pcg_solve"):
            if req.rhs is None:
                raise RequestRejected(f"{req.kind} request requires rhs",
                                      kind=req.kind, fid=res.fid)
            rhs = np.asarray(req.rhs, np.dtype(res.fact.dtype)).reshape(-1)
            if rhs.shape[0] != res.fact.n:
                raise RequestRejected(
                    f"rhs length {rhs.shape[0]} != n="
                    f"{res.fact.n} of {res.fid!r}", kind=req.kind,
                    fid=res.fid)
            if not np.isfinite(rhs).all():
                bad = int(np.sum(~np.isfinite(rhs)))
                raise RequestRejected(
                    f"{req.kind} rhs contains {bad} non-finite entries",
                    kind=req.kind, fid=res.fid)
            req.rhs = rhs
        if req.kind == "sample" and res.fact.is_ldlt:
            raise RequestRejected(
                "sample requires a Cholesky factorization "
                f"({res.fid!r} is LDL^T)", kind=req.kind, fid=res.fid)
        if req.kind == "pcg_solve" and res.engine is None:
            raise RequestRejected(
                f"pcg_solve requires {res.fid!r} to be "
                "registered with its operator", kind=req.kind, fid=res.fid)
        rid = self._queue.submit(req)
        self._submit_t[rid] = time.perf_counter()
        self._submit_tick[rid] = self._tick
        return rid

    @property
    def pending(self) -> int:
        """Requests waiting in the queue (not yet in a slot)."""
        return len(self._queue)

    @property
    def active(self) -> int:
        """Requests currently occupying slots."""
        return sum(s is not None for s in self._slots)

    # -- warmup ------------------------------------------------------------

    def warmup(self) -> None:
        """Compile every fixed-shape executable the serve path uses, per
        resident: the ``(n, slots)`` solve block, the batched ``L @ Z``
        sample product, the ``(n, 1)`` per-request Gaussian draw, and one
        full PCG window (engines are reset after; the executables
        survive). After this the tick loop never traces -- the test suite
        pins it via the ``trace_count`` registry."""
        for res in self._residents.values():
            fact = res.fact
            B = jnp.zeros((fact.n, self.slots), fact.dtype)
            fact.solve(B).block_until_ready()
            if not fact.is_ldlt:
                jax.random.normal(jax.random.PRNGKey(0), (fact.n, 1),
                                  fact.dtype).block_until_ready()
                self._sample_block(res, B).block_until_ready()
            if res.engine is not None:
                res.engine.load(0, np.ones(fact.n), tol=0.0,
                                maxiter=self.check_every)
                res.engine.advance(self.check_every)
                res.engine.reset()
        self._warm = True

    # -- the tick ----------------------------------------------------------

    def _sample_block(self, res: _Resident, Z: jax.Array) -> jax.Array:
        """x = P^T L z for a packed draw block (the batched body of
        ``_mvn_sample_impl``, minus the draw -- draws happen per request
        at admission so results don't depend on slot placement)."""
        fact = res.fact
        X = fact.tri_matvec(Z)
        eperm = _solve.tile_perm_to_element_perm(fact.perm, fact.L.b)
        return _solve._unpermute_rows(X, eperm)

    def _admit(self, i: int, req: ServeRequest) -> None:
        slot = _Slot(req=req, admit_tick=self._tick)
        res = self._residents[req.fid]
        if req.kind == "sample":
            # The identical (n, 1) draw .sample(key, 1) makes, pulled to
            # host once so tick packing stays in numpy.
            z = jax.random.normal(req.sample_key(), (res.fact.n, 1),
                                  res.fact.dtype)
            slot.z = np.asarray(z)[:, 0]
        elif req.kind == "pcg_solve":
            res.engine.load(i, req.rhs, tol=req.tol, maxiter=req.maxiter)
        self._slots[i] = slot
        self.stats.admitted += 1

    def _complete(self, i: int, value, *, iterations: int = 0,
                  converged: bool = True, breakdown=None,
                  history=None, ok: bool = True,
                  error: Optional[str] = None) -> ServeResult:
        slot = self._slots[i]
        req = slot.req
        result = ServeResult(
            rid=req.rid, kind=req.kind, fid=req.fid, value=value,
            iterations=iterations, converged=converged, breakdown=breakdown,
            history=history,
            latency_s=time.perf_counter() - self._submit_t.pop(req.rid),
            ticks=self._tick - slot.admit_tick + 1, ok=ok, error=error,
            attempts=slot.attempts)
        self._submit_tick.pop(req.rid, None)
        self.results[req.rid] = result
        self.stats.record_completion(req.kind, result.latency_s,
                                     result.ticks)
        self._slots[i] = None
        return result

    def _complete_unslotted(self, req: ServeRequest, *, error: str,
                            attempts: int = 1) -> ServeResult:
        """Error completion for a request that never reached (or no longer
        holds) a slot -- deadline-expired in the queue, or stranded by
        ``evict_resident``."""
        result = ServeResult(
            rid=req.rid, kind=req.kind, fid=req.fid or "", value=None,
            converged=False,
            latency_s=time.perf_counter()
            - self._submit_t.pop(req.rid, time.perf_counter()),
            ticks=0, ok=False, error=error, attempts=attempts)
        self._submit_tick.pop(req.rid, None)
        self.results[req.rid] = result
        return result

    def _expired(self, req: ServeRequest) -> bool:
        if req.deadline_ticks is None:
            return False
        born = self._submit_tick.get(req.rid, self._tick)
        return self._tick - born >= req.deadline_ticks

    def _deadline_scan(self, done: List[ServeResult]) -> None:
        """Evict every request whose deadline passed: queued and
        backoff-held requests complete as unslotted timeouts; slotted ones
        free their slot (cancelling the PCG column mid-flight, so the
        freed column is refillable this very tick)."""
        for req in self._queue.drain(self._expired):
            self.stats.timeouts += 1
            done.append(self._complete_unslotted(req, error="timeout"))
        if self._backoff:
            kept = []
            for req, ready, attempts in self._backoff:
                if self._expired(req):
                    self.stats.timeouts += 1
                    done.append(self._complete_unslotted(
                        req, error="timeout", attempts=attempts - 1))
                else:
                    kept.append((req, ready, attempts))
            self._backoff = kept
        for i, slot in enumerate(self._slots):
            if slot is None or not self._expired(slot.req):
                continue
            res = self._residents.get(slot.req.fid)
            if slot.req.kind == "pcg_solve" and res is not None \
                    and res.engine is not None:
                res.engine.cancel(i)
            self.stats.timeouts += 1
            done.append(self._complete(i, None, converged=False, ok=False,
                                       error="timeout"))

    def _evict_block(self, idx: List[int], X: np.ndarray,
                     done: List[ServeResult]) -> None:
        """Complete a packed solve/sample block column-by-column, isolating
        any non-finite column as an ``error="nonfinite_result"`` completion
        -- a poisoned column never reaches a caller as a value, and never
        touches its co-batched neighbours (the block op already ran; the
        check is per-column on the host pull)."""
        if faults.active():
            rids = [self._slots[i].req.rid if (i in idx) else None
                    for i in range(self.slots)]
            X = faults.corrupt_result_block(X, rids)
        for i in idx:
            x = X[:, i].copy()
            if not np.isfinite(x).all():
                self.stats.errors += 1
                done.append(self._complete(i, None, converged=False,
                                           ok=False,
                                           error="nonfinite_result"))
            else:
                done.append(self._complete(i, x))

    def tick(self) -> List[ServeResult]:
        """One refill -> compute -> evict cycle; returns the requests
        completed this tick (in slot order per kind)."""
        if not self._warm:
            self.warmup()
        t0 = time.perf_counter()
        with obs.span("serve.tick", cat="serve", tick=self._tick) as _tsp:
            done: List[ServeResult] = []
            # 0. deadline scan: expired requests (queued, backoff-held, or
            # slotted) complete as timeout errors before refill, so their
            # slots are reusable this very tick.
            self._deadline_scan(done)
            # 1. refill free slots: breakdown retries whose backoff has
            # elapsed re-admit first (they are older than anything queued),
            # then the queue in FIFO order. Fault-injected admission delays
            # hold a popped request out for this tick and requeue it at the
            # front, preserving submit order.
            with obs.span("serve.pack", cat="serve", stage="refill"):
                ready = [e for e in self._backoff if e[1] <= self._tick]
                deferred: List[ServeRequest] = []
                for i in range(self.slots):
                    if self._slots[i] is not None:
                        continue
                    if ready:
                        entry = ready.pop(0)
                        self._backoff.remove(entry)
                        req, _rt, attempts = entry
                        self._admit(i, req)
                        self._slots[i].attempts = attempts
                        continue
                    while self._queue:
                        req = self._queue.pop()
                        if faults.active() and faults.defer_admission(req.rid):
                            deferred.append(req)
                            continue
                        self._admit(i, req)
                        break
                if deferred:
                    self._queue.requeue(deferred)
            self.stats.record_tick(self.active, 0.0)  # seconds patched below
            if obs.enabled():
                _tsp.set(active=self.active, pending=self.pending)
                obs.counter("occupancy", {"active": self.active,
                                          "slots": self.slots})
            # 2/3. compute + evict, one batched op per (resident, kind)
            for fid, res in self._residents.items():
                by_kind: Dict[str, List[int]] = {}
                for i, slot in enumerate(self._slots):
                    if slot is not None and slot.req.fid == fid:
                        by_kind.setdefault(slot.req.kind, []).append(i)
                if "logdet" in by_kind:
                    with obs.span("serve.evict", cat="serve", kind="logdet"):
                        for i in by_kind["logdet"]:
                            done.append(self._complete(i, res.logdet))
                if "solve" in by_kind:
                    idx = by_kind["solve"]
                    with obs.span("serve.pack", cat="serve", kind="solve",
                                  count=len(idx)):
                        B = np.zeros((res.fact.n, self.slots),
                                     np.dtype(res.fact.dtype))
                        for i in idx:
                            B[:, i] = self._slots[i].req.rhs
                    with obs.span("serve.dispatch", cat="serve",
                                  kind="solve"):
                        Xd = res.fact.solve(jnp.asarray(B))
                    with obs.span("serve.sync", cat="serve", kind="solve"):
                        X = np.asarray(Xd)
                    with obs.span("serve.evict", cat="serve", kind="solve"):
                        self._evict_block(idx, X, done)
                if "sample" in by_kind:
                    idx = by_kind["sample"]
                    with obs.span("serve.pack", cat="serve", kind="sample",
                                  count=len(idx)):
                        Z = np.zeros((res.fact.n, self.slots),
                                     np.dtype(res.fact.dtype))
                        for i in idx:
                            Z[:, i] = self._slots[i].z
                    with obs.span("serve.dispatch", cat="serve",
                                  kind="sample"):
                        Xd = self._sample_block(res, jnp.asarray(Z))
                    with obs.span("serve.sync", cat="serve", kind="sample"):
                        X = np.asarray(Xd)
                    with obs.span("serve.evict", cat="serve", kind="sample"):
                        self._evict_block(idx, X, done)
                if "pcg_solve" in by_kind:
                    with obs.span("serve.dispatch", cat="serve",
                                  kind="pcg_solve"):
                        res.engine.advance(self.check_every)
                    # ``done_columns`` rather than advance's return: a
                    # zero-rhs load finishes without ever activating.
                    with obs.span("serve.evict", cat="serve",
                                  kind="pcg_solve"):
                        for i in res.engine.done_columns:
                            x, iters, hist, conv = res.engine.evict(i)
                            slot = self._slots[i]
                            if hist.breakdown is not None and not conv \
                                    and slot.attempts <= slot.req.retries:
                                # Bounded retry with exponential backoff:
                                # free the slot without completing; the
                                # request re-admits from the holding pen
                                # once 2^(attempts-1) ticks elapse.
                                attempts = slot.attempts
                                self.stats.pcg_retries += 1
                                self._backoff.append(
                                    (slot.req,
                                     self._tick + 2 ** (attempts - 1),
                                     attempts + 1))
                                self._slots[i] = None
                                continue
                            broke = (hist.breakdown is not None
                                     and not conv)
                            if broke:
                                self.stats.errors += 1
                            done.append(self._complete(
                                i, x, iterations=iters, converged=conv,
                                breakdown=hist.breakdown, history=hist,
                                ok=not broke,
                                error="pcg_breakdown" if broke else None))
        self.stats.tick_seconds[-1] = time.perf_counter() - t0
        self._tick += 1
        return done

    def run(self, max_ticks: Optional[int] = None) -> Dict[int, ServeResult]:
        """Tick until the queue and every slot drain (or ``max_ticks``);
        returns all results completed so far, keyed by rid. Termination is
        guaranteed: direct kinds complete in their admission tick and PCG
        columns are bounded by their per-request ``maxiter``."""
        ticks = 0
        while self._queue or self.active or self._backoff:
            if max_ticks is not None and ticks >= max_ticks:
                break
            self.tick()
            ticks += 1
        return dict(self.results)

    def result(self, rid: int) -> ServeResult:
        if rid not in self.results:
            raise KeyError(f"request {rid} has not completed "
                           f"({self.pending} queued, {self.active} active)")
        return self.results[rid]
