"""``TLRServer``: continuous-batching inference over resident TLR
factorizations.

The server is the serving-side mirror of the paper's Algorithm 5: a fixed
block of ``slots`` right-hand-side columns, heterogeneous requests packed
into it, finished work evicted and the freed columns refilled from a FIFO
queue every tick -- shapes never change, so nothing recompiles after
warmup (the unified ``trace_count`` registry pins this in the tests).

One tick:

1. **refill** -- free slots pop requests off the queue in submit order;
   ``pcg_solve`` admissions stage their column into the per-factorization
   :class:`~..core.solve.BatchedPCG` engine, ``sample`` admissions draw
   their per-request Gaussian (the same ``(n, 1)`` draw the sequential
   ``.sample`` path makes, so results are reproducible per request id).
2. **compute** -- per resident factorization, the direct kinds run *once*
   for the whole block: solve columns pack host-side into one ``(n,
   slots)`` block through the plan-dispatched multi-RHS TRSM, sample
   columns through one batched ``L @ Z``; ``logdet`` completes from the
   scalar memoized at registration; PCG engines advance one
   ``check_every`` window with per-column convergence masks.
3. **evict** -- every completed request leaves its slot with a
   :class:`ServeResult` (latency, iteration counts, per-column history);
   the slot is free for the next tick's refill.

All packing/unpacking is host-side numpy around one device call and one
``np.asarray`` pull per op per tick; no per-column-index device ops touch
the hot path, so the compiled-executable set is closed after
:meth:`TLRServer.warmup` (DESIGN.md section 10).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import solve as _solve
from .. import obs
from .queue import RequestQueue
from .request import KINDS, ServeRequest, ServeResult
from .stats import ServerStats


@dataclasses.dataclass
class _Resident:
    """One registered factorization and its serving-side cache."""

    fid: str
    fact: object                      # TLRFactorization
    operator: object = None           # TLROperator (pcg_solve matvec), or None
    logdet: Optional[float] = None    # memoized at registration
    engine: object = None             # BatchedPCG, created when operator given


@dataclasses.dataclass
class _Slot:
    """Occupied-slot record: the request plus admission bookkeeping."""

    req: ServeRequest
    admit_tick: int
    z: Optional[np.ndarray] = None    # sample kinds: the admission-time draw


class TLRServer:
    """Slot-based continuous-batching server over resident factorizations.

    Parameters
    ----------
    slots : fixed RHS block width -- every device op in the serve path runs
        at this column count, occupied or not (idle columns are zeros).
    check_every : PCG window length per tick (one host sync per window,
        PR 6 semantics).
    seed : base seed for ``sample`` requests that don't carry their own.
    """

    def __init__(self, slots: int = 8, *, check_every: int = 4,
                 seed: int = 0):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        self.slots = int(slots)
        self.check_every = max(1, int(check_every))
        self.seed = int(seed)
        self._residents: Dict[str, _Resident] = {}
        self._queue = RequestQueue()
        self._slots: List[Optional[_Slot]] = [None] * self.slots
        self.stats = ServerStats(slots=self.slots)
        self.results: Dict[int, ServeResult] = {}
        self._submit_t: Dict[int, float] = {}
        self._tick = 0
        self._warm = False

    # -- registration ------------------------------------------------------

    def register(self, fid: str, fact, operator=None) -> None:
        """Make factorization ``fact`` resident under name ``fid``.

        ``operator`` (the compressed A) enables ``pcg_solve`` requests
        against this resident: the server builds a width-``slots``
        :class:`BatchedPCG` engine over it, preconditioned by ``fact``.
        The logdet scalar is memoized here so ``logdet`` requests complete
        in one tick with zero device work.
        """
        if fid in self._residents:
            raise ValueError(f"factorization {fid!r} already registered")
        res = _Resident(fid=fid, fact=fact, operator=operator)
        res.logdet = float(fact.logdet())
        if operator is not None:
            res.engine = _solve.BatchedPCG(
                operator, fact.n, self.slots, precond=fact,
                check_every=self.check_every, dtype=fact.dtype)
        self._residents[fid] = res
        self._warm = False

    def _resident(self, fid: Optional[str]) -> _Resident:
        if fid is None:
            if len(self._residents) != 1:
                raise ValueError(
                    "request.fid is required when "
                    f"{len(self._residents)} factorizations are registered")
            return next(iter(self._residents.values()))
        if fid not in self._residents:
            raise ValueError(f"unknown factorization {fid!r} "
                             f"(registered: {sorted(self._residents)})")
        return self._residents[fid]

    # -- submission --------------------------------------------------------

    def submit(self, req: ServeRequest) -> int:
        """Validate and enqueue; returns the assigned request id.

        Validation is eager (host-side, before the request can occupy a
        slot): unknown kinds, missing/mis-sized right-hand sides,
        ``sample`` against an LDL^T factorization, and ``pcg_solve``
        against a resident registered without its operator all raise here.
        """
        if req.kind not in KINDS:
            raise ValueError(f"unknown request kind {req.kind!r} "
                             f"(one of {KINDS})")
        res = self._resident(req.fid)
        req.fid = res.fid
        if req.kind in ("solve", "pcg_solve"):
            if req.rhs is None:
                raise ValueError(f"{req.kind} request requires rhs")
            rhs = np.asarray(req.rhs, np.dtype(res.fact.dtype)).reshape(-1)
            if rhs.shape[0] != res.fact.n:
                raise ValueError(f"rhs length {rhs.shape[0]} != n="
                                 f"{res.fact.n} of {res.fid!r}")
            req.rhs = rhs
        if req.kind == "sample" and res.fact.is_ldlt:
            raise ValueError("sample requires a Cholesky factorization "
                             f"({res.fid!r} is LDL^T)")
        if req.kind == "pcg_solve" and res.engine is None:
            raise ValueError(f"pcg_solve requires {res.fid!r} to be "
                             "registered with its operator")
        rid = self._queue.submit(req)
        self._submit_t[rid] = time.perf_counter()
        return rid

    @property
    def pending(self) -> int:
        """Requests waiting in the queue (not yet in a slot)."""
        return len(self._queue)

    @property
    def active(self) -> int:
        """Requests currently occupying slots."""
        return sum(s is not None for s in self._slots)

    # -- warmup ------------------------------------------------------------

    def warmup(self) -> None:
        """Compile every fixed-shape executable the serve path uses, per
        resident: the ``(n, slots)`` solve block, the batched ``L @ Z``
        sample product, the ``(n, 1)`` per-request Gaussian draw, and one
        full PCG window (engines are reset after; the executables
        survive). After this the tick loop never traces -- the test suite
        pins it via the ``trace_count`` registry."""
        for res in self._residents.values():
            fact = res.fact
            B = jnp.zeros((fact.n, self.slots), fact.dtype)
            fact.solve(B).block_until_ready()
            if not fact.is_ldlt:
                jax.random.normal(jax.random.PRNGKey(0), (fact.n, 1),
                                  fact.dtype).block_until_ready()
                self._sample_block(res, B).block_until_ready()
            if res.engine is not None:
                res.engine.load(0, np.ones(fact.n), tol=0.0,
                                maxiter=self.check_every)
                res.engine.advance(self.check_every)
                res.engine.reset()
        self._warm = True

    # -- the tick ----------------------------------------------------------

    def _sample_block(self, res: _Resident, Z: jax.Array) -> jax.Array:
        """x = P^T L z for a packed draw block (the batched body of
        ``_mvn_sample_impl``, minus the draw -- draws happen per request
        at admission so results don't depend on slot placement)."""
        fact = res.fact
        X = fact.tri_matvec(Z)
        eperm = _solve.tile_perm_to_element_perm(fact.perm, fact.L.b)
        return _solve._unpermute_rows(X, eperm)

    def _admit(self, i: int, req: ServeRequest) -> None:
        slot = _Slot(req=req, admit_tick=self._tick)
        res = self._residents[req.fid]
        if req.kind == "sample":
            # The identical (n, 1) draw .sample(key, 1) makes, pulled to
            # host once so tick packing stays in numpy.
            z = jax.random.normal(req.sample_key(), (res.fact.n, 1),
                                  res.fact.dtype)
            slot.z = np.asarray(z)[:, 0]
        elif req.kind == "pcg_solve":
            res.engine.load(i, req.rhs, tol=req.tol, maxiter=req.maxiter)
        self._slots[i] = slot
        self.stats.admitted += 1

    def _complete(self, i: int, value, *, iterations: int = 0,
                  converged: bool = True, breakdown=None,
                  history=None) -> ServeResult:
        slot = self._slots[i]
        req = slot.req
        result = ServeResult(
            rid=req.rid, kind=req.kind, fid=req.fid, value=value,
            iterations=iterations, converged=converged, breakdown=breakdown,
            history=history,
            latency_s=time.perf_counter() - self._submit_t.pop(req.rid),
            ticks=self._tick - slot.admit_tick + 1)
        self.results[req.rid] = result
        self.stats.record_completion(req.kind, result.latency_s,
                                     result.ticks)
        self._slots[i] = None
        return result

    def tick(self) -> List[ServeResult]:
        """One refill -> compute -> evict cycle; returns the requests
        completed this tick (in slot order per kind)."""
        if not self._warm:
            self.warmup()
        t0 = time.perf_counter()
        with obs.span("serve.tick", cat="serve", tick=self._tick) as _tsp:
            # 1. refill free slots in FIFO order
            with obs.span("serve.pack", cat="serve", stage="refill"):
                for i in range(self.slots):
                    if self._slots[i] is None and self._queue:
                        self._admit(i, self._queue.pop())
            self.stats.record_tick(self.active, 0.0)  # seconds patched below
            if obs.enabled():
                _tsp.set(active=self.active, pending=self.pending)
                obs.counter("occupancy", {"active": self.active,
                                          "slots": self.slots})
            done: List[ServeResult] = []
            # 2/3. compute + evict, one batched op per (resident, kind)
            for fid, res in self._residents.items():
                by_kind: Dict[str, List[int]] = {}
                for i, slot in enumerate(self._slots):
                    if slot is not None and slot.req.fid == fid:
                        by_kind.setdefault(slot.req.kind, []).append(i)
                if "logdet" in by_kind:
                    with obs.span("serve.evict", cat="serve", kind="logdet"):
                        for i in by_kind["logdet"]:
                            done.append(self._complete(i, res.logdet))
                if "solve" in by_kind:
                    idx = by_kind["solve"]
                    with obs.span("serve.pack", cat="serve", kind="solve",
                                  count=len(idx)):
                        B = np.zeros((res.fact.n, self.slots),
                                     np.dtype(res.fact.dtype))
                        for i in idx:
                            B[:, i] = self._slots[i].req.rhs
                    with obs.span("serve.dispatch", cat="serve",
                                  kind="solve"):
                        Xd = res.fact.solve(jnp.asarray(B))
                    with obs.span("serve.sync", cat="serve", kind="solve"):
                        X = np.asarray(Xd)
                    with obs.span("serve.evict", cat="serve", kind="solve"):
                        for i in idx:
                            done.append(self._complete(i, X[:, i].copy()))
                if "sample" in by_kind:
                    idx = by_kind["sample"]
                    with obs.span("serve.pack", cat="serve", kind="sample",
                                  count=len(idx)):
                        Z = np.zeros((res.fact.n, self.slots),
                                     np.dtype(res.fact.dtype))
                        for i in idx:
                            Z[:, i] = self._slots[i].z
                    with obs.span("serve.dispatch", cat="serve",
                                  kind="sample"):
                        Xd = self._sample_block(res, jnp.asarray(Z))
                    with obs.span("serve.sync", cat="serve", kind="sample"):
                        X = np.asarray(Xd)
                    with obs.span("serve.evict", cat="serve", kind="sample"):
                        for i in idx:
                            done.append(self._complete(i, X[:, i].copy()))
                if "pcg_solve" in by_kind:
                    with obs.span("serve.dispatch", cat="serve",
                                  kind="pcg_solve"):
                        res.engine.advance(self.check_every)
                    # ``done_columns`` rather than advance's return: a
                    # zero-rhs load finishes without ever activating.
                    with obs.span("serve.evict", cat="serve",
                                  kind="pcg_solve"):
                        for i in res.engine.done_columns:
                            x, iters, hist, conv = res.engine.evict(i)
                            done.append(self._complete(
                                i, x, iterations=iters, converged=conv,
                                breakdown=hist.breakdown, history=hist))
        self.stats.tick_seconds[-1] = time.perf_counter() - t0
        self._tick += 1
        return done

    def run(self, max_ticks: Optional[int] = None) -> Dict[int, ServeResult]:
        """Tick until the queue and every slot drain (or ``max_ticks``);
        returns all results completed so far, keyed by rid. Termination is
        guaranteed: direct kinds complete in their admission tick and PCG
        columns are bounded by their per-request ``maxiter``."""
        ticks = 0
        while self._queue or self.active:
            if max_ticks is not None and ticks >= max_ticks:
                break
            self.tick()
            ticks += 1
        return dict(self.results)

    def result(self, rid: int) -> ServeResult:
        if rid not in self.results:
            raise KeyError(f"request {rid} has not completed "
                           f"({self.pending} queued, {self.active} active)")
        return self.results[rid]
