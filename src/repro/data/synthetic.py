"""Deterministic, shardable, resumable synthetic token pipeline.

Batches are a pure function of (seed, step): resuming from a checkpoint at
step k replays exactly the stream a non-preempted run would have seen, and
any host can materialize just its slice (``host_slice``) -- the properties a
real distributed loader must have, provided here without an external corpus.

Tokens follow a Zipf distribution with document boundaries (EOS every
~doc_len tokens) so losses behave like natural text rather than uniform
noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    doc_len: int = 512
    eos_id: int = 0


class SyntheticTokens:
    """Stateless-by-step token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, *, host_index: int = 0,
                 host_count: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.batch % host_count == 0
        local_b = cfg.batch // host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_index]))
        z = rng.zipf(cfg.zipf_a, size=(local_b, cfg.seq_len + 1))
        tokens = (z % (cfg.vocab_size - 1)) + 1     # reserve 0 for EOS
        # document boundaries
        doc = rng.geometric(1.0 / cfg.doc_len, size=(local_b, 8))
        pos = np.cumsum(doc, axis=1)
        for b in range(local_b):
            for p in pos[b]:
                if p < cfg.seq_len + 1:
                    tokens[b, p] = cfg.eos_id
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
