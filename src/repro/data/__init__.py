from .synthetic import DataConfig, SyntheticTokens  # noqa: F401
